"""Exp. 9 & 10 (Fig. 18/19): effective training-time ratio under frequent
failures (MTBF 0.1-5h) and with 8-64 GPUs.

Paper claims: LowDiff+(S) highest everywhere (94.0% @ MTBF 0.3h), LowDiff
second (92%), LowDiff+(P) above CheckFreq/Gemini; at 64 GPUs LowDiff
holds ~98% while others fall toward 90%.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core.simulator import paper_profiles, simulate

BASE = dict(iter_time=0.35, full_bytes=1.4e9, diff_bytes=9.2e6,
            compress_stall=0.08, batch_size=2, full_interval=20)
STRATS = ("full_sync", "checkfreq", "gemini", "lowdiff",
          "lowdiff_plus_s", "lowdiff_plus_p")


def eff(name, mtbf_s, profiles, iters=60_000, seeds=4):
    return float(np.mean([
        simulate(profiles[name], run_iters=iters, mtbf_s=mtbf_s,
                 seed=s).effective_ratio for s in range(seeds)]))


def main(out):
    profiles = paper_profiles(**BASE)
    for mtbf_h in (0.1, 0.3, 1.0, 5.0):
        vals = {n: eff(n, mtbf_h * 3600, profiles) for n in STRATS}
        out(row(f"exp9.mtbf{mtbf_h}", 0.0,
                " ".join(f"{k}={v * 100:.1f}%" for k, v in vals.items())))
        assert vals["lowdiff_plus_s"] >= max(
            vals["checkfreq"], vals["full_sync"]) - 1e-9

    # Exp 10: failure rate scales with GPU count (MTBF_cluster = MTBF/N)
    node_mtbf_h = 30.0
    for n_gpus in (8, 16, 32, 64):
        mtbf = node_mtbf_h * 3600 * 8 / n_gpus
        vals = {n: eff(n, mtbf, profiles) for n in STRATS}
        out(row(f"exp10.gpus{n_gpus}", 0.0,
                " ".join(f"{k}={v * 100:.1f}%" for k, v in vals.items())))


if __name__ == "__main__":
    main(print)
