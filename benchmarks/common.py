"""Shared fixtures/timing helpers for the benchmark suite."""
from __future__ import annotations

import shutil
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint.config import StoreConfig
from repro.checkpoint.store import CheckpointStore
from repro.configs import get_config
from repro.core.steps import init_state, make_train_step
from repro.data.synthetic import make_batch
from repro.models.registry import build_model

SEQ, BATCH = 64, 4


def bench_model(name: str = "gpt2-l", **overrides):
    cfg = get_config(name).reduced()
    if overrides:
        cfg = cfg.replace(**overrides)
    return build_model(cfg)


def timeit(fn: Callable, *, warmup: int = 2, iters: int = 5) -> float:
    """Median seconds per call."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def fresh_store(path: str, backend: str = "local",
                **kw) -> CheckpointStore:
    shutil.rmtree(path, ignore_errors=True)
    return StoreConfig.from_legacy(path, backend=backend, **kw).build()


def measured_iter_time(model, steps: int = 6) -> float:
    """Raw training iteration time (no checkpointing)."""
    step = make_train_step(model, mode="dense")
    state = init_state(model, jax.random.PRNGKey(0), mode="dense")
    b = make_batch(model.cfg, SEQ, BATCH)

    def one():
        nonlocal state
        state, _, _ = step(state, b)
        jax.block_until_ready(state["params"])

    return timeit(one, warmup=2, iters=steps)


def row(name: str, seconds_per_call: float, derived: str = "") -> str:
    """CSV row in the harness format: name,us_per_call,derived."""
    return f"{name},{seconds_per_call * 1e6:.1f},{derived}"
