"""Fig. 1: DC compression / transmission stalls vs frequency.

Measures (a) the cost of compressing a 3Ψ state differential (Naïve DC's
per-checkpoint compute) and (b) the blocking write of the compressed
differential, then derives the training slowdown at compression
frequencies 1/2/4/8 iterations — the measurement behind the paper's
Challenge 1 & 2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_model, fresh_store, measured_iter_time, row, timeit
from repro.compression.sparse import compress_tree
from repro.core.lowdiff import host_copy
from repro.core.steps import init_state


def main(out):
    model = bench_model()
    state = init_state(model, jax.random.PRNGKey(0), mode="dense")
    iter_t = measured_iter_time(model)

    # 3Ψ differential (params + both Adam moments), compressed at rho=0.01
    diff = {"p": state["params"], "mu": state["opt"].mu,
            "nu": state["opt"].nu}
    comp = jax.jit(lambda d: compress_tree(d, 0.01))
    cd = comp(diff)
    t_comp = timeit(lambda: jax.block_until_ready(comp(diff)))
    out(row("fig1.compress_3psi", t_comp,
            f"iter={iter_t * 1e3:.1f}ms"))

    store = fresh_store("/tmp/repro_bench/dc_stalls")
    payload = host_copy(cd)
    t_write = timeit(lambda: store.save_diff(0, payload), iters=3)
    out(row("fig1.write_diff", t_write, ""))

    for freq in (8, 4, 2, 1):
        slowdown = (t_comp + t_write) / freq / iter_t * 100
        out(row(f"fig1.slowdown_freq{freq}",
                iter_t + (t_comp + t_write) / freq,
                f"slowdown={slowdown:.1f}%"))


if __name__ == "__main__":
    main(print)
