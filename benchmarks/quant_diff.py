"""Exp. 18: quantized row differentials (--diff-quant int8/int4).

Three measurements on the exp16 synthetic MoE workload (one big expert
table, ~1% of rows dirty per persist interval), now with the row spans
quantized on the wire:

* **bytes written per persist** — raw row spans (PR 7's row mode) vs
  int8 vs nibble-packed int4 payloads. The headline number: int4 must
  write >= 3x fewer bytes/persist than raw row mode at ~1% dirty rows
  (CI asserts this from the smoke artifact). The raw/quantized gap is
  the per-row absmax codec's realized ratio minus frame/scale overhead.
* **recovery wall** — a 16-patch quantized chain replayed on the host
  overlay path (``load_latest_state``) and the device replay path
  (``recovery.load_state_device``, fused dequantize-and-scatter); both
  must land bit-identical states.
* **convergence parity** — a small Adam regression run that crashes
  mid-training and resumes from its persisted chain: the final loss
  with int4 + error feedback lands within noise of the raw-chain run
  (quantization error is fed back, not compounded).
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import row, timeit
from repro.checkpoint.config import StoreConfig
from repro.checkpoint.store import walk_leaves
from repro.core import recovery
from repro.core.lowdiff_plus import _NumpyAdam

ROWS = 8192               # expert-table rows
DM = 256                  # 8 MiB fp32 per component (params/mu/nu)
HOT_BLOCKS = 8            # dirty spans per interval...
BLOCK = 10                # ...of this many rows: ~1% of ROWS
PERSISTS = 4


def make_replica(diff_quant="off", rows=ROWS, dm=DM, seed=0):
    rng = np.random.default_rng(seed)
    params = {"table": (0.1 * rng.standard_normal(
        (rows, dm))).astype(np.float32)}
    mu = {k: np.zeros_like(v) for k, v in params.items()}
    nu = {k: np.zeros_like(v) for k, v in params.items()}
    return _NumpyAdam(params, mu, nu, 0, lr=1e-3, track_dirty=True,
                      dirty_granularity="row", diff_quant=diff_quant)


def sparse_row_grads(rep, seed):
    """~1% of rows nonzero, in HOT_BLOCKS random contiguous blocks."""
    rng = np.random.default_rng(seed)
    rows, dm = rep.params["table"].shape
    g = np.zeros((rows, dm), np.float32)
    for start in rng.integers(0, rows - BLOCK, HOT_BLOCKS):
        g[start:start + BLOCK] = rng.standard_normal(
            (BLOCK, dm)).astype(np.float32)
    return {"table": g}


def bench_bytes(out, tmp):
    per_mode = {}
    for mode in ("raw", "int8", "int4"):
        dq = "off" if mode == "raw" else mode
        store = StoreConfig.from_legacy(f"{tmp}/{mode}").build()
        rep = make_replica(dq)
        rep.apply(sparse_row_grads(rep, 0))
        base = store.save_full(1, rep.snapshot_full(), record_names=True)
        base_bytes = store.bytes_written
        t_persist = []
        for step in range(2, PERSISTS + 2):
            rep.apply(sparse_row_grads(rep, step))
            updates, _ = rep.snapshot_dirty()
            t0 = time.perf_counter()
            store.save_patch(step, base, updates)
            t_persist.append(time.perf_counter() - t0)
        per_mode[mode] = (store.bytes_written - base_bytes) / PERSISTS
        out(row(f"exp18_{mode}_persist_bytes", 0.0,
                f"{per_mode[mode] / 1e6:.3f}MB"))
        out(row(f"exp18_{mode}_persist_latency",
                float(np.median(t_persist))))
        # host and device replay of the same chain must agree bitwise
        got, _ = store.load_latest_state()
        dgot, _ = recovery.load_state_device(store)
        for path, leaf in walk_leaves(got):
            np.testing.assert_array_equal(
                np.asarray(leaf), np.asarray(dict(walk_leaves(dgot))[path]),
                err_msg=f"{mode}: {path}")
        if mode == "raw":
            # raw chains additionally recover the exact replica bytes
            np.testing.assert_array_equal(got["params"]["table"],
                                          rep.params["table"])
        else:
            # quantized chains land within the absmax codec's error
            err = float(np.abs(np.asarray(got["params"]["table"])
                               - rep.params["table"]).max())
            scale = float(np.abs(rep.params["table"]).max())
            assert err <= scale, f"{mode} recovery error {err}"
        store.close()
    for mode in ("int8", "int4"):
        ratio = per_mode["raw"] / max(per_mode[mode], 1.0)
        out(row(f"exp18_bytes_ratio_raw_over_{mode}", 0.0, f"x{ratio:.1f}"))
    return per_mode["raw"] / max(per_mode["int4"], 1.0)


def bench_recovery(out, tmp):
    for dq in ("int8", "int4"):
        store = StoreConfig.from_legacy(f"{tmp}/rec_{dq}").build()
        rep = make_replica(dq)
        rep.apply(sparse_row_grads(rep, 0))
        base = store.save_full(1, rep.snapshot_full(), record_names=True)
        for step in range(2, 18):
            rep.apply(sparse_row_grads(rep, step))
            updates, _ = rep.snapshot_dirty()
            store.save_patch(step, base, updates)
        t_host = timeit(lambda: store.load_latest_state(),
                        warmup=1, iters=3)
        t_dev = timeit(lambda: recovery.load_state_device(store),
                       warmup=1, iters=3)
        out(row(f"exp18_recovery_host_{dq}_chain_16", t_host))
        out(row(f"exp18_recovery_device_{dq}_chain_16", t_dev))
        store.close()


def _regression_loss(w, x, y):
    r = x @ w.T - y
    return float(np.mean(r * r))


def bench_convergence(out, tmp):
    """Crash-and-resume training parity: raw vs int4 + error feedback.

    A least-squares Adam run persists an incremental chain every step,
    "crashes" at the midpoint, resumes from the recovered chain, and
    trains to the end. With error feedback the quantized chain's final
    loss lands within noise of the raw chain's."""
    rng = np.random.default_rng(7)
    n_out, n_in, n_data, steps, crash_at = 64, 16, 256, 240, 120
    x = rng.standard_normal((n_data, n_in)).astype(np.float32)
    w_true = rng.standard_normal((n_out, n_in)).astype(np.float32)
    y = x @ w_true.T + 0.01 * rng.standard_normal(
        (n_data, n_out)).astype(np.float32)

    def grads(w):
        r = x @ w.T - y                       # (n_data, n_out)
        return (2.0 / n_data) * r.T @ x       # (n_out, n_in)

    final = {}
    for mode in ("raw", "int4"):
        dq = "off" if mode == "raw" else mode
        store = StoreConfig.from_legacy(f"{tmp}/conv_{mode}").build()
        w0 = np.zeros((n_out, n_in), np.float32)
        rep = _NumpyAdam({"w": w0}, {"w": np.zeros_like(w0)},
                         {"w": np.zeros_like(w0)}, 0, lr=5e-2,
                         track_dirty=True, dirty_granularity="row",
                         diff_quant=dq)
        base = store.save_full(1, rep.snapshot_full(), record_names=True)
        for step in range(crash_at):
            rep.apply({"w": grads(rep.params["w"])})
            updates, _ = rep.snapshot_dirty()
            store.save_patch(2 + step, base, updates)
        # crash: rebuild the replica from the persisted chain alone
        state, _ = store.load_latest_state()
        rep = _NumpyAdam({"w": np.array(state["params"]["w"])},
                         {"w": np.array(state["mu"]["w"])},
                         {"w": np.array(state["nu"]["w"])},
                         int(state["count"]), lr=5e-2,
                         track_dirty=True, dirty_granularity="row",
                         diff_quant=dq)
        base = store.save_full(2 + crash_at, rep.snapshot_full(),
                               record_names=True)
        for step in range(crash_at, steps):
            rep.apply({"w": grads(rep.params["w"])})
            updates, _ = rep.snapshot_dirty()
            store.save_patch(3 + step, base, updates)
        final[mode] = _regression_loss(rep.params["w"], x, y)
        out(row(f"exp18_final_loss_{mode}", 0.0, f"{final[mode]:.6f}"))
        store.close()
    # parity: the quantized-chain run converges like the raw run (the
    # noise floor is the 0.01 label noise -> loss ~1e-4 either way)
    rel = abs(final["int4"] - final["raw"]) / max(final["raw"], 1e-12)
    out(row("exp18_convergence_rel_gap", 0.0, f"{rel:.4f}"))
    assert rel < 0.25, (
        f"int4+EF final loss {final['int4']:.6f} diverged from raw "
        f"{final['raw']:.6f} (rel gap {rel:.3f})")


def main(out=print):
    tmp = tempfile.mkdtemp(prefix="exp18_")
    try:
        ratio = bench_bytes(out, tmp)
        bench_recovery(out, tmp)
        bench_convergence(out, tmp)
        if ratio < 3.0:
            raise AssertionError(
                f"quantized persist regression: int4 wrote only "
                f"{ratio:.1f}x fewer bytes than raw row spans at ~1% "
                f"dirty rows (acceptance bar: 3x)")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
