"""Table I + Eq. 8-10: normalized wasted time over (FCF, BS) grid and the
closed-form optimum.

Paper claim: wasted time is U-shaped in both FCF and BS; minimum in the
paper's measurement at FCF=20, BS=2. We evaluate Eq. (8) with
paper-calibrated constants, print the normalized grid, and verify the
closed form lands in the grid minimum cell.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core.config_opt import (SystemParams, grid_verify, optimal_config,
                                   wasted_time)

# constants chosen to reproduce Table I's regime (GPT2-L on 8xA100, 25Gbps)
P = SystemParams(N=8, M=7200, W=5e9, S=8.7e9, T=1e5, R_F=6.0, R_D=1.1)

FCF = [10, 20, 50, 100]          # full-checkpoint interval (iterations)
BS = [1, 2, 3, 4, 5, 6]


def main(out):
    grid = np.array([[wasted_time(1.0 / fcf, b, P) for b in BS]
                     for fcf in FCF])
    grid /= grid.min()
    i, j = np.unravel_index(np.argmin(grid), grid.shape)
    out(row("table1.grid_min", 0.0,
            f"FCF={FCF[i]} BS={BS[j]} (paper: FCF=20 BS=2)"))
    for r, fcf in enumerate(FCF):
        cells = " ".join(f"{grid[r, c]:.3f}" for c in range(len(BS)))
        out(row(f"table1.fcf{fcf}", 0.0, cells))
    f_star, b_star = optimal_config(P)
    f_g, b_g, _ = grid_verify(P)
    out(row("eq10.closed_form", 0.0,
            f"interval={1 / f_star:.1f} b={b_star:.2f} "
            f"(grid: {1 / f_g:.1f}/{b_g:.2f})"))


if __name__ == "__main__":
    main(print)
