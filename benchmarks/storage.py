"""Exp. 7 (Table III): per-checkpoint storage — Full vs Naïve DC vs LowDiff.

Byte-exact measurement on the reduced models plus the analytic projection
for the paper's model sizes. Paper claims: Naïve DC ≈ 34.4% below full
(compresses params only — optimizer dominates); LowDiff a further 90.5%
below Naïve DC (compresses the 1Ψ gradient instead of the 3Ψ state).
"""
from __future__ import annotations

import jax

from benchmarks.common import BATCH, SEQ, bench_model, row
from repro.compression.sparse import (compress_tree, dense_nbytes,
                                      tree_nbytes)
from repro.core.steps import init_state, make_train_step
from repro.data.synthetic import make_batch

PAPER_MODELS = {  # params (from Table II), f32 bytes
    "ResNet-101": 44.5e6, "VGG-19": 143.7e6, "BERT-B": 110e6,
    "BERT-L": 334e6, "GPT2-S": 117e6, "GPT2-L": 762e6,
}
RHO = 0.01
TOPK_OVERHEAD = 1.5   # values + int16 indices per kept f32 element


def main(out):
    model = bench_model()
    step = make_train_step(model, mode="lowdiff", rho=RHO)
    state = init_state(model, jax.random.PRNGKey(0), mode="lowdiff")
    state, _, cg = step(state, make_batch(model.cfg, SEQ, BATCH))

    full = (dense_nbytes(state["params"]) + dense_nbytes(state["opt"].mu)
            + dense_nbytes(state["opt"].nu))
    naive = compress_tree({"p": state["params"], "mu": state["opt"].mu,
                           "nu": state["opt"].nu}, RHO)
    naive_b = tree_nbytes(naive)
    low_b = tree_nbytes(cg)
    out(row("exp7.measured.full", 0.0, f"{full / 2**20:.2f}MiB"))
    out(row("exp7.measured.naive_dc", 0.0,
            f"{naive_b / 2**20:.2f}MiB ({naive_b / full * 100:.1f}% of full)"))
    out(row("exp7.measured.lowdiff", 0.0,
            f"{low_b / 2**20:.2f}MiB ({(1 - low_b / naive_b) * 100:.1f}% "
            f"below naive)"))

    # analytic projection at the paper's model sizes (f32, rho=0.01, 8
    # data-parallel workers):
    # full = 3*4*P ; naive-dc(Check-N-Run) compresses only params (the
    # state diff is identical on every worker) -> rho*P*4*ovh + 2*4*P ;
    # lowdiff stores the allgathered sparsified gradient, whose index set
    # is the union over workers -> ~N_workers * rho * P entries (this is
    # why the paper's GPT2-L LowDiff checkpoint is 541M, not 61M).
    workers = 8
    for name, P in PAPER_MODELS.items():
        full_b = 3 * 4 * P
        naive_b = RHO * P * 4 * TOPK_OVERHEAD + 2 * 4 * P
        low_b = RHO * P * workers * 4 * TOPK_OVERHEAD
        out(row(f"exp7.paper.{name}", 0.0,
                f"full={full_b / 2**30:.2f}G naive={naive_b / 2**30:.2f}G "
                f"lowdiff={low_b / 2**20:.0f}M "
                f"(lowdiff {(1 - low_b / naive_b) * 100:.1f}% below naive; "
                f"paper GPT2-L: 90.5%)"))


if __name__ == "__main__":
    main(print)
