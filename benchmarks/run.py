"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Run with::

    PYTHONPATH=src python -m benchmarks.run [--only exp5]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    ("fig1_dc_stalls", "benchmarks.dc_stalls"),
    ("fig4_overlap", "benchmarks.overlap"),
    ("table1_config", "benchmarks.config_table"),
    ("exp1_training_time", "benchmarks.training_time"),
    ("exp3_wasted_time", "benchmarks.wasted_time"),
    ("exp4_max_frequency", "benchmarks.max_frequency"),
    ("exp5_recovery", "benchmarks.recovery_bench"),
    ("exp6_batched_write", "benchmarks.batched_write"),
    ("exp7_storage", "benchmarks.storage"),
    ("exp8_compression_ratio", "benchmarks.compression_ratio"),
    ("exp9_10_scaling", "benchmarks.scaling"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark name")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    for name, modname in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["main"])
            mod.main(print)
            print(f"# {name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
