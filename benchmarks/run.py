"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Run with::

    PYTHONPATH=src python -m benchmarks.run [--only exp5] [--json out.json]

``--json`` additionally writes the rows (plus per-module wall time and
failure status) as a JSON document — CI uploads this as the benchmark
smoke artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

MODULES = [
    ("fig1_dc_stalls", "benchmarks.dc_stalls"),
    ("fig4_overlap", "benchmarks.overlap"),
    ("table1_config", "benchmarks.config_table"),
    ("exp1_training_time", "benchmarks.training_time"),
    ("exp3_wasted_time", "benchmarks.wasted_time"),
    ("exp4_max_frequency", "benchmarks.max_frequency"),
    ("exp5_recovery", "benchmarks.recovery_bench"),
    ("exp6_batched_write", "benchmarks.batched_write"),
    ("exp7_storage", "benchmarks.storage"),
    ("exp8_compression_ratio", "benchmarks.compression_ratio"),
    ("exp9_10_scaling", "benchmarks.scaling"),
    ("exp11_remote_tier", "benchmarks.remote_tier"),
    ("exp12_serialization", "benchmarks.serialization"),
    ("exp13_maintenance", "benchmarks.maintenance"),
    ("exp14_incremental_persist", "benchmarks.incremental_persist"),
    ("exp15_peer_replica", "benchmarks.peer_replica"),
    ("exp16_row_granular", "benchmarks.row_granular"),
    # third element (optional) = entry point, for modules hosting more
    # than one experiment
    ("exp17_device_replay", "benchmarks.recovery_bench", "main17"),
    ("exp18_quant_diff", "benchmarks.quant_diff"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark name")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON (CI artifact)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    results = []
    failures = 0
    for entry in MODULES:
        name, modname = entry[0], entry[1]
        attr = entry[2] if len(entry) > 2 else "main"
        if args.only and args.only not in name:
            continue
        rows: list = []

        def out(row, _rows=rows):
            _rows.append(str(row))
            print(row)

        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=[attr])
            getattr(mod, attr)(out)
            status = "ok"
            print(f"# {name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures += 1
            status = "failed"
            print(f"# {name} FAILED", file=sys.stderr)
            traceback.print_exc()
        results.append({"benchmark": name, "module": modname,
                        "status": status,
                        "seconds": round(time.time() - t0, 3),
                        "rows": rows})
    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump({"schema": "repro-bench/v1", "results": results}, f,
                      indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
