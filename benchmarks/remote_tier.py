"""Exp. 11: remote object-store tier throughput.

Measures RemoteObjectBackend put/get bandwidth through a hermetic
FakeObjectStore (with simulated per-MB latency standing in for the
network) at several chunk sizes, the retry overhead under injected
transient faults, and how the CPU-memory tier's asynchronous write-back
hides remote put latency from the caller (the paper's requirement that
the lowest tier absorb a gradient stream without stalling training).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.checkpoint.backends import MemoryTierBackend
from repro.checkpoint.remote import (FakeObjectStore, FaultInjector,
                                     RemoteObjectBackend)

BLOB_MB = 8
LATENCY_S_PER_MB = 0.002       # simulated wire time: ~500 MB/s


def _tree(mb: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = int(mb * 2**20 / 4)
    return {"g": rng.normal(size=(n,)).astype(np.float32)}


def main(out):
    tree = _tree(BLOB_MB)

    for chunk_mb in (1, 4, 16):
        be = RemoteObjectBackend(
            FakeObjectStore(latency_s_per_mb=LATENCY_S_PER_MB),
            chunk_bytes=int(chunk_mb * 2**20))
        t_put = timeit(lambda: be.put("k", tree), warmup=1, iters=3)
        t_get = timeit(lambda: be.get("k"), warmup=1, iters=3)
        out(row(f"exp11.remote.chunk{chunk_mb}mb.put", t_put,
                f"{BLOB_MB / t_put:.0f}MB/s"))
        out(row(f"exp11.remote.chunk{chunk_mb}mb.get", t_get,
                f"{BLOB_MB / t_get:.0f}MB/s"))

    # retry overhead under a 20% transient-fault rate
    faulty = RemoteObjectBackend(
        FakeObjectStore(FaultInjector(rate=0.2, seed=7),
                        latency_s_per_mb=LATENCY_S_PER_MB),
        chunk_bytes=1 << 20, backoff_s=0.001)
    t_put = timeit(lambda: faulty.put("k", tree), warmup=1, iters=3)
    st = faulty.stats()
    out(row("exp11.remote.faulty20.put", t_put,
            f"{BLOB_MB / t_put:.0f}MB/s retries={st['retries']}"))

    # async write-back: the caller sees memcpy speed, not wire speed
    tier = MemoryTierBackend(RemoteObjectBackend(
        FakeObjectStore(latency_s_per_mb=LATENCY_S_PER_MB),
        chunk_bytes=4 << 20))
    i = [0]

    def tiered_put():
        tier.put(f"k{i[0]}", tree)
        i[0] += 1

    t_tier = timeit(tiered_put, warmup=1, iters=3)
    tier.flush()
    out(row("exp11.remote.memtier.put", t_tier,
            f"caller sees {BLOB_MB / t_tier:.0f}MB/s "
            f"(wire {1.0 / LATENCY_S_PER_MB:.0f}MB/s)"))
    tier.close()


if __name__ == "__main__":
    main(print)
