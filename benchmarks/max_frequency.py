"""Exp. 4 (Fig. 14): maximum checkpointing frequency under a 3.5%
training-slowdown bound.

For each strategy we measure the non-overlappable per-checkpoint cost in
the training loop and derive the smallest interval with overhead <= 3.5%.
Paper claims: LowDiff achieves interval=1 everywhere; CheckFreq ~10;
Gemini 1-4; NaiveDC 2-8 growing with model size.
"""
from __future__ import annotations

import jax

from benchmarks.common import (BATCH, SEQ, bench_model, fresh_store,
                               measured_iter_time, row, timeit)
from repro.compression.sparse import compress_tree
from repro.core.lowdiff import LowDiff, host_copy
from repro.core.steps import init_state, make_train_step
from repro.data.synthetic import make_batch

BOUND = 0.035


def main(out):
    for name, ov in {"small": dict(n_layers=2, d_model=192),
                     "large": dict(n_layers=4, d_model=256)}.items():
        model = bench_model(**ov)
        iter_t = measured_iter_time(model)
        state = init_state(model, jax.random.PRNGKey(0), mode="lowdiff")
        step = make_train_step(model, mode="lowdiff", rho=0.01)
        b = make_batch(model.cfg, SEQ, BATCH)
        state, _, cg = step(state, b)

        store = fresh_store(f"/tmp/repro_bench/maxfreq_{name}")
        # LowDiff: loop cost = enqueue only (write is off-thread). A large
        # queue removes backpressure so the measurement reflects the
        # hand-off cost, not this container's single-core contention
        # (on a TPU host the consumer runs on spare CPU cores).
        ld = LowDiff(model, store, rho=0.01, full_interval=1000,
                     batch_size=8, queue_size=64)
        st2 = dict(state)
        ld.train_step(st2, b)
        t0 = ld.ckpt_time
        for _ in range(4):
            ld.train_step(st2, b)
        lowdiff_cost = (ld.ckpt_time - t0) / 4
        ld.close()

        snap_cost = timeit(lambda: host_copy(state))      # CheckFreq/Gemini
        diff3 = {"p": state["params"], "mu": state["opt"].mu,
                 "nu": state["opt"].nu}
        cmp3 = jax.jit(lambda d: compress_tree(d, 0.01))
        jax.block_until_ready(cmp3(diff3))
        naive_cost = timeit(lambda: jax.block_until_ready(cmp3(diff3)))

        def min_interval(cost):
            k = 1
            while cost / k > BOUND * iter_t and k < 64:
                k += 1
            return k

        out(row(f"exp4.{name}.lowdiff", lowdiff_cost,
                f"interval={min_interval(lowdiff_cost)}"))
        out(row(f"exp4.{name}.gemini_snap", snap_cost,
                f"interval={min_interval(snap_cost)}"))
        out(row(f"exp4.{name}.checkfreq_snap", snap_cost,
                f"interval={max(10, min_interval(snap_cost))}"))
        out(row(f"exp4.{name}.naive_dc", naive_cost,
                f"interval={min_interval(naive_cost)}"))


if __name__ == "__main__":
    main(print)
