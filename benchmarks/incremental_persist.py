"""Exp. 14: incremental-merging persistence engine.

Four measurements on a synthetic sparse-update workload (20 leaves,
~15% dirty per persist interval — the regime Check-N-Run reports for
embedding-heavy training):

* **bytes written per persist** — full replica rewrite vs dirty-leaf
  patch blobs. The headline number: incremental persistence must write
  >= 5x fewer bytes when <= 20% of leaves are dirty (CI asserts this
  from the smoke artifact).
* **persist latency** — wall time of ``save_full`` vs ``save_patch``
  on the persist thread.
* **consumer-thread stall** — time the replica lock is held for the
  persist snapshot: O(model) deep copy vs dirty-leaves-only copy.
* **recovery time vs patch-chain length** — ``load_latest_state`` with
  0 / 8 / 16 outstanding patches, and again after the background fold
  consolidates the chain back to one frame read.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import row, timeit
from repro.checkpoint.config import StoreConfig
from repro.core.lowdiff_plus import _NumpyAdam

N_LEAVES = 20
LEAF = 16384              # 64 KiB per leaf (fp32)
HOT = [f"w{i}" for i in range(3)]   # 3 of 20 leaves dirty per interval
PERSISTS = 4


def make_replica(track):
    rng = np.random.default_rng(0)
    params = {f"w{i}": (0.1 * rng.standard_normal(LEAF)).astype(np.float32)
              for i in range(N_LEAVES)}
    mu = {k: np.zeros_like(v) for k, v in params.items()}
    nu = {k: np.zeros_like(v) for k, v in params.items()}
    return _NumpyAdam(params, mu, nu, 0, lr=1e-3, track_dirty=track)


def sparse_grads(rep, seed):
    rng = np.random.default_rng(seed)
    return {k: (rng.standard_normal(v.shape).astype(np.float32)
                if k in HOT else np.zeros_like(v))
            for k, v in rep.params.items()}


def bench_bytes_and_latency(out, tmp):
    full_store = StoreConfig.from_legacy(f"{tmp}/full").build()
    rep = make_replica(track=False)
    t_full, stall_full = [], []
    for step in range(1, PERSISTS + 1):
        rep.apply(sparse_grads(rep, step))
        t0 = time.perf_counter()
        snap = rep.snapshot_full()
        stall_full.append(time.perf_counter() - t0)
        t1 = time.perf_counter()
        full_store.save_full(step, snap)
        t_full.append(time.perf_counter() - t1)
    full_bytes = full_store.bytes_written / PERSISTS
    full_store.close()

    incr_store = StoreConfig.from_legacy(f"{tmp}/incr").build()
    rep = make_replica(track=True)
    rep.apply(sparse_grads(rep, 0))
    base = incr_store.save_full(1, rep.snapshot_full(), record_names=True)
    base_bytes = incr_store.bytes_written
    t_incr, stall_incr = [], []
    for step in range(2, PERSISTS + 2):
        rep.apply(sparse_grads(rep, step))
        t0 = time.perf_counter()
        updates, _ = rep.snapshot_dirty()
        stall_incr.append(time.perf_counter() - t0)
        t1 = time.perf_counter()
        incr_store.save_patch(step, base, updates)
        t_incr.append(time.perf_counter() - t1)
    patch_bytes = (incr_store.bytes_written - base_bytes) / PERSISTS
    incr_store.close()

    ratio = full_bytes / max(patch_bytes, 1.0)
    out(row("exp14_full_persist_bytes", 0.0, f"{full_bytes / 1e6:.2f}MB"))
    out(row("exp14_incr_persist_bytes", 0.0, f"{patch_bytes / 1e6:.3f}MB"))
    out(row("exp14_bytes_ratio_full_over_incr", 0.0, f"x{ratio:.1f}"))
    out(row("exp14_full_persist_latency", float(np.median(t_full))))
    out(row("exp14_incr_persist_latency", float(np.median(t_incr))))
    out(row("exp14_full_snapshot_stall", float(np.median(stall_full))))
    out(row("exp14_incr_snapshot_stall", float(np.median(stall_incr))))
    return ratio


def bench_recovery(out, tmp):
    for chain in (0, 8, 16):
        store = StoreConfig.from_legacy(f"{tmp}/rec_{chain}").build()
        rep = make_replica(track=True)
        rep.apply(sparse_grads(rep, 0))
        base = store.save_full(1, rep.snapshot_full(), record_names=True)
        for step in range(2, chain + 2):
            rep.apply(sparse_grads(rep, step))
            updates, _ = rep.snapshot_dirty()
            store.save_patch(step, base, updates)
        t = timeit(lambda s=store: s.load_latest_state(), warmup=1, iters=3)
        out(row(f"exp14_recovery_chain_{chain:02d}", t))
        if chain == 16:
            store.fold_sync(merge_slice=8)
            t = timeit(lambda s=store: s.load_latest_state(),
                       warmup=1, iters=3)
            out(row("exp14_recovery_after_fold", t,
                    "chain folded to one frame read"))
        store.close()


def main(out=print):
    tmp = tempfile.mkdtemp(prefix="exp14_")
    try:
        ratio = bench_bytes_and_latency(out, tmp)
        bench_recovery(out, tmp)
        if ratio < 5.0:
            raise AssertionError(
                f"incremental persist regression: only {ratio:.1f}x fewer "
                f"bytes than full persistence (acceptance bar: 5x)")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
