"""Exp. 1 & 2 (Fig. 11/12): training time under per-iteration checkpointing
for every strategy, vs the W/O-CKPT upper bound.

Paper claims to validate: LowDiff overhead over W/O CKPT is 2.4-3.1%,
LowDiff+ 7.2-9.1%, while CheckFreq/Gemini/NaiveDC at the same frequency
cost far more. On this single-core container the *absolute* gaps differ
from an A100 server (checkpoint thread competes with compute for the one
core), so we report the ordering and the overlapped-write fractions.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import (BATCH, SEQ, bench_model, fresh_store,
                               measured_iter_time, row)
from repro.core.baselines import CheckFreq, FullSync, Gemini, NaiveDC
from repro.core.lowdiff import LowDiff
from repro.core.lowdiff_plus import LowDiffPlus
from repro.core.steps import init_state
from repro.data.synthetic import make_batch

STEPS = 16


def _run_strategy(model, name: str) -> float:
    store = fresh_store(f"/tmp/repro_bench/{name}")
    if name == "lowdiff":
        strat = LowDiff(model, store, rho=0.01, full_interval=10,
                        batch_size=2)
        mode = "lowdiff"
    elif name == "lowdiff_plus":
        strat = LowDiffPlus(model, store, persist_interval=4)
        mode = "lowdiff_plus"
    elif name == "checkfreq":
        strat, mode = CheckFreq(model, store, interval=10), "dense"
    elif name == "gemini":
        strat, mode = Gemini(model, store, interval=1,
                             persist_interval=16), "dense"
    elif name == "naive_dc":
        strat, mode = NaiveDC(model, store, rho=0.01,
                              full_interval=16), "dense"
    elif name == "full_sync":
        strat, mode = FullSync(model, store, interval=1), "dense"
    state = init_state(model, jax.random.PRNGKey(0), mode=mode)
    b = make_batch(model.cfg, SEQ, BATCH)
    # warmup (compile)
    state, _ = strat.train_step(state, b)
    t0 = time.perf_counter()
    for _ in range(STEPS):
        state, _ = strat.train_step(state, b)
    jax.block_until_ready(state["params"])
    strat.close()
    return (time.perf_counter() - t0) / STEPS


def main(out):
    model = bench_model()
    base = measured_iter_time(model)
    out(row("exp1.wo_ckpt", base, "baseline"))
    for name in ("lowdiff", "naive_dc", "checkfreq", "gemini", "full_sync",
                 "lowdiff_plus"):
        t = _run_strategy(model, name)
        ovh = (t - base) / base * 100
        out(row(f"exp1.{name}", t, f"overhead={ovh:.1f}%"))


if __name__ == "__main__":
    main(print)
