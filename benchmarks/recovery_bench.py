"""Exp. 5 (Fig. 15): recovery time — Baseline (full reload) vs Naïve DC
(serial delta merge) vs LowDiff parallel recovery vs LowDiff+(S)
in-memory restore.

Paper claims: LowDiff parallel recovery beats Baseline by 83.2% and Naïve
DC by 55.8% at FCF=10; LowDiff+(S) is 9.4-57.1x faster than Baseline.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import BATCH, SEQ, bench_model, fresh_store, row, timeit
from repro.core.lowdiff import LowDiff
from repro.core.lowdiff_plus import LowDiffPlus
from repro.core.steps import init_state
from repro.data.synthetic import make_batch


def main(out):
    model = bench_model()
    for n_diffs in (10, 30):
        store = fresh_store(f"/tmp/repro_bench/rec{n_diffs}")
        ld = LowDiff(model, store, rho=0.01, full_interval=10_000,
                     batch_size=2)
        state = init_state(model, jax.random.PRNGKey(0), mode="lowdiff")
        store.save_full(0, jax.tree.map(lambda x: x, state))
        b = make_batch(model.cfg, SEQ, BATCH)
        for _ in range(n_diffs):
            state, _ = ld.train_step(state, b)
        ld.flush()

        t_base = timeit(lambda: store.load_full(store.latest_full()),
                        iters=3)
        ld.parallel_recovery = False
        t_serial = timeit(lambda: ld.recover(), iters=3)
        ld.parallel_recovery = True
        ld.recover()   # compile the scan kernel once
        t_par = timeit(lambda: ld.recover(), iters=3)
        import math
        depth = math.ceil(math.log2(n_diffs)) + 1
        out(row(f"exp5.n{n_diffs}.full_reload", t_base, "baseline io"))
        out(row(f"exp5.n{n_diffs}.serial_replay", t_serial,
                f"depth={n_diffs} merges"))
        out(row(f"exp5.n{n_diffs}.parallel_replay", t_par,
                f"depth={depth} (log n) wall={t_serial / t_par:.2f}x "
                f"on 1 core"))
        ld.close()

    # LowDiff+ software recovery (from CPU replica)
    store = fresh_store("/tmp/repro_bench/rec_plus")
    ldp = LowDiffPlus(model, store, persist_interval=1000)
    state = init_state(model, jax.random.PRNGKey(1), mode="lowdiff_plus")
    b = make_batch(model.cfg, SEQ, BATCH)
    for _ in range(5):
        state, _ = ldp.train_step(state, b)
    ldp.flush()
    t_mem = timeit(lambda: ldp.recover_software(state), iters=3)
    out(row("exp5.lowdiff_plus_mem_restore", t_mem, "in-memory"))
    ldp.close()


if __name__ == "__main__":
    main(print)
