"""Exp. 5 (Fig. 15): recovery time — Baseline (full reload) vs Naïve DC
(serial delta merge) vs LowDiff parallel recovery vs LowDiff+(S)
in-memory restore.

Paper claims: LowDiff parallel recovery beats Baseline by 83.2% and Naïve
DC by 55.8% at FCF=10; LowDiff+(S) is 9.4-57.1x faster than Baseline.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import BATCH, SEQ, bench_model, fresh_store, row, timeit
from repro.core.lowdiff import LowDiff
from repro.core.lowdiff_plus import LowDiffPlus
from repro.core.steps import init_state
from repro.data.synthetic import make_batch


def main(out):
    model = bench_model()
    for n_diffs in (10, 30):
        store = fresh_store(f"/tmp/repro_bench/rec{n_diffs}")
        ld = LowDiff(model, store, rho=0.01, full_interval=10_000,
                     batch_size=2)
        state = init_state(model, jax.random.PRNGKey(0), mode="lowdiff")
        store.save_full(0, jax.tree.map(lambda x: x, state))
        b = make_batch(model.cfg, SEQ, BATCH)
        for _ in range(n_diffs):
            state, _ = ld.train_step(state, b)
        ld.flush()

        t_base = timeit(lambda: store.load_full(store.latest_full()),
                        iters=3)
        ld.parallel_recovery = False
        t_serial = timeit(lambda: ld.recover(), iters=3)
        ld.parallel_recovery = True
        ld.recover()   # compile the scan kernel once
        t_par = timeit(lambda: ld.recover(), iters=3)
        import math
        depth = math.ceil(math.log2(n_diffs)) + 1
        out(row(f"exp5.n{n_diffs}.full_reload", t_base, "baseline io"))
        out(row(f"exp5.n{n_diffs}.serial_replay", t_serial,
                f"depth={n_diffs} merges"))
        out(row(f"exp5.n{n_diffs}.parallel_replay", t_par,
                f"depth={depth} (log n) wall={t_serial / t_par:.2f}x "
                f"on 1 core"))
        ld.close()

    # LowDiff+ software recovery (from CPU replica)
    store = fresh_store("/tmp/repro_bench/rec_plus")
    ldp = LowDiffPlus(model, store, persist_interval=1000)
    state = init_state(model, jax.random.PRNGKey(1), mode="lowdiff_plus")
    b = make_batch(model.cfg, SEQ, BATCH)
    for _ in range(5):
        state, _ = ldp.train_step(state, b)
    ldp.flush()
    t_mem = timeit(lambda: ldp.recover_software(state), iters=3)
    out(row("exp5.lowdiff_plus_mem_restore", t_mem, "in-memory"))
    ldp.close()


def _compressed_chain(params, n, rho, rng):
    """n synthetic differentials in wire form with numpy leaves — the
    same shape payloads take after a storage round-trip."""
    import numpy as np

    from repro.compression.sparse import compress_tree
    diffs = []
    for i in range(n):
        grads = jax.tree.map(
            lambda p: rng.standard_normal(p.shape).astype(np.float32), params)
        payload = jax.tree.map(np.asarray, compress_tree(grads, rho))
        diffs.append((i + 1, payload))
    return diffs


def main17(out):
    """Exp. 17: device-resident recovery fast path.

    Replay wall-clock vs chain length (16/64/256), host (dense-decode
    parallel scan) vs device (fused decompress-and-apply scan over the
    compressed wire payloads), each against the memory-bandwidth
    roofline; plus the snapshot stall with vs without overlapped
    per-shard D2H."""
    import numpy as np

    from repro.analysis.roofline import replay_roofline
    from repro.checkpoint.io import COPY_METER
    from repro.compression.sparse import tree_nbytes
    from repro.core import recovery as rec
    from repro.core.snapshot import SnapshotArena, host_copy

    model = bench_model()
    state = init_state(model, jax.random.PRNGKey(0), mode="lowdiff")
    params, opt = state["params"], state["opt"]
    rng = np.random.default_rng(0)
    rho = 0.01
    chain = _compressed_chain(params, 256, rho, rng)
    state_bytes = sum(l.nbytes for l in jax.tree.leaves(params)) + \
        sum(l.nbytes for l in jax.tree.leaves((opt.mu, opt.nu)))
    payload_bytes = tree_nbytes(chain[0][1])
    window = 32

    speedup64 = None
    for n in (16, 64, 256):
        diffs = chain[:n]

        def host():
            p, o, k = rec.replay_parallel(params, opt, diffs,
                                          window=window)
            assert k == n
            jax.block_until_ready(jax.tree.leaves(p))

        def device():
            p, o, k = rec.replay_device(params, opt, diffs, window=window)
            assert k == n
            jax.block_until_ready(jax.tree.leaves(p))

        t_host = timeit(host, warmup=1, iters=3)
        t_dev = timeit(device, warmup=1, iters=3)
        roof = replay_roofline(state_bytes, payload_bytes, n)
        if n == 64:
            speedup64 = t_host / t_dev
        out(row(f"exp17.n{n}.host_replay", t_host,
                f"dense H2D={n * state_bytes // 3} bytes"))
        out(row(f"exp17.n{n}.device_replay", t_dev,
                f"host/device={t_host / t_dev:.2f}x "
                f"roofline={roof['min_seconds'] / t_dev:.1%} "
                f"compressed H2D={n * payload_bytes} bytes"))
    out(row("exp17.speedup64", 0.0,
            f"device_vs_host_64={speedup64:.2f}x"))

    # snapshot stall: blocking whole-tree copy vs overlapped per-shard
    # DMA (training-loop-side time only; materialization is the persist
    # thread's problem)
    t_block = timeit(lambda: host_copy(state), warmup=1, iters=3)
    arena = SnapshotArena(slots=2)
    COPY_METER.reset()
    stalls = []
    for _ in range(4):
        t0 = time.perf_counter()
        ps = arena.snapshot_sharded_async(state, shards=8)
        stalls.append(time.perf_counter() - t0)
        ps.result()
        ps.release()
    t_issue = float(np.median(stalls))
    overlap = COPY_METER.d2h_overlap_ratio()
    out(row("exp17.snapshot.blocking", t_block, "whole-tree host_copy"))
    out(row("exp17.snapshot.sharded_issue", t_issue,
            f"stall_ratio={t_issue / t_block:.3f} "
            f"d2h_overlap={overlap if overlap is None else round(overlap, 3)}"))


if __name__ == "__main__":
    main(print)
    main17(print)
