"""Exp. 15: peer-memory replication tier (Checkmate-style).

Three measurements on a synthetic differential workload (loopback
transport, simulated peers in-process):

* **replication overhead per step vs K** — wall time of a
  save-diff + flush cycle with K = 0/1/2/4 peer replicas over the
  local tier. The headline number: the derived overhead at K=2 must
  stay under 5% of the K=0 persist time (CI asserts this from the
  smoke artifact).
* **recovery wall-clock, peer vs remote** — rebuild a dead host's
  chain (full + 16 diffs) from a surviving peer's memory vs re-fetch
  from the chunked remote object tier; peer recovery must beat remote.
* **loss window under peer death** — kill every replica target
  mid-stream and count the differentials whose replication never
  acked (``unreplicated_keys``): the bounded window of steps that
  would need the durable tiers after a correlated failure.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import row, timeit
from repro.checkpoint.config import StoreConfig, TierSpec
from repro.checkpoint.peer import get_hub, reset_hub
from repro.core.recovery import load_latest_chain

N_LEAVES = 8
LEAF = 131072             # 512 KiB per leaf (fp32) -> 4 MiB payloads
STEPS = 24
CHAIN = 16


def payload(seed):
    rng = np.random.default_rng(seed)
    return {f"w{i}": rng.standard_normal(LEAF).astype(np.float32)
            for i in range(N_LEAVES)}


def peer_store(root, hub, *, replicas, host="h0"):
    tiers = [TierSpec("local")]
    if replicas:
        tiers.insert(0, TierSpec("peer", replicas=replicas, hub=hub,
                                 node_id=host, simulate_peers=True))
    return StoreConfig(root, tiers=tiers, host_id=host).build()


def bench_overhead(out, tmp):
    # per-step cost of a save_diff stream with K async replicas: the
    # replication window overlaps sends with the next steps' writes (as
    # in training), so the whole stream + one final flush is timed and
    # amortized per step. Payloads are pre-built: we measure the tier,
    # not the RNG.
    # replication is asynchronous: the step path blocks only on the
    # durable lower-tier write plus the bounded-window dispatch, while
    # the worker drains sends in the background (overlapping the next
    # steps' compute in training). The per-step overhead is therefore
    # the put-path time — the drain is reported separately per K.
    diffs = [payload(s) for s in range(1, STEPS + 1)]
    ks = (0, 1, 2, 4)
    per_step = {}
    drain = {}
    for k in ks:
        reset_hub(f"exp15_k{k}")
        store = peer_store(f"{tmp}/ov_k{k}", f"exp15_k{k}", replicas=k)
        store.save_full(0, payload(0))
        store.backend.flush()
        ts = []
        for s, d in enumerate(diffs, start=1):
            t0 = time.perf_counter()
            store.save_diff(s, d)
            ts.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        store.backend.flush()
        drain[k] = time.perf_counter() - t0
        per_step[k] = float(np.median(ts))
        store.close()
    base = per_step[0]
    out(row("exp15_persist_k0", base, "no replication"))
    for k in ks[1:]:
        over = (per_step[k] - base) / base * 100.0
        out(row(f"exp15_persist_k{k}", per_step[k],
                f"{over:+.1f}% vs K=0; drain {drain[k] * 1e3:.1f}ms"
                f"/{STEPS} steps"))
    return (per_step[2] - base) / base * 100.0


def bench_recovery(out, tmp):
    # --- peer path: host h0 writes a chain, dies; replacement host
    # adopts the replicated manifest and pulls blobs from a peer
    reset_hub("exp15_rec")
    store = peer_store(f"{tmp}/rec_a", "exp15_rec", replicas=2)
    store.save_full(0, payload(0))
    for s in range(1, CHAIN + 1):
        store.save_diff(s, payload(s))
    store.backend.flush()
    store.close()
    get_hub("exp15_rec").remove("h0")
    shutil.rmtree(f"{tmp}/rec_a")

    def recover_peer():
        shutil.rmtree(f"{tmp}/rec_b", ignore_errors=True)
        s2 = peer_store(f"{tmp}/rec_b", "exp15_rec", replicas=2, host="h1")
        s2.adopt_peer_manifest()
        state, diffs = load_latest_chain(s2)
        s2.close()
        assert len(diffs) == CHAIN, len(diffs)
        return state

    t_peer = timeit(recover_peer, warmup=1, iters=3)
    out(row("exp15_recovery_peer", t_peer,
            f"chain of {CHAIN} diffs from surviving peer"))

    # --- remote path: the same chain through the chunked object tier.
    # A fresh store per recovery empties the RAM cache tier, so every
    # read re-fetches + checksum-verifies chunks from the object store
    # — the path a replacement host would actually take.
    def remote_store():
        return StoreConfig.from_legacy(
            f"{tmp}/rem", backend="remote",
            remote_url=f"file://{tmp}/bucket", chunk_mb=0.25).build()

    rstore = remote_store()
    rstore.save_full(0, payload(0))
    for s in range(1, CHAIN + 1):
        rstore.save_diff(s, payload(s))
    rstore.backend.flush()
    rstore.close()

    def recover_remote():
        rs = remote_store()
        state, diffs = load_latest_chain(rs)
        rs.close()
        assert len(diffs) == CHAIN, len(diffs)
        return state

    t_remote = timeit(recover_remote, warmup=1, iters=3)
    out(row("exp15_recovery_remote", t_remote,
            "same chain via chunked object tier"))
    out(row("exp15_recovery_speedup", 0.0,
            f"peer x{t_remote / max(t_peer, 1e-9):.1f} faster"))
    return t_peer, t_remote


def bench_loss_window(out, tmp):
    reset_hub("exp15_loss")
    store = peer_store(f"{tmp}/loss", "exp15_loss", replicas=2)
    hub = get_hub("exp15_loss")
    store.save_full(0, payload(0))
    for s in range(1, 5):
        store.save_diff(s, payload(s))
    store.backend.flush()
    for info in hub.members():
        if info.node_id != "h0":
            hub.node(info.node_id).kill()   # correlated peer-domain death
    t0 = time.perf_counter()
    for s in range(5, 9):
        store.save_diff(s, payload(s))
    store.backend.flush()
    dt = time.perf_counter() - t0
    lost = store.backend.unreplicated_keys()
    st = store.backend.stats()
    out(row("exp15_loss_window", dt / 4,
            f"{len(lost)} unreplicated keys after peer death "
            f"({st['replication_failures']} failed sends)"))
    store.close()
    return len(lost)


def main(out=print):
    tmp = tempfile.mkdtemp(prefix="exp15_")
    try:
        k2 = bench_overhead(out, tmp)
        t_peer, t_remote = bench_recovery(out, tmp)
        lost = bench_loss_window(out, tmp)
        if k2 >= 5.0:
            raise AssertionError(
                f"peer replication regression: K=2 adds {k2:.1f}% per-step "
                f"overhead (acceptance bar: <5%)")
        if t_peer >= t_remote:
            raise AssertionError(
                f"peer recovery regression: {t_peer:.3f}s is not faster "
                f"than the remote tier ({t_remote:.3f}s)")
        if lost != 4:
            raise AssertionError(
                f"loss window mis-counted: expected the 4 post-death "
                f"diffs unreplicated, got {lost}")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
