"""Exp. 3 (Fig. 13): wasted time under MTBF in {0.5, 1, 2} hours.

Simulator driven by measured iteration/checkpoint costs scaled to the
paper's GPT2-S setting. Paper claims: LowDiff lowest wasted time at every
MTBF; the LowDiff-Gemini gap widens as failures become more frequent;
LowDiff+(S) 3.7-5.1% below LowDiff; LowDiff+(P) slightly above.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core.simulator import paper_profiles, simulate

PROFILES = paper_profiles(iter_time=0.35, full_bytes=1.4e9,
                          diff_bytes=9.2e6, compress_stall=0.08,
                          batch_size=2, full_interval=20)
RUN_ITERS = 100_000


def wasted_h(name, mtbf_h, seeds=5):
    w = [simulate(PROFILES[name], run_iters=RUN_ITERS,
                  mtbf_s=mtbf_h * 3600, seed=s).wasted_time / 3600
         for s in range(seeds)]
    return float(np.mean(w))


def main(out):
    for mtbf in (0.5, 1.0, 2.0):
        vals = {n: wasted_h(n, mtbf) for n in
                ("naive_dc", "checkfreq", "gemini", "lowdiff",
                 "lowdiff_plus_s", "lowdiff_plus_p")}
        order = " ".join(f"{k}={v:.3f}h" for k, v in vals.items())
        out(row(f"exp3.mtbf{mtbf}", 0.0, order))
        assert vals["lowdiff"] <= min(vals["naive_dc"], vals["checkfreq"],
                                      vals["gemini"]) + 1e-9
    g1 = wasted_h("gemini", 2.0) - wasted_h("lowdiff", 2.0)
    g2 = wasted_h("gemini", 0.5) - wasted_h("lowdiff", 0.5)
    out(row("exp3.gap_widens", 0.0,
            f"gap@2h={g1:.3f}h gap@0.5h={g2:.3f}h widening={g2 > g1}"))


if __name__ == "__main__":
    main(print)
