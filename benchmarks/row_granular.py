"""Exp. 16: row-granular differential persistence.

Three measurements on a synthetic MoE-style workload (one big expert
table, ~1% of rows dirty per persist interval — what expert-parallel
routing leaves on each host):

* **bytes written per persist** — leaf-granular dirty tracking (the
  whole table re-persists whenever any row moved) vs row-granular
  spans. The headline number: row granularity must write >= 5x fewer
  bytes/persist at ~1% dirty rows (CI asserts this from the smoke
  artifact; on this workload the real gap is ~2 orders of magnitude).
* **fold cost vs patch count** — ``fold_sync`` wall time over chains
  of 64 / 256 / 1024 single-row patches: the newest-wins span merge
  keeps fold work proportional to *distinct dirty rows*, not to chain
  length times leaf size.
* **adaptive vs fixed fold trigger** — the same bursty workload driven
  once with the fixed ``--fold-interval`` cap alone and once with the
  ``--fold-amplification`` trigger layered on: the adaptive run folds
  when the chain is actually expensive to read, bounding worst-case
  recovery read amplification instead of patch count.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import row, timeit
from repro.checkpoint.config import StoreConfig
from repro.core.lowdiff_plus import _NumpyAdam, fold_due

ROWS = 8192               # expert-table rows
DM = 32                   # 1 MiB fp32 per component (params/mu/nu)
HOT_BLOCKS = 8            # dirty spans per interval...
BLOCK = 10                # ...of this many rows: ~1% of ROWS
PERSISTS = 4


def make_replica(granularity):
    rng = np.random.default_rng(0)
    params = {"table": (0.1 * rng.standard_normal(
        (ROWS, DM))).astype(np.float32)}
    mu = {k: np.zeros_like(v) for k, v in params.items()}
    nu = {k: np.zeros_like(v) for k, v in params.items()}
    return _NumpyAdam(params, mu, nu, 0, lr=1e-3, track_dirty=True,
                      dirty_granularity=granularity)


def sparse_row_grads(rep, seed):
    """~1% of rows nonzero, in HOT_BLOCKS random contiguous blocks."""
    rng = np.random.default_rng(seed)
    g = np.zeros((ROWS, DM), np.float32)
    for start in rng.integers(0, ROWS - BLOCK, HOT_BLOCKS):
        g[start:start + BLOCK] = rng.standard_normal(
            (BLOCK, DM)).astype(np.float32)
    return {"table": g}


def bench_bytes(out, tmp):
    per_mode = {}
    for mode in ("leaf", "row"):
        store = StoreConfig.from_legacy(f"{tmp}/{mode}").build()
        rep = make_replica(mode)
        rep.apply(sparse_row_grads(rep, 0))
        base = store.save_full(1, rep.snapshot_full(), record_names=True)
        base_bytes = store.bytes_written
        t_persist = []
        for step in range(2, PERSISTS + 2):
            rep.apply(sparse_row_grads(rep, step))
            updates, _ = rep.snapshot_dirty()
            t0 = time.perf_counter()
            store.save_patch(step, base, updates)
            t_persist.append(time.perf_counter() - t0)
        per_mode[mode] = (store.bytes_written - base_bytes) / PERSISTS
        out(row(f"exp16_{mode}_persist_bytes", 0.0,
                f"{per_mode[mode] / 1e6:.3f}MB"))
        out(row(f"exp16_{mode}_persist_latency",
                float(np.median(t_persist))))
        # either chain must recover the exact replica bytes
        got, _ = store.load_latest_state()
        np.testing.assert_array_equal(got["params"]["table"],
                                      rep.params["table"])
        store.close()
    ratio = per_mode["leaf"] / max(per_mode["row"], 1.0)
    out(row("exp16_bytes_ratio_leaf_over_row", 0.0, f"x{ratio:.1f}"))
    return ratio


def bench_fold_cost(out, tmp):
    """Fold wall time vs chain length at one dirty row per patch
    (hand-built RowUpdates: a replica's Adam moments keep re-dirtying
    every touched row, which measures the optimizer, not the fold)."""
    from repro.checkpoint.patchset import Span, row_update_from_spans
    rng = np.random.default_rng(1)
    for n_patches in (64, 256, 1024):
        store = StoreConfig.from_legacy(f"{tmp}/fold_{n_patches}").build()
        rep = make_replica("row")
        base = store.save_full(1, rep.snapshot_full(), record_names=True)
        for step in range(2, n_patches + 2):
            r = int(rng.integers(0, ROWS))
            data = rng.standard_normal((1, DM)).astype(np.float32)
            upd = {"params": {"table": row_update_from_spans(
                       [Span(r, data)], (ROWS, DM))},
                   "count": np.array(step, np.int64)}
            store.save_patch(step, base, upd)
        t0 = time.perf_counter()
        folded = store.fold_sync(merge_slice=8)
        t = time.perf_counter() - t0
        assert folded == n_patches
        out(row(f"exp16_fold_patches_{n_patches:04d}", t))
        store.close()


def bench_adaptive_trigger(out, tmp):
    """Bursty chain growth under the fixed patch-count cap alone vs
    with the amplification trigger layered on: the adaptive policy
    bounds how expensive the chain is allowed to get to read."""
    policies = {"fixed": 0.0, "adaptive": 1.5}
    for name, fold_amp in policies.items():
        store = StoreConfig.from_legacy(f"{tmp}/trig_{name}").build()
        rep = make_replica("row")
        rep.apply(sparse_row_grads(rep, 0))
        base = store.save_full(1, rep.snapshot_full(), record_names=True)
        rng = np.random.default_rng(2)
        folds, since, worst_amp = 0, 0, 0.0
        for step in range(2, 34):
            # bursty: every 4th interval dirties 30% of the table
            if step % 4 == 0:
                g = {"table": rng.standard_normal(
                    (ROWS, DM)).astype(np.float32)
                    * (rng.random((ROWS, 1)) < 0.3)}
            else:
                g = sparse_row_grads(rep, step)
            rep.apply(g)
            updates, _ = rep.snapshot_dirty()
            store.save_patch(step, base, updates)
            since += 1
            amp = store.chain_amplification()
            worst_amp = max(worst_amp, amp)
            if fold_due(since, 16, amp, fold_amp):
                store.fold_sync(merge_slice=8)
                base = store._entry_key(store.latest_full())
                folds, since = folds + 1, 0
        out(row(f"exp16_{name}_trigger", 0.0,
                f"{folds} folds max_amp x{worst_amp:.2f}"))
        store.close()


def bench_recovery(out, tmp):
    store = StoreConfig.from_legacy(f"{tmp}/rec").build()
    rep = make_replica("row")
    rep.apply(sparse_row_grads(rep, 0))
    base = store.save_full(1, rep.snapshot_full(), record_names=True)
    for step in range(2, 18):
        rep.apply(sparse_row_grads(rep, step))
        updates, _ = rep.snapshot_dirty()
        store.save_patch(step, base, updates)
    t = timeit(lambda: store.load_latest_state(), warmup=1, iters=3)
    out(row("exp16_recovery_row_chain_16", t))
    store.close()


def main(out=print):
    tmp = tempfile.mkdtemp(prefix="exp16_")
    try:
        ratio = bench_bytes(out, tmp)
        bench_fold_cost(out, tmp)
        bench_adaptive_trigger(out, tmp)
        bench_recovery(out, tmp)
        if ratio < 5.0:
            raise AssertionError(
                f"row-granular persist regression: only {ratio:.1f}x fewer "
                f"bytes than leaf granularity at ~1% dirty rows "
                f"(acceptance bar: 5x)")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
