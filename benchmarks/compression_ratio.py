"""Exp. 8 (Fig. 17): impact of compression ratio rho on checkpoint
frequency.

For rho in [0.001, 0.1]: measure compressed-gradient bytes, derive the
write time on a 5 GB/s NVMe and the smallest per-checkpoint interval that
still overlaps with one training iteration (the paper's criterion).
Paper claims: per-iteration everywhere for GPT2-S; GPT2-L needs 2
iterations only at rho=0.1.
"""
from __future__ import annotations

import math

import jax

from benchmarks.common import BATCH, SEQ, bench_model, row
from repro.compression.sparse import compress_tree, dense_nbytes, tree_nbytes
from repro.core.steps import init_state

NVME_BW = 5e9
# paper-scale projection: GPT2-S (117M) iter 0.35s, GPT2-L (762M) iter 0.9s
PAPER = {"GPT2-S": (117e6, 0.35), "GPT2-L": (762e6, 0.9)}


def main(out):
    model = bench_model()
    state = init_state(model, jax.random.PRNGKey(0), mode="dense")
    grads = state["params"]   # same shapes as a gradient pytree
    dense_b = dense_nbytes(grads)
    for rho in (0.001, 0.01, 0.05, 0.075, 0.1):
        cg = jax.jit(lambda g: compress_tree(g, rho))(grads)
        b = tree_nbytes(cg)
        out(row(f"exp8.measured.rho{rho}", 0.0,
                f"{b / 2**20:.2f}MiB ({b / dense_b * 100:.2f}% of dense)"))
        for name, (P, iter_s) in PAPER.items():
            cbytes = rho * P * 4 * 1.5
            interval = max(1, math.ceil(cbytes / NVME_BW / iter_s))
            out(row(f"exp8.paper.{name}.rho{rho}", 0.0,
                    f"interval={interval}"))


if __name__ == "__main__":
    main(print)
