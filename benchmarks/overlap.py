"""Fig. 4: iteration vs full-checkpoint vs differential-checkpoint time.

Paper claim: DC (compressed-gradient) time is 20.5-24.6% of iteration
time across BERT-B/L, GPT2-S/L — i.e. checkpointing fully overlaps with
training. We measure the same three quantities for scaled model variants.
"""
from __future__ import annotations

import jax

from benchmarks.common import BATCH, SEQ, bench_model, fresh_store, row, timeit
from repro.compression.sparse import compress_tree, tree_nbytes, dense_nbytes
from repro.core.lowdiff import host_copy
from repro.core.steps import init_state, make_train_step
from repro.data.synthetic import make_batch

VARIANTS = {
    "gpt2s_like": dict(n_layers=2, d_model=192),
    "gpt2l_like": dict(n_layers=2, d_model=256),
    "bertl_like": dict(n_layers=4, d_model=256),
}


def main(out):
    store = fresh_store("/tmp/repro_bench/overlap")
    for name, ov in VARIANTS.items():
        model = bench_model(**ov)
        step = make_train_step(model, mode="lowdiff", rho=0.01)
        state = init_state(model, jax.random.PRNGKey(0), mode="lowdiff")
        b = make_batch(model.cfg, SEQ, BATCH)
        state, _, cg = step(state, b)

        def iter_fn():
            s2, _, c2 = step(state, b)
            jax.block_until_ready(s2["params"])

        t_iter = timeit(iter_fn)
        payload = host_copy(cg)
        t_dc = timeit(lambda: store.save_diff(0, payload), iters=3)
        snap = host_copy(state)
        t_full = timeit(lambda: store.save_full(0, snap), iters=3)
        out(row(f"fig4.{name}.iter", t_iter, ""))
        out(row(f"fig4.{name}.full_ckpt", t_full,
                f"ratio={t_full / t_iter * 100:.0f}%"))
        out(row(f"fig4.{name}.diff_ckpt", t_dc,
                f"ratio={t_dc / t_iter * 100:.0f}%"))


if __name__ == "__main__":
    main(print)
