"""Exp. 13: checkpoint maintenance service cost model.

Four measurements:

* **GC slice throughput** — keys swept per second through the journaled
  mark/sweep path (plan + bounded slices + cursor records), vs the
  synchronous `CheckpointStore.gc` baseline.
* **scrub throughput** — MB/s of cold-blob bytes re-verified (frame
  leaf sha256 recomputation through ``StorageBackend.verify``).
* **step-time jitter, maintenance on vs off** — a LowDiff training loop
  with retention GC + periodic scrubbing running concurrently on the
  maintenance worker; the acceptance bar is p99 step time within 5% of
  the maintenance-off run (the whole point of moving sweep I/O off the
  step loop).
* **journal-segment merge cost vs host count** — deterministic merge
  of N per-host segments carrying the same total record count.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import row
from repro.checkpoint.config import StoreConfig
from repro.checkpoint.journal import SegmentedManifestJournal
from repro.maintenance import MaintenanceService

PAY_KB = 64
FULLS = 12
DIFFS_PER = 8


def _pay(s, kb=PAY_KB):
    return {"g": np.full(kb * 256, float(s), np.float32)}


def _build_chain(store, fulls=FULLS, diffs_per=DIFFS_PER):
    step = 0
    for _ in range(fulls):
        for _ in range(diffs_per):
            step += 1
            store.save_diff(step, _pay(step))
        step += 1
        store.save_full(step, {"params": _pay(step),
                               "step": np.int32(step)})
    return step


def bench_gc(out, tmp):
    for mode in ("sync", "service"):
        store = StoreConfig.from_legacy(f"{tmp}/gc_{mode}").build()
        _build_chain(store)
        doomed = len(store.gc_plan(retention_fulls=1))
        t0 = time.perf_counter()
        if mode == "sync":
            store.gc(retention_fulls=1)
        else:
            svc = MaintenanceService(store, gc_slice=16)
            store.attach_maintenance(svc)
            svc.start()
            svc.request_gc(1)
            svc.drain(60.0)
        dt = time.perf_counter() - t0
        out(row(f"exp13.gc.{mode}", dt / max(doomed, 1),
                f"{doomed / dt:.0f}keys/s ({doomed} swept)"))
        store.close()


def bench_scrub(out, tmp):
    store = StoreConfig.from_legacy(f"{tmp}/scrub").build()
    _build_chain(store, fulls=4)
    nbytes = sum(e["bytes"] for kind in ("fulls", "diffs")
                 for e in store.manifest[kind])
    svc = MaintenanceService(store, scrub_slice=16)
    store.attach_maintenance(svc)
    svc.start()
    t0 = time.perf_counter()
    svc.request_scrub()
    svc.drain(120.0)
    dt = time.perf_counter() - t0
    out(row("exp13.scrub", dt / max(svc.scrubbed, 1),
            f"{nbytes / 2**20 / dt:.0f}MB/s ({svc.scrubbed} blobs)"))
    store.close()


def bench_jitter(out, tmp):
    """p99 step time with background maintenance on vs off — the
    acceptance bar is within 5%."""
    import jax
    from repro.configs import get_config
    from repro.core.lowdiff import LowDiff
    from repro.core.steps import init_state
    from repro.data.synthetic import make_batch
    from repro.models.registry import build_model

    model = build_model(get_config("qwen2-1.5b").reduced())
    p99 = {}
    # "on" runs FIRST: process-level warmup (jax init, first traces)
    # lands on the maintenance-enabled leg, so the reported ratio is a
    # conservative upper bound on maintenance-induced jitter
    for mode in ("on", "off"):
        store = StoreConfig.from_legacy(f"{tmp}/jit_{mode}", retention_fulls=1).build()
        if mode == "on":
            svc = MaintenanceService(store, gc_slice=8,
                                     scrub_interval=0.05)
            store.attach_maintenance(svc)
            svc.start()
        ld = LowDiff(model, store, rho=0.05, full_interval=4, batch_size=2)
        state = init_state(model, jax.random.PRNGKey(0), mode="lowdiff")
        times = []
        for t in range(24):
            b = make_batch(model.cfg, 32, 2, step=t)
            t0 = time.perf_counter()
            state, _ = ld.train_step(state, b)
            jax.block_until_ready(state["params"])
            times.append(time.perf_counter() - t0)
        ld.close()
        p99[mode] = float(np.percentile(times[4:], 99))
        out(row(f"exp13.step_p99.maintenance_{mode}", p99[mode]))
    out(row("exp13.step_p99.ratio", 0.0,
            f"on/off={p99['on'] / p99['off']:.3f} (bar: <=1.05)"))


def bench_merge(out, tmp):
    records_total = 512
    for hosts in (1, 2, 4, 8):
        root = f"{tmp}/merge_{hosts}"
        journals = [SegmentedManifestJournal(root, host=f"h{i}",
                                             compact_every=10**6)
                    for i in range(hosts)]
        for s in range(records_total):
            journals[s % hosts].append(
                "add", "diffs", entry={"step": s, "key": f"diff_{s:08d}",
                                       "bytes": 1})
        t0 = time.perf_counter()
        journals[0].compact()
        dt = time.perf_counter() - t0
        for j in journals:
            j.close()
        out(row(f"exp13.merge.hosts_{hosts}", dt,
                f"{records_total / dt / 1e3:.0f}krec/s"))


def main(out):
    tmp = tempfile.mkdtemp(prefix="exp13_")
    try:
        bench_gc(out, tmp)
        bench_scrub(out, tmp)
        bench_merge(out, tmp)
        bench_jitter(out, tmp)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main(print)
