"""Exp. 12: zero-copy serialization fast path (frame vs npz).

Meters the three quantities the zero-copy work targets:

* **serialize / deserialize throughput** — LocalFS backend writes and
  reads of a multi-MB pytree in each format (frame streams leaf
  buffers via memoryview; npz re-encodes through a zip container).
* **host-side copies of tensor bytes per checkpoint** — via the copy
  meter, on the remote path where the seed made 3 (D2H snapshot + npz
  blob materialization + chunk re-slice) and the frame path makes 1
  (the D2H snapshot only; chunks are views of the snapshot buffers).
* **snapshot stall** — time the training thread spends starting a full
  state snapshot: the seed's synchronous per-leaf ``np.asarray`` walk
  vs the arena's ``copy_to_host_async`` + deferred materialization.
"""
from __future__ import annotations

import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.checkpoint import io as cio
from repro.checkpoint.backends import LocalFSBackend
from repro.checkpoint.remote import FakeObjectStore, RemoteObjectBackend
from repro.core.snapshot import SnapshotArena, host_copy

TREE_MB = 32


def _host_tree(mb: float, leaves: int = 8, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = int(mb * 2**20 / 4 / leaves)
    return {f"w{i}": rng.normal(size=(n,)).astype(np.float32)
            for i in range(leaves)}


def _device_tree(mb: float, leaves: int = 8, seed: int = 0):
    return {k: jnp.asarray(v)
            for k, v in _host_tree(mb, leaves, seed).items()}


def main(out):
    tree = _host_tree(TREE_MB)
    nbytes = sum(a.nbytes for a in tree.values())

    # ---------------- local serialize / deserialize -------------------
    tmp = tempfile.mkdtemp(prefix="exp12_")
    try:
        for fmt in ("npz", "frame"):
            be = LocalFSBackend(f"{tmp}/{fmt}", fmt=fmt)
            t_put = timeit(lambda b=be: b.put("k", tree), warmup=1, iters=3)
            out(row(f"exp12.serialize.{fmt}", t_put,
                    f"{nbytes / 2**20 / t_put:.0f}MB/s"))
            # full materialization (touch every leaf)
            t_get = timeit(
                lambda b=be: jax.tree.map(np.sum, b.get("k")),
                warmup=1, iters=3)
            out(row(f"exp12.deserialize.{fmt}", t_get,
                    f"{nbytes / 2**20 / t_get:.0f}MB/s"))
        # lazy one-leaf read: the memmap advantage replay relies on
        fbe = LocalFSBackend(f"{tmp}/frame", fmt="frame")
        t_lazy = timeit(lambda: np.sum(fbe.get("k")["w0"]),
                        warmup=1, iters=3)
        out(row("exp12.deserialize.frame.one_leaf", t_lazy,
                f"touches 1/8 of {TREE_MB}MB"))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # ---------------- remote put (the byte-blob transport) ------------
    for fmt in ("npz", "frame"):
        be = RemoteObjectBackend(FakeObjectStore(), chunk_bytes=4 << 20,
                                 fmt=fmt)
        i = [0]

        def rput(b=be, i=i):
            b.put(f"k{i[0]}", tree)
            i[0] += 1

        t_put = timeit(rput, warmup=1, iters=3)
        out(row(f"exp12.remote_put.{fmt}", t_put,
                f"{nbytes / 2**20 / t_put:.0f}MB/s"))
    cio.COPY_METER.reset()

    # ---------------- copies per checkpoint (remote path) -------------
    dtree = _device_tree(TREE_MB)
    for fmt in ("npz", "frame"):
        be = RemoteObjectBackend(FakeObjectStore(), chunk_bytes=4 << 20,
                                 fmt=fmt)
        cio.COPY_METER.reset()
        snap = host_copy(dtree)        # copy 1: the D2H snapshot
        be.put("k", snap)
        copies = cio.COPY_METER.bytes / nbytes
        cio.COPY_METER.reset()
        out(row(f"exp12.copies.{fmt}", 0.0,
                f"{copies:.2f} host copies of tensor bytes/ckpt"))

    # ---------------- snapshot stall on the training thread -----------
    def sync_snap():
        host_copy(dtree)

    t_sync = timeit(sync_snap, warmup=1, iters=5)
    out(row("exp12.snapshot.sync", t_sync,
            f"{nbytes / 2**20 / t_sync:.0f}MB/s blocking"))

    arena = SnapshotArena(slots=2)

    def async_start():
        # what train_step pays: issue the transfers, hand off, return
        p = arena.snapshot_async(dtree)
        p.release()                    # persist thread's work, not timed

    t_async = timeit(async_start, warmup=1, iters=5)
    out(row("exp12.snapshot.async_start", t_async,
            f"stall {t_async / max(t_sync, 1e-12) * 100:.1f}% of sync"))
    # and the deferred wait really produces the same bytes
    pending = arena.snapshot_async(dtree)
    snap = pending.result()
    assert all(np.array_equal(np.asarray(dtree[k]), snap[k]) for k in snap)
    pending.release()
    cio.COPY_METER.reset()


if __name__ == "__main__":
    main(print)
