"""Exp. 6 (Fig. 16): batched-write speedup + device-memory effect of
offloaded batching.

Paper claims: batching reduces average differential write time by up to
30.9% (BS=20); offloading the batch buffer to CPU returns device memory
to the no-checkpoint level.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import BATCH, SEQ, bench_model, fresh_store, row
from repro.compression.sparse import tree_nbytes
from repro.core.lowdiff import host_copy
from repro.core.steps import init_state, make_train_step
from repro.data.synthetic import make_batch


def main(out):
    model = bench_model()
    step = make_train_step(model, mode="lowdiff", rho=0.01)
    state = init_state(model, jax.random.PRNGKey(0), mode="lowdiff")
    b = make_batch(model.cfg, SEQ, BATCH)
    state, _, cg = step(state, b)
    payload = host_copy(cg)

    base = None
    for bs in (1, 2, 5, 10, 20):
        store = fresh_store(f"/tmp/repro_bench/bw{bs}")
        n_total = 20
        t0 = time.perf_counter()
        i = 0
        while i < n_total:
            batch = [payload] * min(bs, n_total - i)
            store.save_batch(i, i + len(batch) - 1, batch)
            i += len(batch)
        per_diff = (time.perf_counter() - t0) / n_total
        if base is None:
            base = per_diff
        out(row(f"exp6.write_bs{bs}", per_diff,
                f"reduction={(1 - per_diff / base) * 100:.1f}%"))

    # device-memory effect of offloading: bytes held on device if the
    # batch buffer lived there vs on host (it is on host by design)
    per = tree_nbytes(cg)
    out(row("exp6.device_bytes_no_offload", 0.0,
            f"{per * 20 / 2**20:.1f}MiB held for BS=20"))
    out(row("exp6.device_bytes_offloaded", 0.0, "0MiB (buffer in host DRAM)"))


if __name__ == "__main__":
    main(print)
