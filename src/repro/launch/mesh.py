"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model); the pod
axis joins data-parallelism (batch and FSDP shard over ('pod','data')),
so cross-pod traffic is gradient reduction only — the right placement for
the slow inter-pod links.

Functions, not module-level constants: importing this module must not
touch jax device state (smoke tests see 1 CPU device; only dryrun.py sets
XLA_FLAGS to fake 512 hosts).
"""
from __future__ import annotations

import jax


def _axis_type_kw(n: int) -> dict:
    """jax >= 0.5 takes axis_types in make_mesh; older releases don't
    have jax.sharding.AxisType at all (Auto is then the only behavior)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kw(len(axes)))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist."""
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_type_kw(2))


# Hardware constants (TPU v5e) used by the roofline analysis.
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
