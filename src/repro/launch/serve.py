"""Batched-request serving driver (decode loop with KV cache).

Serves a model with a batch of concurrent requests: one prefill-free
warm start (zero cache) or a short prompt prefill via repeated decode,
then autoregressive decoding, reporting tokens/s.

Example::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --batch 8 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import make_batch
from repro.models.registry import build_model
from repro.obs.log import configure as configure_logging, get_logger

log = get_logger("serve")


def load_params(model, ckpt_dir):
    """Newest persisted params from a checkpoint directory, or None
    when the store holds no loadable full. The store is declared with
    the default single-tier StoreConfig — serving only reads."""
    from repro.checkpoint.config import StoreConfig
    store = StoreConfig(root=ckpt_dir).build()
    try:
        state, step = store.load_latest_state()
    except FileNotFoundError:
        return None
    finally:
        store.close()
    params = state.get("params", state) if isinstance(state, dict) else state
    log.info(f"loaded checkpoint step {step} from {ckpt_dir}")
    return jax.tree.map(jnp.asarray, params)


def run(args):
    configure_logging(getattr(args, "log_level", "info"))
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = None
    if getattr(args, "ckpt_dir", None):
        params = load_params(model, args.ckpt_dir)
        if params is None:
            log.info(f"no loadable checkpoint in {args.ckpt_dir}; "
                     f"using random init")
    if params is None:
        params = model.init(jax.random.PRNGKey(0))
    total = args.prompt_len + args.gen
    cache = model.init_cache(args.batch, total)
    step = jax.jit(lambda p, c, b: model.decode_step(p, c, b, total))

    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))
    tokens = jnp.asarray(prompt[:, :1], jnp.int32)
    out_tokens = []

    t0 = time.perf_counter()
    for pos in range(total - 1):
        batch = {"tokens": tokens, "pos": jnp.asarray(pos, jnp.int32)}
        logits, cache = step(params, cache, batch)
        if pos + 1 < args.prompt_len:
            tokens = jnp.asarray(prompt[:, pos + 1:pos + 2], jnp.int32)
        else:
            tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            out_tokens.append(np.asarray(tokens[:, 0]))
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    n_generated = len(out_tokens) * args.batch
    log.info(f"arch={cfg.name} batch={args.batch} "
             f"prompt={args.prompt_len} generated={len(out_tokens)}/req")
    log.info(f"{n_generated} tokens in {dt:.2f}s -> "
             f"{n_generated / dt:.1f} tok/s (batch-aggregate)")
    log.info(f"sample continuation (req 0): "
             f"{[int(t[0]) for t in out_tokens[:10]]}")
    return out_tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None,
                    help="load the newest persisted params from this "
                         "checkpoint store (random init when absent)")
    ap.add_argument("--log-level", default="info",
                    choices=("debug", "info", "warning", "error"))
    run(ap.parse_args())


if __name__ == "__main__":
    main()
