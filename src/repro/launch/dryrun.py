"""Multi-pod dry-run driver.

Lowers + compiles every (architecture x input shape) combination on the
production meshes — (16,16) single pod and (2,16,16) two pods — and
records memory analysis, cost analysis and collective statistics.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both
"""
# The two lines below MUST run before any other import (jax locks the
# device count on first backend initialization).
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse
import json
import time
import traceback

import jax

from repro.analysis.hlo_stats import collective_bytes
from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.distributed import sharding as shd
from repro.distributed.step_builder import (make_sharded_serve_step,
                                            make_sharded_train_step,
                                            make_sharded_prefill_step)
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build_model


def lower_combo(arch: str, shape_id: str, *, multi_pod: bool = False,
                train_mode: str = "lowdiff_sharded",
                rules: dict = None, keep_text: bool = False) -> dict:
    """Lower + compile one combination; returns the §Dry-run record."""
    cfg = get_config(arch)
    model = build_model(cfg)
    shape = INPUT_SHAPES[shape_id]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if rules is None:
        rules = cfg.rules(shape.kind)
    rec = {"arch": arch, "shape": shape_id,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "mode": shape.kind, "status": "ok"}
    t0 = time.time()
    with shd.use_mesh(mesh, rules):
        if shape.kind == "decode":
            step, aps, acache, ab = make_sharded_serve_step(model, shape)
            lowered = step.lower(aps, acache, ab)
            rec["step_kind"] = "serve_step"
        elif shape.kind == "prefill":
            step, aps, ab = make_sharded_prefill_step(model, shape)
            lowered = step.lower(aps, ab)
            rec["step_kind"] = "prefill_step"
        else:
            step, ast, ab = make_sharded_train_step(model, shape,
                                                    mode=train_mode)
            lowered = step.lower(ast, ab)
            rec["step_kind"] = f"train_step[{train_mode}]"
        rec["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

        m = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(m.argument_size_in_bytes),
            "output_bytes": int(m.output_size_in_bytes),
            "temp_bytes": int(m.temp_size_in_bytes),
            "alias_bytes": int(m.alias_size_in_bytes),
            "peak_bytes_est": int(m.argument_size_in_bytes
                                  + m.output_size_in_bytes
                                  + m.temp_size_in_bytes
                                  - m.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
        text = compiled.as_text()
        rec["collectives"] = collective_bytes(text)
        rec["n_devices"] = mesh.devices.size
        if keep_text:
            rec["hlo_text"] = text
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape id (default: all)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--train-mode", default="lowdiff_sharded",
                    choices=["dense", "lowdiff_sharded"])
    ap.add_argument("--out", default=None, help="incremental JSON output")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.multi_pod]

    results = []
    if args.out and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") == "ok"}

    for mp in pods:
        mesh_name = "2x16x16" if mp else "16x16"
        for arch in archs:
            for shape_id in shapes:
                if (arch, shape_id, mesh_name) in done:
                    continue
                try:
                    rec = lower_combo(arch, shape_id, multi_pod=mp,
                                      train_mode=args.train_mode)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape_id,
                           "mesh": mesh_name, "status": "fail",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc(limit=6)}
                tag = ("OK " if rec["status"] == "ok" else "FAIL")
                print(f"[{tag}] {mesh_name:8s} {arch:24s} {shape_id:12s} "
                      + (f"compile={rec.get('compile_s')}s "
                         f"peak={rec['memory']['peak_bytes_est'] / 2**30:.1f}GiB"
                         if rec["status"] == "ok" else rec["error"]),
                      flush=True)
                results.append(rec)
                if args.out:
                    os.makedirs(os.path.dirname(args.out) or ".",
                                exist_ok=True)
                    json.dump(results, open(args.out, "w"), indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    print(f"\n{n_ok}/{len(results)} combinations lowered+compiled")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
