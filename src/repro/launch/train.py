"""End-to-end training driver with pluggable checkpointing strategies.

Runs a real training loop (synthetic data, native Adam) with LowDiff /
LowDiff+ / baselines attached, reports per-strategy overhead vs the
no-checkpoint bound, and supports failure injection + recovery.

Examples::

    PYTHONPATH=src python -m repro.launch.train --arch gpt2-l --reduced \
        --steps 50 --strategy lowdiff --ckpt-dir /tmp/ck
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
        --steps 30 --strategy lowdiff_plus --fail-at 20
"""
from __future__ import annotations

import argparse
import shutil
import time

import jax
import numpy as np

from repro.checkpoint import BACKENDS, FORMATS, make_store
from repro.maintenance import MaintenanceService
from repro.configs import get_config
from repro.core.baselines import CheckFreq, FullSync, Gemini, NaiveDC
from repro.core.config_opt import SystemParams
from repro.core.lowdiff import LowDiff
from repro.core.lowdiff_plus import LowDiffPlus
from repro.core.steps import init_state, make_train_step
from repro.data.synthetic import TokenStream
from repro.models.registry import build_model

STRATEGIES = ("none", "lowdiff", "lowdiff_plus", "checkfreq", "gemini",
              "naive_dc", "full_sync")


def build_strategy(name: str, model, store, *, lr, rho, full_interval,
                   batch_size, compressor="topk", persist_mode="full",
                   persist_threshold=0.0, fold_interval=16,
                   replay_window=None):
    if name == "lowdiff":
        # 0 = auto: seed (f, b) from the Eq. (10) closed form and keep
        # adapting them from observed merge times (online tuning)
        return LowDiff(model, store, rho=rho, lr=lr,
                       full_interval=full_interval or None,
                       batch_size=batch_size or None,
                       compressor=compressor,
                       sys_params=SystemParams(),
                       replay_window=replay_window)
    if name == "lowdiff_plus":
        return LowDiffPlus(model, store, lr=lr,
                           persist_interval=batch_size or 1,
                           persist_mode=persist_mode,
                           persist_threshold=persist_threshold,
                           fold_interval=fold_interval)
    if name == "checkfreq":
        return CheckFreq(model, store, lr=lr, interval=10)
    if name == "gemini":
        return Gemini(model, store, lr=lr, interval=1,
                      persist_interval=full_interval)
    if name == "naive_dc":
        return NaiveDC(model, store, lr=lr, rho=rho,
                       full_interval=full_interval)
    if name == "full_sync":
        return FullSync(model, store, lr=lr, interval=full_interval)
    return None


def run(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    print(f"arch={cfg.name} params={model.n_params() / 1e6:.1f}M "
          f"strategy={args.strategy}")
    if args.clean and args.ckpt_dir:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    store = (make_store(args.ckpt_dir,
                        backend=getattr(args, "backend", "local"),
                        shards=getattr(args, "shards", 4),
                        capacity_mb=getattr(args, "memory_capacity_mb", None),
                        retention_fulls=getattr(args, "retention", 0),
                        remote_url=getattr(args, "remote_url", None),
                        chunk_mb=getattr(args, "chunk_mb", 4.0),
                        max_retries=getattr(args, "max_retries", 4),
                        remote_fault_rate=getattr(args, "remote_fault_rate",
                                                  0.0),
                        fmt=getattr(args, "format", "frame"),
                        eviction=getattr(args, "eviction", "fifo"),
                        host_id=getattr(args, "host_id", None))
             if args.ckpt_dir else None)
    if store is not None and getattr(args, "maintenance", "off") == "on":
        # background maintenance: retention GC sweeps in journaled
        # slices off the step loop, the scrubber re-verifies cold blobs
        # periodically, and an unfinished task from a previous crash is
        # resumed before new work. store.close() stops the worker.
        svc = MaintenanceService(
            store, gc_slice=getattr(args, "gc_slice", 64),
            merge_slice=getattr(args, "merge_slice", 64),
            scrub_interval=getattr(args, "scrub_interval", 0.0))
        store.attach_maintenance(svc)
        svc.start()
    strat = (build_strategy(args.strategy, model, store, lr=args.lr,
                            rho=args.rho, full_interval=args.full_interval,
                            batch_size=args.batch_size,
                            compressor=getattr(args, "compressor", "topk"),
                            persist_mode=getattr(args, "persist_mode",
                                                 "full"),
                            persist_threshold=getattr(
                                args, "persist_threshold", 0.0),
                            fold_interval=getattr(args, "fold_interval", 16),
                            replay_window=getattr(args, "replay_window",
                                                  0) or None)
             if args.strategy != "none" else None)
    mode = ("lowdiff" if args.strategy == "lowdiff" else
            "lowdiff_plus" if args.strategy == "lowdiff_plus" else "dense")
    state = init_state(model, jax.random.PRNGKey(args.seed), mode=mode)
    plain_step = make_train_step(model, mode=mode, lr=args.lr, rho=args.rho)
    stream = TokenStream(cfg, args.seq, args.batch, seed=args.seed)

    losses, times = [], []
    t_start = time.perf_counter()
    for t in range(args.steps):
        batch = next(stream)
        t0 = time.perf_counter()
        if strat is not None:
            state, metrics = strat.train_step(state, batch)
        else:
            state, metrics, _ = plain_step(state, batch)
        jax.block_until_ready(state["params"])
        times.append(time.perf_counter() - t0)
        losses.append(float(metrics["loss"]))
        if args.log_every and (t + 1) % args.log_every == 0:
            print(f"step {t + 1:5d} loss={losses[-1]:.4f} "
                  f"it={np.mean(times[-args.log_every:]) * 1e3:.1f}ms")
        if args.fail_at and t + 1 == args.fail_at:
            print(f"\n*** injected failure at step {t + 1} ***")
            assert strat is not None, "--fail-at needs a strategy"
            strat.flush()
            if args.strategy == "lowdiff_plus":
                state = strat.recover_software(state)
            else:
                state, n = strat.recover()
            print(f"recovered at step {int(state['step'])}; resuming\n")
            stream.step = int(state["step"])

    wall = time.perf_counter() - t_start
    if strat is not None:
        strat.close()
    elif store is not None:
        store.close()
    print(f"\n{args.steps} steps in {wall:.1f}s "
          f"(mean iter {np.mean(times) * 1e3:.1f}ms, "
          f"p50 {np.percentile(times, 50) * 1e3:.1f}ms)")
    print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    if strat is not None:
        print("strategy stats:", strat.stats())
    return losses, times


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-l")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--rho", type=float, default=0.01)
    ap.add_argument("--strategy", choices=STRATEGIES, default="lowdiff")
    ap.add_argument("--full-interval", type=int, default=20,
                    help="full-checkpoint interval f (0 = Eq. (10) optimum "
                         "+ online tuning)")
    ap.add_argument("--batch-size", type=int, default=2,
                    help="differential batching size b (0 = Eq. (10) "
                         "optimum + online tuning)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--backend", choices=BACKENDS, default="local",
                    help="checkpoint storage backend (local FS, CPU-memory "
                         "tier with async spill, or sharded concurrent)")
    ap.add_argument("--format", choices=FORMATS, default="frame",
                    help="checkpoint serialization: 'frame' (streamed "
                         "zero-copy, memmap reads) or 'npz' (legacy); "
                         "reads sniff, so old chains recover either way")
    ap.add_argument("--compressor", choices=("topk", "quant8", "packed"),
                    default="topk",
                    help="lowdiff gradient compression: topk sparsification, "
                         "quant8 blockwise int8, or packed (fused top-k + "
                         "int8 + wire pack in one Pallas kernel)")
    ap.add_argument("--shards", type=int, default=4,
                    help="shard count for --backend sharded")
    ap.add_argument("--memory-capacity-mb", type=float, default=None,
                    help="RAM-tier byte budget for --backend memory/remote")
    ap.add_argument("--remote-url", default=None,
                    help="object store for --backend remote: fake://bucket "
                         "(in-process) or file:///path (directory-backed); "
                         "default file://<ckpt-dir>")
    ap.add_argument("--chunk-mb", type=float, default=4.0,
                    help="remote-tier content chunk size in MiB")
    ap.add_argument("--max-retries", type=int, default=4,
                    help="bounded retries per remote chunk transfer")
    ap.add_argument("--remote-fault-rate", type=float, default=0.0,
                    help="injected transient-fault probability on fake:// "
                         "stores (exercises retry/backoff)")
    ap.add_argument("--retention", type=int, default=0,
                    help="keep this many full checkpoints + their chains "
                         "(0 = never garbage-collect)")
    ap.add_argument("--eviction", choices=("fifo", "lru"), default="fifo",
                    help="memory-tier eviction policy over size-class "
                         "buckets; lru refreshes recency on recovery reads")
    ap.add_argument("--maintenance", choices=("on", "off"), default="off",
                    help="background maintenance service: journaled "
                         "resumable GC + integrity scrub off the step "
                         "loop (off = synchronous GC fallback)")
    ap.add_argument("--persist-mode", choices=("full", "incremental"),
                    default="full",
                    help="lowdiff_plus persistence: 'full' rewrites the "
                         "whole replica every persist; 'incremental' "
                         "writes only the leaves that changed since the "
                         "last persist as a patch chain on a base full, "
                         "folded back in the background (requires "
                         "--format frame)")
    ap.add_argument("--persist-threshold", type=float, default=0.0,
                    help="incremental persist filter: defer re-persisting "
                         "a dirty leaf until its accumulated relative "
                         "L-inf change exceeds this (0 = exact: persist "
                         "every changed leaf)")
    ap.add_argument("--fold-interval", type=int, default=16,
                    help="fold the patch chain into its base frame after "
                         "this many incremental persists (0 = never)")
    ap.add_argument("--merge-slice", type=int, default=64,
                    help="leaves patched per journaled fold slice "
                         "(bounded work between progress records)")
    ap.add_argument("--replay-window", type=int, default=0,
                    help="differentials per parallel-replay scan window; "
                         "bounds peak recovery memory to O(window * "
                         "model) (0 = one window)")
    ap.add_argument("--gc-slice", type=int, default=64,
                    help="keys swept per journaled GC slice (bounded "
                         "work between progress records)")
    ap.add_argument("--scrub-interval", type=float, default=0.0,
                    help="seconds between background integrity scrubs "
                         "(0 = scrub only on demand)")
    ap.add_argument("--host-id", default=None,
                    help="journal segment id for multi-controller jobs: "
                         "each host appends to its own manifest segment, "
                         "merged deterministically on read/compaction")
    ap.add_argument("--clean", action="store_true", default=True)
    ap.add_argument("--fail-at", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    run(ap.parse_args())


if __name__ == "__main__":
    main()
