"""End-to-end training driver with pluggable checkpointing strategies.

Runs a real training loop (synthetic data, native Adam) with LowDiff /
LowDiff+ / baselines attached, reports per-strategy overhead vs the
no-checkpoint bound, and supports failure injection + recovery.

All flags map through :class:`repro.core.engine.EngineConfig` (engine
knobs) and :class:`repro.checkpoint.config.StoreConfig` (the tier
stack) — ``EngineConfig.from_args`` owns the flag→config translation
in one place, and ``tests/test_flag_config_sync.py`` fails if a flag
and its config field drift apart.

Examples::

    PYTHONPATH=src python -m repro.launch.train --arch gpt2-l --reduced \
        --steps 50 --strategy lowdiff --ckpt-dir /tmp/ck
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
        --steps 30 --strategy lowdiff_plus --fail-at 20
    PYTHONPATH=src python -m repro.launch.train --arch gpt2-l --reduced \
        --steps 40 --backend local --peers 2 --fail-at 25
"""
from __future__ import annotations

import argparse
import shutil
import time
import warnings

import jax
import numpy as np

from repro.checkpoint import BACKENDS, FORMATS
from repro.configs import get_config
from repro.core.engine import STRATEGIES, EngineConfig, make_engine
from repro.core.steps import init_state, make_train_step
from repro.data.synthetic import TokenStream
from repro.models.registry import build_model
from repro.obs.log import configure as configure_logging, get_logger
from repro.obs.metrics import REGISTRY
from repro.obs.timeline import STALL_CATEGORIES, TIMELINE
from repro.obs.trace import TRACER


def build_strategy(name: str, model, store, *, lr, rho, full_interval,
                   batch_size, compressor="topk", persist_mode="full",
                   persist_threshold=0.0, fold_interval=16,
                   replay_window=None):
    """Deprecated shim: construct a strategy from loose keywords. New
    code builds an :class:`EngineConfig` and calls ``make_engine``."""
    warnings.warn(
        "build_strategy() is deprecated; build an "
        "repro.core.engine.EngineConfig and call make_engine()",
        DeprecationWarning, stacklevel=2)
    cfg = EngineConfig(strategy=name, lr=lr, rho=rho,
                       full_interval=full_interval or 0,
                       batch_size=batch_size or 0, compressor=compressor,
                       persist_mode=persist_mode,
                       persist_threshold=persist_threshold,
                       fold_interval=fold_interval,
                       replay_window=replay_window or 0)
    return make_engine(cfg, model, store=store)


def _stall_suffix(rec) -> str:
    """Render a committed step record's stall attribution (only the
    categories that actually charged time — quiet steps stay short)."""
    parts = []
    for cat in STALL_CATEGORIES:
        if rec.get(cat, 0.0) > 0.0:
            parts.append(f"{cat}={rec[cat] * 1e3:.1f}ms")
    parts.append(f"stall%={TIMELINE.stall_fraction() * 100:.1f}")
    return " ".join(parts)


def run(args):
    configure_logging(getattr(args, "log_level", "info"))
    log = get_logger("train")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    log.info(f"arch={cfg.name} params={model.n_params() / 1e6:.1f}M "
             f"strategy={args.strategy}")
    if getattr(args, "clean", False) and args.ckpt_dir:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    engine_cfg = EngineConfig.from_args(args)
    TIMELINE.clear()
    if engine_cfg.trace_out:
        TRACER.enable(engine_cfg.trace_buffer)
    store = engine_cfg.build_store()
    strat = make_engine(engine_cfg, model, store=store)
    mode = ("lowdiff" if args.strategy == "lowdiff" else
            "lowdiff_plus" if args.strategy == "lowdiff_plus" else "dense")
    state = init_state(model, jax.random.PRNGKey(args.seed), mode=mode)
    plain_step = make_train_step(model, mode=mode, lr=args.lr, rho=args.rho)
    stream = TokenStream(cfg, args.seq, args.batch, seed=args.seed)

    losses, times = [], []
    t_start = time.perf_counter()
    for t in range(args.steps):
        batch = next(stream)
        t0 = time.perf_counter()
        TIMELINE.begin(t + 1)
        if strat is not None:
            state, metrics = strat.train_step(state, batch)
        else:
            state, metrics, _ = plain_step(state, batch)
        jax.block_until_ready(state["params"])
        step_wall = time.perf_counter() - t0
        rec = TIMELINE.commit(t + 1, step_wall)
        times.append(step_wall)
        losses.append(float(metrics["loss"]))
        if args.log_every and (t + 1) % args.log_every == 0:
            log.info(f"step {t + 1:5d} loss={losses[-1]:.4f} "
                     f"it={np.mean(times[-args.log_every:]) * 1e3:.1f}ms "
                     + _stall_suffix(rec))
        if args.fail_at and t + 1 == args.fail_at:
            log.info(f"\n*** injected failure at step {t + 1} ***")
            assert strat is not None, "--fail-at needs a strategy"
            strat.flush()
            if args.strategy == "lowdiff_plus":
                state = strat.recover_software(state)
            else:
                state, n = strat.recover()
            log.info(f"recovered at step {int(state['step'])}; resuming\n")
            stream.step = int(state["step"])

    wall = time.perf_counter() - t_start
    if strat is not None:
        strat.close()
    elif store is not None:
        store.close()
    log.info(f"\n{args.steps} steps in {wall:.1f}s "
             f"(mean iter {np.mean(times) * 1e3:.1f}ms, "
             f"p50 {np.percentile(times, 50) * 1e3:.1f}ms)")
    log.info(f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    if strat is not None:
        log.info(f"strategy stats: {strat.stats()}")
    if engine_cfg.trace_out:
        n = TRACER.export_chrome(engine_cfg.trace_out)
        log.info(f"wrote {n} trace events -> {engine_cfg.trace_out}")
    if engine_cfg.metrics_out:
        extras = [{"kind": "metric", **m} for m in REGISTRY.collect()]
        n = TIMELINE.write_jsonl(engine_cfg.metrics_out, extra=extras)
        log.info(f"wrote {n} records -> {engine_cfg.metrics_out}")
    return losses, times


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-l")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--rho", type=float, default=0.01)
    ap.add_argument("--strategy", choices=STRATEGIES, default="lowdiff")
    ap.add_argument("--full-interval", type=int, default=20,
                    help="full-checkpoint interval f (0 = Eq. (10) optimum "
                         "+ online tuning)")
    ap.add_argument("--batch-size", type=int, default=2,
                    help="differential batching size b (0 = Eq. (10) "
                         "optimum + online tuning)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--backend", choices=BACKENDS, default="local",
                    help="checkpoint storage backend (local FS, CPU-memory "
                         "tier with async spill, or sharded concurrent)")
    ap.add_argument("--format", choices=FORMATS, default="frame",
                    help="checkpoint serialization: 'frame' (streamed "
                         "zero-copy, memmap reads) or 'npz' (legacy); "
                         "reads sniff, so old chains recover either way")
    ap.add_argument("--compressor", choices=("topk", "quant8", "packed"),
                    default="topk",
                    help="lowdiff gradient compression: topk sparsification, "
                         "quant8 blockwise int8, or packed (fused top-k + "
                         "int8 + wire pack in one Pallas kernel)")
    ap.add_argument("--shards", type=int, default=4,
                    help="shard count for --backend sharded")
    ap.add_argument("--memory-capacity-mb", type=float, default=None,
                    help="RAM-tier byte budget for --backend memory/remote")
    ap.add_argument("--remote-url", default=None,
                    help="object store for --backend remote: fake://bucket "
                         "(in-process) or file:///path (directory-backed); "
                         "default file://<ckpt-dir>")
    ap.add_argument("--chunk-mb", type=float, default=4.0,
                    help="remote-tier content chunk size in MiB")
    ap.add_argument("--max-retries", type=int, default=4,
                    help="bounded retries per remote chunk transfer")
    ap.add_argument("--remote-fault-rate", type=float, default=0.0,
                    help="injected transient-fault probability on fake:// "
                         "stores (exercises retry/backoff)")
    ap.add_argument("--peers", type=int, default=0,
                    help="replicate every differential to this many "
                         "failure-domain-diverse peer hosts' memory "
                         "(Checkmate-style tier above the local stack; "
                         "0 = off). Single-process runs simulate peers "
                         "in-process via the loopback transport")
    ap.add_argument("--peer-hub", default=None,
                    help="peer membership group name; hosts sharing a hub "
                         "replicate to each other (default: 'default')")
    ap.add_argument("--peer-domain", default="d0",
                    help="failure domain of this host (rack/pod); peer "
                         "selection prefers one replica per domain")
    ap.add_argument("--peer-window", type=int, default=8,
                    help="max in-flight peer replication sends before "
                         "put() backpressures")
    ap.add_argument("--peer-fault-rate", type=float, default=0.0,
                    help="injected transient-fault probability on peer "
                         "sends (exercises retry/backoff)")
    ap.add_argument("--retention", type=int, default=0,
                    help="keep this many full checkpoints + their chains "
                         "(0 = never garbage-collect)")
    ap.add_argument("--eviction", choices=("fifo", "lru"), default="fifo",
                    help="memory-tier eviction policy over size-class "
                         "buckets; lru refreshes recency on recovery reads")
    ap.add_argument("--maintenance", choices=("on", "off"), default="off",
                    help="background maintenance service: journaled "
                         "resumable GC + integrity scrub off the step "
                         "loop (off = synchronous GC fallback)")
    ap.add_argument("--persist-mode", choices=("full", "incremental"),
                    default="full",
                    help="lowdiff_plus persistence: 'full' rewrites the "
                         "whole replica every persist; 'incremental' "
                         "writes only the leaves that changed since the "
                         "last persist as a patch chain on a base full, "
                         "folded back in the background (requires "
                         "--format frame)")
    ap.add_argument("--persist-threshold", type=float, default=0.0,
                    help="incremental persist filter: defer re-persisting "
                         "a dirty leaf until its accumulated relative "
                         "L-inf change exceeds this (0 = exact: persist "
                         "every changed leaf)")
    ap.add_argument("--dirty-granularity", choices=("leaf", "row"),
                    default="leaf",
                    help="incremental persist unit: 'leaf' re-persists "
                         "whole changed arrays; 'row' tracks dirtiness "
                         "per first-axis row and patches only the "
                         "changed row ranges")
    ap.add_argument("--diff-quant", choices=("off", "int8", "int4"),
                    default="off",
                    help="quantize row-span patch payloads on the wire "
                         "(per-row-block absmax scales, error-feedback "
                         "residuals; requires --persist-mode incremental "
                         "--dirty-granularity row)")
    ap.add_argument("--fold-interval", type=int, default=16,
                    help="fold the patch chain into its base frame after "
                         "this many incremental persists (0 = never)")
    ap.add_argument("--fold-amplification", type=float, default=1.5,
                    help="also fold when chain overlay bytes divided by "
                         "base frame bytes reach this ratio (0 = "
                         "disable the adaptive trigger; --fold-interval "
                         "stays as the hard cap)")
    ap.add_argument("--merge-slice", type=int, default=64,
                    help="leaves patched per journaled fold slice "
                         "(bounded work between progress records)")
    ap.add_argument("--replay-window", type=int, default=0,
                    help="differentials per parallel-replay scan window; "
                         "bounds peak recovery memory to O(window * "
                         "model) (0 = one window)")
    ap.add_argument("--replay-device", choices=("on", "off"), default="off",
                    help="device-resident recovery: stage the compressed "
                         "payloads H2D and replay the chain as one jitted "
                         "scan through the fused decompress-and-apply "
                         "kernels (bit-identical to serial replay)")
    ap.add_argument("--snapshot-shards", type=int, default=4,
                    help="per-shard overlapped D2H snapshot transfers; "
                         "each shard's buffers release as its bytes land "
                         "(0 = legacy whole-tree batch copy)")
    ap.add_argument("--gc-slice", type=int, default=64,
                    help="keys swept per journaled GC slice (bounded "
                         "work between progress records)")
    ap.add_argument("--scrub-interval", type=float, default=0.0,
                    help="seconds between background integrity scrubs "
                         "(0 = scrub only on demand)")
    ap.add_argument("--host-id", default=None,
                    help="journal segment id for multi-controller jobs: "
                         "each host appends to its own manifest segment, "
                         "merged deterministically on read/compaction")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace_event JSON of the pipeline "
                         "spans here (load in chrome://tracing or "
                         "ui.perfetto.dev); also enables the span tracer")
    ap.add_argument("--metrics-out", default=None,
                    help="write per-step stall-attribution records and the "
                         "final metrics-registry collection as JSON Lines")
    ap.add_argument("--trace-buffer", type=int, default=65536,
                    help="span ring-buffer capacity; oldest spans drop "
                         "beyond this (the Chrome export reports drops)")
    ap.add_argument("--clean", action="store_true", default=True)
    ap.add_argument("--fail-at", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--log-level", default="info",
                    choices=("debug", "info", "warning", "error"),
                    help="driver log verbosity (default keeps the "
                         "human-readable step lines)")
    return ap


def main():
    run(build_parser().parse_args())


if __name__ == "__main__":
    main()
