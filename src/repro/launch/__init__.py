"""Entry points: training driver, serving, mesh construction, dryrun."""
