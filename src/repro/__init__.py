"""LowDiff reproduction: frequent differential checkpointing for
distributed training (jax/pallas)."""

__version__ = "0.1.0"
