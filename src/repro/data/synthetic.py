"""Deterministic synthetic data pipeline.

Produces shardable batches for every architecture/shape without external
datasets: token streams from a counter-based PRNG (stable across restarts
— checkpoint-recovery tests rely on byte-identical batch replay), plus
stub frontend embeddings for the audio/vlm backbones.

``input_specs`` returns ShapeDtypeStruct stand-ins (optionally sharded)
for dry-run lowering — the same dict structure the concrete pipeline
produces.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec


def _tok_rng(seed: int, step: int):
    return np.random.default_rng(np.uint64(seed * 1_000_003 + step))


def make_batch(cfg: ArchConfig, seq_len: int, batch: int, *, step: int = 0,
               seed: int = 0, kind: str = "train") -> Dict[str, jax.Array]:
    """Concrete host batch for training / prefill."""
    rng = _tok_rng(seed, step)
    if kind == "decode":
        return {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (batch, 1)), jnp.int32),
            "pos": jnp.asarray(min(seq_len - 1, 7), jnp.int32),
        }
    toks = rng.integers(0, cfg.vocab, (batch, seq_len + 1))
    out = {
        "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
        "targets": jnp.asarray(toks[:, 1:], jnp.int32),
    }
    if cfg.arch_type == "vlm":
        P = min(cfg.n_patches or 16, seq_len // 2)
        out["patch_embeds"] = jnp.asarray(
            rng.normal(size=(batch, P, cfg.frontend_dim)), cfg.cdtype())
        mask = np.ones((batch, seq_len), np.float32)
        mask[:, :P] = 0.0
        out["loss_mask"] = jnp.asarray(mask)
    if cfg.arch_type == "audio":
        Ss = encdec.src_len(cfg, seq_len)
        out["src_embeds"] = jnp.asarray(
            rng.normal(size=(batch, Ss, cfg.d_model)) * 0.1, cfg.cdtype())
    return out


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                shardings: Optional[dict] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for dry-run lowering.

    ``shardings``: optional {name -> jax.sharding.Sharding}; names:
    'tokens', 'targets', 'loss_mask', 'patch_embeds', 'src_embeds', 'pos'.
    """
    B, S = shape.global_batch, shape.seq_len

    def sds(shape_, dtype, name):
        sh = (shardings or {}).get(name)
        if sh is not None:
            return jax.ShapeDtypeStruct(shape_, dtype, sharding=sh)
        return jax.ShapeDtypeStruct(shape_, dtype)

    if shape.kind == "decode":
        return {"tokens": sds((B, 1), jnp.int32, "tokens"),
                "pos": sds((), jnp.int32, "pos")}
    out = {"tokens": sds((B, S), jnp.int32, "tokens"),
           "targets": sds((B, S), jnp.int32, "targets")}
    if cfg.arch_type == "vlm":
        P = min(cfg.n_patches or 16, S // 2)
        out["patch_embeds"] = sds((B, P, cfg.frontend_dim), cfg.cdtype(),
                                  "patch_embeds")
        out["loss_mask"] = sds((B, S), jnp.float32, "loss_mask")
    if cfg.arch_type == "audio":
        out["src_embeds"] = sds((B, encdec.src_len(cfg, S), cfg.d_model),
                                cfg.cdtype(), "src_embeds")
    return out


class TokenStream:
    """Stateful iterator facade used by the training launcher."""

    def __init__(self, cfg: ArchConfig, seq_len: int, batch: int, seed: int = 0):
        self.cfg, self.seq_len, self.batch, self.seed = cfg, seq_len, batch, seed
        self.step = 0

    def __next__(self):
        b = make_batch(self.cfg, self.seq_len, self.batch,
                       step=self.step, seed=self.seed)
        self.step += 1
        return b

    def __iter__(self):
        return self

    def state(self):
        return {"step": self.step, "seed": self.seed}

    def restore(self, state):
        self.step = int(state["step"])
        self.seed = int(state["seed"])
