"""Synthetic token streams for deterministic training runs."""
