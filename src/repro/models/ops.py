"""Shared numeric building blocks: norms, RoPE, attention, chunked xent.

All functions are pure jnp and shard-friendly (no host control flow on
traced values). Softmax statistics are kept in f32 regardless of the
compute dtype.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard

NEG_INF = -1e30

# Analysis mode: XLA's cost model counts a while-loop body once, so the
# roofline composer lowers single-layer segments with every inner scan
# fully unrolled (trip counts folded into the segment counts instead).
_UNROLL = False


def set_analysis_unroll(flag: bool):
    global _UNROLL
    _UNROLL = bool(flag)


def scan_unroll():
    return True if _UNROLL else 1


def rms_norm(x, gamma, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float):
    exps = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exps)                       # (hd/2,)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (...,S,hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def attention(q, k, v, *, causal=True, window=0, q_chunk=512, kv_chunk=1024,
              q_offset=0, causal_skip=True):
    """Online-softmax blocked attention (pure-jnp flash).

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D) with H % KV == 0 (GQA).
    Memory is bounded by (B, q_chunk, H, kv_chunk) score tiles.
    ``q_offset`` is the absolute position of q[0] (prefill continuation).

    ``causal_skip`` (perf iteration A-3/C-1): statically skip tiles that
    are fully masked — strictly-upper tiles under causality, and tiles
    entirely below a *static* window. Halves causal-attention compute and
    score traffic vs the masked-full baseline. Requires causal, no
    q_offset, and a static window (traced per-layer windows fall back).
    """
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    if _UNROLL:
        # analysis mode: fewer/bigger tiles => tractable unrolled HLO.
        # Total FLOPs are tile-size invariant; bytes shift marginally.
        q_chunk = max(q_chunk, Sq // 8)
        kv_chunk = max(kv_chunk, Sk // 8)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    assert Sq % q_chunk == 0 and Sk % kv_chunk == 0
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    scale = 1.0 / (D ** 0.5)

    qr = (q * scale).reshape(B, nq, q_chunk, KV, G, D)
    kr = k.reshape(B, nk, kv_chunk, KV, D)
    vr = v.reshape(B, nk, kv_chunk, KV, D)

    static_window = isinstance(window, int)
    use_skip = (causal_skip and causal and static_window and q_offset == 0
                and Sq == Sk and nq > 1)

    def kv_tile(state, qb, q_idx, ki_base, ki):
        acc, m, l = state
        kb = jax.lax.dynamic_index_in_dim(kr, ki_base + ki, 1, False)
        vb = jax.lax.dynamic_index_in_dim(vr, ki_base + ki, 1, False)
        k_idx = (ki_base + ki) * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qb, kb,
                       preferred_element_type=jnp.float32)
        qpos, kpos = q_idx[:, None], k_idx[None, :]
        ok = jnp.ones((q_chunk, kv_chunk), bool)
        if causal:
            ok &= kpos <= qpos
        # window may be a traced per-layer value (hybrid archs)
        ok &= (jnp.asarray(window) <= 0) | (kpos > qpos - window)
        s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return acc_new, m_new, l_new

    def init_state():
        return (jnp.zeros((B, q_chunk, KV, G, D), jnp.float32),
                jnp.full((B, q_chunk, KV, G), NEG_INF, jnp.float32),
                jnp.zeros((B, q_chunk, KV, G), jnp.float32))

    if use_skip:
        # static python loop over q blocks; each scans only live kv tiles
        outs = []
        for qi in range(nq):
            qb = qr[:, qi]
            q_idx = qi * q_chunk + jnp.arange(q_chunk)
            lo = 0
            if window and window > 0:
                lo = max(0, (qi * q_chunk - int(window)) // kv_chunk)
            # last kv tile touched by this q block's final position
            hi = min(((qi + 1) * q_chunk - 1) // kv_chunk + 1, nk)
            live = hi - lo

            def body(state, ki):
                return kv_tile(state, qb, q_idx, lo, ki), None

            (acc, m, l), _ = jax.lax.scan(body, init_state(),
                                          jnp.arange(live),
                                          unroll=scan_unroll())
            outs.append((acc / jnp.maximum(l, 1e-20)[..., None])
                        .astype(q.dtype))
        out = jnp.stack(outs, axis=1)                   # (B,nq,qc,KV,G,D)
        return out.reshape(B, Sq, H, D)

    def q_block(carry, qi):
        del carry
        qb = jax.lax.dynamic_index_in_dim(qr, qi, 1, False)
        q_idx = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def body(state, ki):
            return kv_tile(state, qb, q_idx, 0, ki), None

        (acc, m, l), _ = jax.lax.scan(body, init_state(), jnp.arange(nk),
                                      unroll=scan_unroll())
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return None, out.astype(q.dtype)

    _, blocks = jax.lax.scan(q_block, None, jnp.arange(nq),
                             unroll=scan_unroll())
    # blocks: (nq, B, qc, KV, G, D) -> (B, S, H, D)
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, Sq, KV, G, D)
    return out.reshape(B, Sq, H, D)


def decode_attention(q, k_cache, v_cache, pos, *, window=0):
    """Single-token attention against a (possibly seq-sharded) KV cache.

    q: (B, H, D); k_cache/v_cache: (B, S, KV, D); pos: scalar int32 —
    index of the current token (entries > pos are invalid).
    """
    B, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qr = (q * (1.0 / D ** 0.5)).reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache,
                   preferred_element_type=jnp.float32)
    idx = jnp.arange(S)
    ok = idx <= pos
    ok &= (jnp.asarray(window) <= 0) | (idx > pos - window)
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, D).astype(q.dtype)


def cache_update(cache, new, pos):
    """Write ``new`` (B, KV, D) at sequence slot ``pos`` of (B, S, KV, D).

    Uses a masked elementwise write (iota == pos) rather than
    dynamic_update_slice so a sequence-sharded cache never needs gathering.
    """
    S = cache.shape[1]
    onehot = (jnp.arange(S) == pos)[None, :, None, None]
    return jnp.where(onehot, new[:, None].astype(cache.dtype), cache)


def swiglu(x, wg, wu, wd):
    g = jnp.einsum("bsd,df->bsf", x, wg.astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, wu.astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard(h, "batch", None, "mlp")
    return jnp.einsum("bsf,fd->bsd", h, wd.astype(x.dtype))


def chunked_softmax_xent(h, w_lm, targets, *, chunk=512, mask=None,
                         logit_cap=0.0):
    """Cross-entropy without materializing (B, S, V) logits.

    h: (B, S, D) final hidden; w_lm: (D, V); targets: (B, S) int32.
    Returns (sum_loss, n_tokens). Chunks are rematerialized on backward.
    """
    B, S, D = h.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    n = S // chunk
    hr = h.reshape(B, n, chunk, D)
    tr = targets.reshape(B, n, chunk)
    mr = (mask.reshape(B, n, chunk) if mask is not None
          else jnp.ones_like(tr, jnp.float32))

    @jax.checkpoint
    def body(carry, xs):
        hb, tb, mb = xs                                 # (B,chunk,D) ...
        logits = jnp.einsum("bcd,dv->bcv", hb, w_lm.astype(hb.dtype),
                            preferred_element_type=jnp.float32)
        if logit_cap:
            logits = logit_cap * jnp.tanh(logits / logit_cap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # one-hot contraction instead of take_along_axis: shards cleanly
        # when the vocab dim is model-parallel.
        onehot = jax.nn.one_hot(tb, logits.shape[-1], dtype=logits.dtype)
        tgt = jnp.sum(logits * onehot, axis=-1)
        loss = (lse - tgt) * mb
        return (carry[0] + loss.sum(), carry[1] + mb.sum()), None

    xs = (jnp.moveaxis(hr, 1, 0), jnp.moveaxis(tr, 1, 0),
          jnp.moveaxis(mr, 1, 0))
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), xs,
                                 unroll=scan_unroll())
    return tot, cnt
