"""Decoder-only language model assembly (dense / moe / vlm / hybrid / ssm).

The layer stack is scanned (stacked leading 'layers' dim) with optional
rematerialization; heterogeneous per-layer attention windows (Hymba) ride
along as scan inputs. Decode threads per-layer cache slices through the
same scan.

Batch dicts:
  train/prefill: {"tokens": (B,S) i32, "targets": (B,S) i32,
                  ["patch_embeds": (B,P,Fd)]}
  decode:        {"tokens": (B,1) i32, "pos": () i32}
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import blocks, moe as moe_lib, ops, xlstm
from repro.models.param import ParamSpec, abstractify, materialize


# --------------------------------------------------------------------------
# Specs
# --------------------------------------------------------------------------

def layer_specs(cfg: ArchConfig, layers: Optional[int] = None) -> dict:
    L = layers if layers is not None else cfg.n_layers
    t = cfg.arch_type
    if t == "ssm":  # xLSTM: scan over (mLSTM, sLSTM) pairs
        assert cfg.slstm_every == 2 and L % 2 == 0
        return {"mlstm": xlstm.mlstm_specs(cfg, L // 2),
                "slstm": xlstm.slstm_specs(cfg, L // 2)}
    specs = {
        "attn_norm": ParamSpec((L, cfg.d_model), ("layers", "embed"), init="ones"),
        "attn": blocks.attention_specs(cfg, L),
        "ffn_norm": ParamSpec((L, cfg.d_model), ("layers", "embed"), init="ones"),
    }
    if t == "moe":
        specs["moe"] = moe_lib.moe_specs(cfg, L)
    else:
        specs["ffn"] = blocks.ffn_specs(cfg, L)
    if t == "hybrid":
        specs["mamba"] = blocks.mamba_specs(cfg, L)
    return specs


def param_specs(cfg: ArchConfig) -> dict:
    d, V = cfg.d_model, cfg.vocab
    specs: Dict[str, Any] = {
        "embed": ParamSpec((V, d), ("vocab", "embed"), init="embed", scale=0.02),
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
        "layers": layer_specs(cfg),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, V), ("embed", "vocab"))
    if cfg.arch_type == "vlm":
        specs["projector"] = ParamSpec((cfg.frontend_dim, d), ("null", "embed"))
    return specs


# --------------------------------------------------------------------------
# Per-layer window pattern (hybrid archs)
# --------------------------------------------------------------------------

def layer_windows(cfg: ArchConfig) -> np.ndarray:
    """window per layer; 0 = full attention."""
    w = np.full((cfg.n_layers,), cfg.sliding_window, np.int32)
    for i in cfg.global_attn_layers:
        w[i] = 0
    return w


# --------------------------------------------------------------------------
# Forward (train / prefill)
# --------------------------------------------------------------------------

def _std_block(lp, h, cfg: ArchConfig, positions, window):
    """One dense/moe/vlm/hybrid block. Returns (h, aux)."""
    x = ops.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
    attn_out = blocks.attention_apply(lp["attn"], x, cfg,
                                      positions=positions, window=window)
    if cfg.arch_type == "hybrid":
        m_out = blocks.mamba_apply(lp["mamba"], x, cfg)
        attn_out = 0.5 * (attn_out + m_out)
    h = h + attn_out
    x = ops.rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
    aux = jnp.float32(0)
    if cfg.arch_type == "moe":
        f_out, aux = moe_lib.moe_apply(lp["moe"], x, cfg)
    else:
        f_out = blocks.ffn_apply(lp["ffn"], x)
    return h + f_out, aux


def stack_apply(params, h, cfg: ArchConfig, positions):
    """Scan the layer stack. Returns (h, aux_sum)."""
    if cfg.arch_type == "ssm":
        def pair(h, lp):
            h = xlstm.mlstm_apply(lp["mlstm"], h, cfg)
            h = xlstm.slstm_apply(lp["slstm"], h, cfg)
            # sequence-parallel residual between blocks (remat stash shards)
            return shard(h, "batch", "residual_seq", None), jnp.float32(0)
        body = jax.checkpoint(pair) if cfg.remat else pair
        h, aux = jax.lax.scan(lambda c, lp: body(c, lp), h, params["layers"],
                              unroll=ops.scan_unroll())
        return h, aux.sum()

    windows = jnp.asarray(layer_windows(cfg))

    def one(h, xs):
        lp, w = xs
        h, aux = _std_block(lp, h, cfg, positions, w)
        # sequence-parallel residual between blocks (remat stash shards)
        return shard(h, "batch", "residual_seq", None), aux

    if len(set(layer_windows(cfg).tolist())) == 1:
        w0 = int(layer_windows(cfg)[0])
        def one(h, xs):  # noqa: F811 — static window specialization
            lp, _ = xs
            h, aux = _std_block(lp, h, cfg, positions, w0)
            return shard(h, "batch", "residual_seq", None), aux

    body = jax.checkpoint(one) if cfg.remat else one
    h, aux = jax.lax.scan(body, h, (params["layers"], windows),
                          unroll=ops.scan_unroll())
    return h, aux.sum()


def embed_tokens(params, batch, cfg: ArchConfig):
    tokens = batch["tokens"]
    h = params["embed"].astype(cfg.cdtype())[tokens]
    h = h * jnp.asarray(np.sqrt(cfg.d_model), cfg.cdtype())
    if cfg.arch_type == "vlm":
        pe = jnp.einsum("bpf,fd->bpd", batch["patch_embeds"].astype(cfg.cdtype()),
                        params["projector"].astype(cfg.cdtype()))
        pe = pe * jnp.asarray(np.sqrt(cfg.d_model), cfg.cdtype())
        h = jnp.concatenate([pe, h[:, pe.shape[1]:]], axis=1) \
            if pe.shape[1] < h.shape[1] else pe[:, :h.shape[1]]
    return shard(h, "batch", None, None)


def lm_head_weight(params, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def forward(params, batch, cfg: ArchConfig):
    """Full-sequence forward to final hidden states (B, S, d)."""
    h = embed_tokens(params, batch, cfg)
    S = h.shape[1]
    positions = jnp.arange(S)
    h, aux = stack_apply(params, h, cfg, positions)
    h = ops.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, aux


def loss_fn(params, batch, cfg: ArchConfig):
    """Mean next-token cross-entropy (+ MoE aux). Returns (loss, metrics)."""
    h, aux = forward(params, batch, cfg)
    mask = batch.get("loss_mask")
    tot, cnt = ops.chunked_softmax_xent(
        h, lm_head_weight(params, cfg), batch["targets"],
        chunk=cfg.loss_chunk, mask=mask)
    xent = tot / jnp.maximum(cnt, 1.0)
    loss = xent + cfg.moe.router_aux_weight * aux
    return loss, {"xent": xent, "aux": aux, "tokens": cnt}


def logits_fn(params, batch, cfg: ArchConfig):
    """Prefill: final-position logits (B, V) — serving entry point."""
    h, _ = forward(params, batch, cfg)
    w = lm_head_weight(params, cfg)
    return jnp.einsum("bd,dv->bv", h[:, -1], w.astype(h.dtype),
                      preferred_element_type=jnp.float32)


# --------------------------------------------------------------------------
# Decode (single token, cached)
# --------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    """Stacked per-layer caches. Unused fields are () placeholders."""
    k: Any = ()
    v: Any = ()
    mamba: Any = ()
    mlstm: Any = ()
    slstm: Any = ()


def cache_len(cfg: ArchConfig, seq_len: int) -> int:
    """Ring-buffer length: full-attn archs bound long contexts by window."""
    if cfg.sliding_window:
        need = cfg.sliding_window
        if cfg.global_attn_layers:
            return seq_len            # hybrid keeps global layers full
        return min(seq_len, need)
    if seq_len > 65536 and cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        return 8192                    # sub-quadratic long-context variant
    return seq_len


def init_cache(cfg: ArchConfig, B: int, seq_len: int, abstract=False):
    Lc = cache_len(cfg, seq_len)
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd()
    dt = cfg.cdtype()

    def mk(shape, dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    if cfg.arch_type == "ssm":
        d, di, H, hp = xlstm._dims(cfg)
        n_pairs = cfg.n_layers // 2
        mc = xlstm.MLSTMCache(
            la_state(mk, (n_pairs, B, H, hp, hp), (n_pairs, B, H, hp),
                     (n_pairs, B, H)),
            mk((n_pairs, B, cfg.ssm.conv_width - 1, di), jnp.float32))
        sc = xlstm.SLSTMState(*[mk((n_pairs, B, d), jnp.float32)
                                for _ in range(4)])
        return DecodeCache(mlstm=mc, slstm=sc)

    k = mk((L, B, Lc, KV, hd), dt)
    v = mk((L, B, Lc, KV, hd), dt)
    if cfg.arch_type == "hybrid":
        d = cfg.d_model
        di = cfg.ssm.expand * d
        N = cfg.ssm.state_dim
        Hm = max(1, di // 64)
        hp = di // Hm
        mam = blocks.MambaCache(
            la_state(mk, (L, B, Hm, N, hp), (L, B, Hm, N), (L, B, Hm)),
            mk((L, B, cfg.ssm.conv_width - 1, di), jnp.float32))
        return DecodeCache(k=k, v=v, mamba=mam)
    return DecodeCache(k=k, v=v)


def la_state(mk, s_shape, n_shape, m_shape):
    from repro.models.linear_attn import LinState
    return LinState(mk(s_shape, jnp.float32), mk(n_shape, jnp.float32),
                    mk(m_shape, jnp.float32))


def cache_logical(cfg: ArchConfig):
    """Logical-axis tree matching ``init_cache`` output (for sharding)."""
    kv = ("layers", "batch", "kvseq", "kv_heads", None)
    lin = la_logical()
    if cfg.arch_type == "ssm":
        from repro.models import xlstm as _x
        mc = _x.MLSTMCache(lin, ("layers", "batch", None, "mlp"))
        sc = _x.SLSTMState(*[("layers", "batch", "mlp")] * 4)
        return DecodeCache(mlstm=mc, slstm=sc)
    if cfg.arch_type == "hybrid":
        from repro.models import blocks as _b
        mam = _b.MambaCache(lin, ("layers", "batch", None, "mlp"))
        return DecodeCache(k=kv, v=kv, mamba=mam)
    return DecodeCache(k=kv, v=kv)


def la_logical():
    from repro.models.linear_attn import LinState
    return LinState(("layers", "batch", None, None, "mlp"),
                    ("layers", "batch", None, None),
                    ("layers", "batch", None))


def decode_block(lp, h, cfg: ArchConfig, ck, cv, pos, *, window=0,
                 ring=False, mam=None):
    """Single-layer decode (also lowered standalone by the roofline
    composer). Returns (h, k', v', mamba_cache')."""
    x = ops.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
    # hybrid: windowed layers use ring slots sized to full cache —
    # masking handles the window; ring only for long-context dense.
    a_out, ck2, cv2 = blocks.attention_decode(
        lp["attn"], x, cfg, ck, cv, pos, window=window, ring=ring)
    if cfg.arch_type == "hybrid":
        m_out, mam = blocks.mamba_decode(lp["mamba"], x, cfg, mam)
        a_out = 0.5 * (a_out + m_out)
    h = h + a_out
    x = ops.rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
    if cfg.arch_type == "moe":
        f_out, _ = moe_lib.moe_apply(lp["moe"], x, cfg)
    else:
        f_out = blocks.ffn_apply(lp["ffn"], x)
    return h + f_out, ck2, cv2, mam


def ssm_decode_block(lp, h, cfg: ArchConfig, mc, sc):
    h, mc2 = xlstm.mlstm_decode(lp["mlstm"], h, cfg, mc)
    h, sc2 = xlstm.slstm_decode(lp["slstm"], h, cfg, sc)
    return h, mc2, sc2


def decode_step(params, cache: DecodeCache, batch, cfg: ArchConfig,
                seq_len: int):
    """One-token decode. batch: {"tokens": (B,1), "pos": ()} -> (logits, cache)."""
    pos = batch["pos"]
    h = params["embed"].astype(cfg.cdtype())[batch["tokens"]]
    h = h * jnp.asarray(np.sqrt(cfg.d_model), cfg.cdtype())
    h = shard(h, "batch", None, None)
    Lc = cache_len(cfg, seq_len)
    ring = Lc < seq_len

    if cfg.arch_type == "ssm":
        def pair(h, xs):
            lp, mc, sc = xs
            h, mc2, sc2 = ssm_decode_block(lp, h, cfg, mc, sc)
            return h, (mc2, sc2)
        h, (mc, sc) = jax.lax.scan(
            pair, h, (params["layers"], cache.mlstm, cache.slstm))
        new_cache = DecodeCache(mlstm=mc, slstm=sc)
    else:
        windows = jnp.asarray(layer_windows(cfg))

        def one(h, xs):
            lp, ck, cv, w, mam = xs
            h, ck2, cv2, mam = decode_block(lp, h, cfg, ck, cv, pos,
                                            window=w, ring=ring, mam=mam)
            return h, (ck2, cv2, mam)

        mam_in = (cache.mamba if cfg.arch_type == "hybrid"
                  else jnp.zeros((cfg.n_layers,), jnp.float32))
        h, (ck, cv, mam) = jax.lax.scan(
            one, h, (params["layers"], cache.k, cache.v, windows, mam_in))
        new_cache = DecodeCache(k=ck, v=cv,
                                mamba=mam if cfg.arch_type == "hybrid" else ())

    h = ops.rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = lm_head_weight(params, cfg)
    logits = jnp.einsum("bd,dv->bv", h[:, 0], w.astype(h.dtype),
                        preferred_element_type=jnp.float32)
    return logits, new_cache
