"""Block-level mixers shared by the model zoo.

Each mixer exposes ``*_specs(cfg)`` (ParamSpec tree) and apply functions for
train/prefill (full sequence) and decode (single token + cache slice).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import linear_attn as la
from repro.models import ops
from repro.models.param import ParamSpec


# --------------------------------------------------------------------------
# GQA attention mixer
# --------------------------------------------------------------------------

def attention_specs(cfg: ArchConfig, layers: int) -> dict:
    d, hd = cfg.d_model, cfg.hd()
    H, KV = cfg.n_heads, cfg.n_kv_heads
    L = (layers,)
    specs = {
        "wq": ParamSpec(L + (d, H * hd), ("layers", "fsdp", "heads")),
        "wk": ParamSpec(L + (d, KV * hd), ("layers", "fsdp", "kv_heads")),
        "wv": ParamSpec(L + (d, KV * hd), ("layers", "fsdp", "kv_heads")),
        "wo": ParamSpec(L + (H * hd, d), ("layers", "heads", "fsdp")),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec(L + (H * hd,), ("layers", "heads"), init="zeros")
        specs["bk"] = ParamSpec(L + (KV * hd,), ("layers", "kv_heads"), init="zeros")
        specs["bv"] = ParamSpec(L + (KV * hd,), ("layers", "kv_heads"), init="zeros")
    return specs


def _qkv(p, x, cfg: ArchConfig, positions):
    B, S, d = x.shape
    hd, H, KV = cfg.hd(), cfg.n_heads, cfg.n_kv_heads
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if positions is not None:
        q = ops.apply_rope(q, positions, cfg.rope_theta)
        k = ops.apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def attention_apply(p, x, cfg: ArchConfig, *, positions, causal=True,
                    window=0, kv: Optional[tuple] = None):
    """Full-sequence attention. ``kv`` overrides keys/values (cross-attn)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    if kv is not None:
        k, v = kv
        causal = False
    out = ops.attention(q, k, v, causal=causal, window=window)
    out = out.reshape(B, S, -1)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))


def attention_decode(p, x, cfg: ArchConfig, cache_k, cache_v, pos, *,
                     window=0, ring=False, cross_kv=None):
    """x: (B, 1, d). cache_k/v: (B, Sc, KV, hd). Returns (out, k', v')."""
    B, _, d = x.shape
    Sc = cache_k.shape[1]
    slot = pos % Sc if ring else pos
    q, k, v = _qkv(p, x, cfg, pos[None] if pos.ndim == 0 else pos)
    if cross_kv is None:
        cache_k = ops.cache_update(cache_k, k[:, 0], slot)
        cache_v = ops.cache_update(cache_v, v[:, 0], slot)
        eff_pos = jnp.minimum(pos, Sc - 1) if ring else pos
        out = ops.decode_attention(q[:, 0], cache_k, cache_v,
                                   Sc - 1 if ring else pos,
                                   window=0 if ring else window)
    else:
        ck, cv = cross_kv
        out = ops.decode_attention(q[:, 0], ck, cv, ck.shape[1] - 1)
    out = jnp.einsum("bh,hd->bd", out.reshape(B, -1),
                     p["wo"].astype(x.dtype))
    return out[:, None], cache_k, cache_v


# --------------------------------------------------------------------------
# Dense SwiGLU FFN
# --------------------------------------------------------------------------

def ffn_specs(cfg: ArchConfig, layers: int) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    L = (layers,)
    return {
        "wg": ParamSpec(L + (d, f), ("layers", "fsdp", "mlp")),
        "wu": ParamSpec(L + (d, f), ("layers", "fsdp", "mlp")),
        "wd": ParamSpec(L + (f, d), ("layers", "mlp", "fsdp")),
    }


def ffn_apply(p, x):
    return ops.swiglu(x, p["wg"], p["wu"], p["wd"])


# --------------------------------------------------------------------------
# Mamba-2 style selective-SSM mixer (Hymba's parallel SSM heads)
# --------------------------------------------------------------------------

def _causal_conv(x, kernel):
    """Depthwise causal conv. x: (B,S,C); kernel: (W,C)."""
    W = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    S = x.shape[1]
    for j in range(W):
        out = out + kernel[j].astype(jnp.float32) * xp[:, j:j + S].astype(jnp.float32)
    return out.astype(x.dtype)


def mamba_specs(cfg: ArchConfig, layers: int) -> dict:
    d = cfg.d_model
    di = cfg.ssm.expand * d
    N = cfg.ssm.state_dim
    Hm = max(1, di // 64)
    L = (layers,)
    return {
        "wx": ParamSpec(L + (d, di), ("layers", "fsdp", "mlp")),
        "wz": ParamSpec(L + (d, di), ("layers", "fsdp", "mlp")),
        "wB": ParamSpec(L + (d, N), ("layers", "fsdp", "state")),
        "wC": ParamSpec(L + (d, N), ("layers", "fsdp", "state")),
        "wdt": ParamSpec(L + (d, Hm), ("layers", "fsdp", "heads")),
        "dt_bias": ParamSpec(L + (Hm,), ("layers", "heads"), init="zeros"),
        "A_log": ParamSpec(L + (Hm,), ("layers", "heads"), init="zeros"),
        "Dskip": ParamSpec(L + (Hm,), ("layers", "heads"), init="ones"),
        "conv": ParamSpec(L + (cfg.ssm.conv_width, di), ("layers", "conv", "mlp"),
                          init="normal", scale=0.5),
        "wout": ParamSpec(L + (di, d), ("layers", "mlp", "fsdp")),
    }


def _mamba_qkv(p, x, cfg: ArchConfig):
    B, S, d = x.shape
    di = cfg.ssm.expand * d
    N = cfg.ssm.state_dim
    Hm = max(1, di // 64)
    hp = di // Hm
    xm = jnp.einsum("bsd,de->bse", x, p["wx"].astype(x.dtype))
    z = jnp.einsum("bsd,de->bse", x, p["wz"].astype(x.dtype))
    return xm, z, di, N, Hm, hp


def mamba_apply(p, x, cfg: ArchConfig):
    B, S, d = x.shape
    xm, z, di, N, Hm, hp = _mamba_qkv(p, x, cfg)
    xm = jax.nn.silu(_causal_conv(xm, p["conv"]).astype(jnp.float32)).astype(x.dtype)
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"].astype(x.dtype))
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"].astype(x.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(x.dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))       # (Hm,)
    ld = dt * A                                         # (B,S,Hm) <= 0
    q = jnp.broadcast_to(Cm[:, :, None, :], (B, S, Hm, N))
    k = jnp.broadcast_to(Bm[:, :, None, :], (B, S, Hm, N))
    v = xm.reshape(B, S, Hm, hp) * dt[..., None].astype(x.dtype)
    y = la.chunked(q, k, v, ld, chunk=cfg.ssm.chunk)
    y = y + xm.reshape(B, S, Hm, hp) * p["Dskip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, di) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["wout"].astype(x.dtype))


class MambaCache(NamedTuple):
    state: la.LinState
    conv: jax.Array        # (B, W-1, di) trailing inputs


def mamba_cache_shape(cfg: ArchConfig, B):
    d = cfg.d_model
    di = cfg.ssm.expand * d
    N = cfg.ssm.state_dim
    Hm = max(1, di // 64)
    hp = di // Hm
    return MambaCache(
        la.LinState(jnp.zeros((B, Hm, N, hp), jnp.float32),
                    jnp.zeros((B, Hm, N), jnp.float32),
                    jnp.zeros((B, Hm), jnp.float32)),
        jnp.zeros((B, cfg.ssm.conv_width - 1, di), jnp.float32))


def mamba_decode(p, x, cfg: ArchConfig, cache: MambaCache):
    """x: (B,1,d) -> (out (B,1,d), new cache)."""
    B, _, d = x.shape
    xm, z, di, N, Hm, hp = _mamba_qkv(p, x, cfg)
    hist = jnp.concatenate([cache.conv, xm.astype(jnp.float32)], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", hist,
                          p["conv"].astype(jnp.float32))
    xm1 = jax.nn.silu(conv_out).astype(x.dtype)         # (B,di)
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"].astype(x.dtype))[:, 0]
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"].astype(x.dtype))[:, 0]
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(x.dtype)).astype(jnp.float32)[:, 0]
        + p["dt_bias"].astype(jnp.float32))             # (B,Hm)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    ld = dt * A
    q = jnp.broadcast_to(Cm[:, None, :], (B, Hm, N))
    k = jnp.broadcast_to(Bm[:, None, :], (B, Hm, N))
    v = xm1.reshape(B, Hm, hp) * dt[..., None].astype(x.dtype)
    st, y = la.decode_step(cache.state, q, k, v, ld)
    y = y.astype(x.dtype) + xm1.reshape(B, Hm, hp) * p["Dskip"].astype(x.dtype)[None, :, None]
    y = y.reshape(B, di) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)[:, 0]
    out = jnp.einsum("be,ed->bd", y, p["wout"].astype(x.dtype))
    new_cache = MambaCache(st, hist[:, 1:])
    return out[:, None], new_cache
