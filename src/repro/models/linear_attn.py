"""Chunkwise-parallel linear attention with gating.

One engine serves both recurrent families in the model zoo:
  * mLSTM (xLSTM): exponential input gate + sigmoid forget gate, running
    max-stabilizer, normalizer state  -> ``stabilize=True, normalize=True``
  * Mamba-2 / SSD (Hymba's SSM heads): scalar per-head decay from dt·A,
    no input gate / normalizer        -> ``stabilize=False``

Recurrence (per head):
    S_t = exp(ld_t) S_{t-1} + exp(li_t) k_t v_t^T
    n_t = exp(ld_t) n_{t-1} + exp(li_t) k_t
    y_t = q_t S_t   [ / max(|q_t n_t|, 1) when normalize ]

The chunked form computes, per chunk of width W, the intra-chunk part as a
decay-masked (W, W) attention and carries (S, n, m) across chunks — the
standard TPU-friendly formulation (quadratic only within the chunk, MXU
matmuls throughout). A step-by-step ``reference_scan`` is provided for the
test oracle.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG = -1e30


class LinState(NamedTuple):
    S: jax.Array      # (B, H, N, P)
    n: jax.Array      # (B, H, N)
    m: jax.Array      # (B, H) running stabilizer (log-space)


def init_state(B, H, N, P, dtype=jnp.float32) -> LinState:
    return LinState(jnp.zeros((B, H, N, P), dtype),
                    jnp.zeros((B, H, N), dtype),
                    jnp.zeros((B, H), dtype))


def _chunk(x, W):
    B, S = x.shape[:2]
    return x.reshape(B, S // W, W, *x.shape[2:])


def chunked(q, k, v, log_decay, log_in=None, *, chunk=128,
            normalize=False, stabilize=False,
            state: Optional[LinState] = None, return_state=False):
    """q,k: (B,S,H,N); v: (B,S,H,P); log_decay/log_in: (B,S,H)."""
    B, S, H, N = q.shape
    P = v.shape[-1]
    W = min(chunk, S)
    assert S % W == 0
    if log_in is None:
        log_in = jnp.zeros_like(log_decay)
    if state is None:
        state = init_state(B, H, N, P)

    qc, kc, vc = (_chunk(x.astype(jnp.float32), W) for x in (q, k, v))
    ldc, lic = _chunk(log_decay.astype(jnp.float32), W), _chunk(
        log_in.astype(jnp.float32), W)
    nchunks = S // W
    tri = jnp.tril(jnp.ones((W, W), bool))              # s <= t

    def step(carry, xs):
        Sst, nst, mst = carry
        qb, kb, vb, ldb, lib = xs                       # (B,W,H,*) / (B,W,H)
        cum = jnp.cumsum(ldb, axis=1)                   # (B,W,H) inclusive
        if stabilize:
            # m_t = max(m_prev + cum_t, cum_t + cummax_{s<=t}(li_s - cum_s))
            inner = jax.lax.cummax(lib - cum, axis=1)
            m_t = jnp.maximum(mst[:, None] + cum, cum + inner)   # (B,W,H)
        else:
            m_t = jnp.zeros_like(cum)
        # inter-chunk: y += exp(cum_t + m_prev - m_t) * q_t  S_prev
        inter_w = jnp.exp(cum + mst[:, None] - m_t)     # (B,W,H)
        y_inter = jnp.einsum("bthn,bhnp->bthp", qb, Sst) * inter_w[..., None]
        d_inter = jnp.einsum("bthn,bhn->bth", qb, nst) * inter_w
        # intra-chunk decay matrix D(t,s) = exp(cum_t - cum_s + li_s - m_t)
        logD = (cum[:, :, None] - cum[:, None, :] + lib[:, None, :]
                - m_t[:, :, None])                      # (B,t,s,H)
        logD = jnp.where(tri[None, :, :, None], logD, NEG)
        D = jnp.exp(logD)
        scores = jnp.einsum("bthn,bshn->btsh", qb, kb) * D
        y_intra = jnp.einsum("btsh,bshp->bthp", scores, vb)
        d_intra = scores.sum(axis=2)                    # (B,t,H)
        y = y_inter + y_intra                           # (B,W,H,P)
        if normalize:
            den = d_inter + d_intra
            y = y / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # carry update (evaluate at t = W)
        cW = cum[:, -1]                                 # (B,H)
        mW = m_t[:, -1]
        Snew = Sst * jnp.exp(cW + mst - mW)[..., None, None]
        upd_w = jnp.exp(cW[:, None] - cum + lib - mW[:, None])  # (B,W,H)
        Snew = Snew + jnp.einsum("bshn,bshp->bhnp", kb * upd_w[..., None], vb)
        nnew = (nst * jnp.exp(cW + mst - mW)[..., None]
                + jnp.einsum("bshn->bhn", kb * upd_w[..., None]))
        return LinState(Snew, nnew, mW), y

    xs = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), ldc.transpose(1, 0, 2, 3),
          lic.transpose(1, 0, 2, 3))
    from repro.models.ops import scan_unroll
    final, ys = jax.lax.scan(step, state, xs, unroll=scan_unroll())
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P).astype(q.dtype)
    if return_state:
        return y, final
    return y


def decode_step(state: LinState, q, k, v, log_decay, log_in=None, *,
                normalize=False, stabilize=False):
    """Single-token recurrent update. q,k: (B,H,N); v: (B,H,P); gates (B,H)."""
    if log_in is None:
        log_in = jnp.zeros_like(log_decay)
    q, k, v = (x.astype(jnp.float32) for x in (q, k, v))
    ld, li = log_decay.astype(jnp.float32), log_in.astype(jnp.float32)
    if stabilize:
        m_new = jnp.maximum(state.m + ld, li)
    else:
        m_new = jnp.zeros_like(state.m)
    fw = jnp.exp(ld + state.m - m_new)
    iw = jnp.exp(li - m_new)
    S = state.S * fw[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", k * iw[..., None], v)
    n = state.n * fw[..., None] + k * iw[..., None]
    y = jnp.einsum("bhn,bhnp->bhp", q, S)
    if normalize:
        den = jnp.einsum("bhn,bhn->bh", q, n)
        y = y / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    return LinState(S, n, m_new), y.astype(jnp.float32)


def reference_scan(q, k, v, log_decay, log_in=None, *, normalize=False,
                   stabilize=False, state: Optional[LinState] = None):
    """Step-by-step oracle for tests (identical math, O(S) scan)."""
    B, S, H, N = q.shape
    P = v.shape[-1]
    if state is None:
        state = init_state(B, H, N, P)
    if log_in is None:
        log_in = jnp.zeros_like(log_decay)

    def step(st, xs):
        qt, kt, vt, ldt, lit = xs
        st2, y = decode_step(st, qt, kt, vt, ldt, lit,
                             normalize=normalize, stabilize=stabilize)
        return st2, y

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), log_decay.transpose(1, 0, 2),
          log_in.transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3).astype(q.dtype), final
