"""Encoder-decoder backbone (SeamlessM4T-medium assignment).

The audio frontend (mel-spectrogram + conformer feature extractor) is a
STUB per the assignment: the batch carries precomputed frame embeddings
``src_embeds`` (B, S_src, d_model). We implement the transformer encoder
over those frames and the causal decoder with cross-attention.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import blocks, ops
from repro.models.param import ParamSpec


def src_len(cfg: ArchConfig, seq_len: int) -> int:
    """Frame count from the (stubbed) frontend: 1 frame per 4 tokens."""
    return max(16, seq_len // 4)


def param_specs(cfg: ArchConfig) -> dict:
    d, V = cfg.d_model, cfg.vocab
    Le, Ld = cfg.n_encoder_layers, cfg.n_layers
    enc = {
        "attn_norm": ParamSpec((Le, d), ("layers", "embed"), init="ones"),
        "attn": blocks.attention_specs(cfg, Le),
        "ffn_norm": ParamSpec((Le, d), ("layers", "embed"), init="ones"),
        "ffn": blocks.ffn_specs(cfg, Le),
    }
    dec = {
        "self_norm": ParamSpec((Ld, d), ("layers", "embed"), init="ones"),
        "self_attn": blocks.attention_specs(cfg, Ld),
        "cross_norm": ParamSpec((Ld, d), ("layers", "embed"), init="ones"),
        "cross_attn": blocks.attention_specs(cfg, Ld),
        "ffn_norm": ParamSpec((Ld, d), ("layers", "embed"), init="ones"),
        "ffn": blocks.ffn_specs(cfg, Ld),
    }
    return {
        "embed": ParamSpec((V, d), ("vocab", "embed"), init="embed", scale=0.02),
        "enc_final_norm": ParamSpec((d,), ("embed",), init="ones"),
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
        "lm_head": ParamSpec((d, V), ("embed", "vocab")),
        "enc_layers": enc,
        "dec_layers": dec,
    }


def enc_block(lp, h, cfg: ArchConfig, positions):
    x = ops.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
    h = h + blocks.attention_apply(lp["attn"], x, cfg,
                                   positions=positions, causal=False)
    x = ops.rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
    h = h + blocks.ffn_apply(lp["ffn"], x)
    return shard(h, "batch", "residual_seq", None)


def encode(params, src_embeds, cfg: ArchConfig):
    """src_embeds: (B, S_src, d) -> encoder memory (B, S_src, d)."""
    h = shard(src_embeds.astype(cfg.cdtype()), "batch", None, None)
    S = h.shape[1]
    positions = jnp.arange(S)

    def one(h, lp):
        return enc_block(lp, h, cfg, positions), None

    body = jax.checkpoint(one) if cfg.remat else one
    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return ops.rms_norm(h, params["enc_final_norm"], cfg.norm_eps)


def _cross_kv(lp_cross, memory, cfg: ArchConfig):
    """Precompute K/V of the encoder memory for one decoder layer."""
    B, S, _ = memory.shape
    KV, hd = cfg.n_kv_heads, cfg.hd()
    k = jnp.einsum("bsd,dh->bsh", memory,
                   lp_cross["wk"].astype(memory.dtype)).reshape(B, S, KV, hd)
    v = jnp.einsum("bsd,dh->bsh", memory,
                   lp_cross["wv"].astype(memory.dtype)).reshape(B, S, KV, hd)
    return k, v


def dec_block(lp, h, memory, cfg: ArchConfig, positions):
    x = ops.rms_norm(h, lp["self_norm"], cfg.norm_eps)
    h = h + blocks.attention_apply(lp["self_attn"], x, cfg,
                                   positions=positions, causal=True)
    x = ops.rms_norm(h, lp["cross_norm"], cfg.norm_eps)
    kv = _cross_kv(lp["cross_attn"], memory, cfg)
    h = h + blocks.attention_apply(lp["cross_attn"], x, cfg,
                                   positions=positions, kv=kv)
    x = ops.rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
    h = h + blocks.ffn_apply(lp["ffn"], x)
    return shard(h, "batch", "residual_seq", None)


def dec_decode_block(lp, h, cfg: ArchConfig, ck, cv, xk, xv, pos, ring):
    x = ops.rms_norm(h, lp["self_norm"], cfg.norm_eps)
    a, ck2, cv2 = blocks.attention_decode(lp["self_attn"], x, cfg,
                                          ck, cv, pos, ring=ring)
    h = h + a
    x = ops.rms_norm(h, lp["cross_norm"], cfg.norm_eps)
    a, _, _ = blocks.attention_decode(lp["cross_attn"], x, cfg,
                                      ck, cv, pos, cross_kv=(xk, xv))
    h = h + a
    x = ops.rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
    return h + blocks.ffn_apply(lp["ffn"], x), ck2, cv2


def decode_stack(params, h, memory, cfg: ArchConfig):
    positions = jnp.arange(h.shape[1])

    def one(h, lp):
        return dec_block(lp, h, memory, cfg, positions), None

    body = jax.checkpoint(one) if cfg.remat else one
    h, _ = jax.lax.scan(body, h, params["dec_layers"])
    return ops.rms_norm(h, params["final_norm"], cfg.norm_eps)


def loss_fn(params, batch, cfg: ArchConfig):
    memory = encode(params, batch["src_embeds"], cfg)
    h = params["embed"].astype(cfg.cdtype())[batch["tokens"]]
    h = h * jnp.asarray(np.sqrt(cfg.d_model), cfg.cdtype())
    h = shard(h, "batch", None, None)
    h = decode_stack(params, h, memory, cfg)
    tot, cnt = ops.chunked_softmax_xent(h, params["lm_head"],
                                        batch["targets"], chunk=cfg.loss_chunk,
                                        mask=batch.get("loss_mask"))
    xent = tot / jnp.maximum(cnt, 1.0)
    return xent, {"xent": xent, "aux": jnp.float32(0), "tokens": cnt}


def logits_fn(params, batch, cfg: ArchConfig):
    memory = encode(params, batch["src_embeds"], cfg)
    h = params["embed"].astype(cfg.cdtype())[batch["tokens"]]
    h = h * jnp.asarray(np.sqrt(cfg.d_model), cfg.cdtype())
    h = decode_stack(params, h, memory, cfg)
    return jnp.einsum("bd,dv->bv", h[:, -1],
                      params["lm_head"].astype(h.dtype),
                      preferred_element_type=jnp.float32)


class EncDecCache(NamedTuple):
    k: Any           # (L, B, Sc, KV, hd) decoder self-attention
    v: Any
    cross_k: Any     # (L, B, S_src, KV, hd) precomputed encoder K/V
    cross_v: Any


def init_cache(cfg: ArchConfig, B: int, seq_len: int, abstract=False):
    from repro.models.lm import cache_len
    Lc = cache_len(cfg, seq_len)
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd()
    Ss = src_len(cfg, min(seq_len, 32768))
    dt = cfg.cdtype()

    def mk(shape):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    return EncDecCache(mk((L, B, Lc, KV, hd)), mk((L, B, Lc, KV, hd)),
                       mk((L, B, Ss, KV, hd)), mk((L, B, Ss, KV, hd)))


def prefill_cache(params, batch, cfg: ArchConfig, B, seq_len):
    """Build the decode cache: encode source, precompute cross K/V."""
    memory = encode(params, batch["src_embeds"], cfg)

    def one(_, lp):
        return None, _cross_kv(lp["cross_attn"], memory, cfg)

    _, (ck, cv) = jax.lax.scan(one, None, params["dec_layers"])
    base = init_cache(cfg, B, seq_len)
    return base._replace(cross_k=ck, cross_v=cv)


def cache_logical(cfg: ArchConfig):
    kv = ("layers", "batch", "kvseq", "kv_heads", None)
    xkv = ("layers", "batch", "frames", "kv_heads", None)
    return EncDecCache(kv, kv, xkv, xkv)


def decode_step(params, cache: EncDecCache, batch, cfg: ArchConfig,
                seq_len: int):
    from repro.models.lm import cache_len
    pos = batch["pos"]
    Lc = cache_len(cfg, seq_len)
    ring = Lc < seq_len
    h = params["embed"].astype(cfg.cdtype())[batch["tokens"]]
    h = h * jnp.asarray(np.sqrt(cfg.d_model), cfg.cdtype())
    h = shard(h, "batch", None, None)

    def one(h, xs):
        lp, ck, cv, xk, xv = xs
        h, ck2, cv2 = dec_decode_block(lp, h, cfg, ck, cv, xk, xv, pos, ring)
        return h, (ck2, cv2)

    h, (ck, cv) = jax.lax.scan(
        one, h, (params["dec_layers"], cache.k, cache.v,
                 cache.cross_k, cache.cross_v))
    h = ops.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, 0],
                        params["lm_head"].astype(h.dtype),
                        preferred_element_type=jnp.float32)
    return logits, cache._replace(k=ck, v=cv)
