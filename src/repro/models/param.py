"""Parameter specification & materialization.

Models declare their parameters as a pytree of ``ParamSpec`` (shape, logical
axis names, initializer). From the same spec tree we derive:
  * concrete initialized params      (``materialize``)
  * ``jax.ShapeDtypeStruct`` stand-ins for dry-runs (``abstractify``)
  * ``PartitionSpec`` trees for pjit (``logical_to_pspec`` in
    repro.distributed.sharding)

Logical axis names used across the model zoo:
  batch, seq, kvseq, embed, mlp, heads, kv_heads, qkv, vocab, experts,
  layers, conv, state, null
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed
    scale: float = 1.0            # stddev multiplier (fan-in handled here)
    dtype: Optional[str] = None   # override param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(key, spec: ParamSpec, dtype) -> jax.Array:
    dt = jnp.dtype(spec.dtype) if spec.dtype else dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "embed":
        std = spec.scale
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dt)
    # fan-in scaled normal: last-but-one significant dim treated as fan-in
    fan_in = spec.shape[0] if len(spec.shape) == 1 else int(np.prod(spec.shape[:-1]))
    # stacked layer dim doesn't contribute to fan-in
    if spec.logical and spec.logical[0] == "layers" and len(spec.shape) > 2:
        fan_in = int(np.prod(spec.shape[1:-1]))
    std = spec.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dt)


def materialize(rng, specs, dtype) -> dict:
    """Initialize a concrete param pytree from a spec tree."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def abstractify(specs, dtype, shardings=None) -> dict:
    """ShapeDtypeStruct tree (for .lower() without allocation)."""
    def leaf(s: ParamSpec, sh=None):
        dt = jnp.dtype(s.dtype) if s.dtype else dtype
        if sh is not None:
            return jax.ShapeDtypeStruct(s.shape, dt, sharding=sh)
        return jax.ShapeDtypeStruct(s.shape, dt)
    if shardings is None:
        return jax.tree.map(leaf, specs, is_leaf=is_spec)
    return jax.tree.map(leaf, specs, shardings, is_leaf=is_spec)


def logical_axes(specs):
    """Pytree of logical-axis tuples matching the param tree."""
    return jax.tree.map(lambda s: s.logical, specs, is_leaf=is_spec)


def param_count(specs) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=is_spec))
