"""Uniform Model facade: build any assigned architecture from its config."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, lm
from repro.models.param import abstractify, logical_axes, materialize, param_count


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    specs: Any
    loss_fn: Callable            # (params, batch) -> (loss, metrics)
    logits_fn: Callable          # (params, batch) -> (B, V) final-pos logits
    init_cache: Callable         # (B, seq_len, abstract=False) -> cache
    decode_step: Callable        # (params, cache, batch, seq_len) -> (logits, cache)
    cache_logical: Callable      # () -> logical-axis tree matching the cache
    prefill_cache: Optional[Callable] = None

    def init(self, rng) -> dict:
        return materialize(rng, self.specs, self.cfg.pdtype())

    def abstract_params(self, shardings=None):
        return abstractify(self.specs, self.cfg.pdtype(), shardings)

    def logical_axes(self):
        return logical_axes(self.specs)

    def n_params(self) -> int:
        return param_count(self.specs)


def build_model(cfg: ArchConfig) -> Model:
    if cfg.arch_type == "audio":
        return Model(
            cfg=cfg,
            specs=encdec.param_specs(cfg),
            loss_fn=lambda p, b: encdec.loss_fn(p, b, cfg),
            logits_fn=lambda p, b: encdec.logits_fn(p, b, cfg),
            init_cache=lambda B, S, abstract=False: encdec.init_cache(
                cfg, B, S, abstract),
            decode_step=lambda p, c, b, S: encdec.decode_step(p, c, b, cfg, S),
            cache_logical=lambda: encdec.cache_logical(cfg),
            prefill_cache=lambda p, b, B, S: encdec.prefill_cache(p, b, cfg, B, S),
        )
    return Model(
        cfg=cfg,
        specs=lm.param_specs(cfg),
        loss_fn=lambda p, b: lm.loss_fn(p, b, cfg),
        logits_fn=lambda p, b: lm.logits_fn(p, b, cfg),
        init_cache=lambda B, S, abstract=False: lm.init_cache(cfg, B, S, abstract),
        decode_step=lambda p, c, b, S: lm.decode_step(p, c, b, cfg, S),
        cache_logical=lambda: lm.cache_logical(cfg),
    )
