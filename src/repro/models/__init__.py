"""Model zoo (10 reduced-config architectures) and registry."""
