"""xLSTM blocks (mLSTM + sLSTM) [arXiv:2405.04517].

mLSTM: matrix-memory LSTM — exponential input gate, sigmoid forget gate,
running-max stabilizer and normalizer state. Computed with the shared
chunkwise linear-attention engine (``stabilize=True, normalize=True``).

sLSTM: scalar-memory recurrent cell with block-diagonal (per-head)
recurrent weights — inherently sequential, computed with lax.scan over
time (TPU adaptation note: the original CUDA kernel fuses the step; on TPU
the scan body is a small fused VPU program, which is the idiomatic
equivalent).

Blocks alternate mLSTM/sLSTM (``cfg.slstm_every == 2``): the layer stack is
scanned over *pairs* so scan params stay homogeneous.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import linear_attn as la
from repro.models import ops
from repro.models.blocks import _causal_conv
from repro.models.param import ParamSpec


def _dims(cfg: ArchConfig):
    d = cfg.d_model
    di = cfg.ssm.expand * d        # mLSTM inner dim
    H = cfg.n_heads
    hp = di // H                   # mLSTM per-head value dim
    return d, di, H, hp


# --------------------------- mLSTM ----------------------------------------

def mlstm_specs(cfg: ArchConfig, layers: int) -> dict:
    d, di, H, hp = _dims(cfg)
    L = (layers,)
    return {
        "norm": ParamSpec(L + (d,), ("layers", "embed"), init="ones"),
        "w_up": ParamSpec(L + (d, 2 * di), ("layers", "fsdp", "mlp")),
        "conv": ParamSpec(L + (cfg.ssm.conv_width, di),
                          ("layers", "conv", "mlp"), init="normal", scale=0.5),
        "wq": ParamSpec(L + (di, di), ("layers", "mlp", "heads")),
        "wk": ParamSpec(L + (di, di), ("layers", "mlp", "heads")),
        "wv": ParamSpec(L + (di, di), ("layers", "mlp", "heads")),
        "w_if": ParamSpec(L + (di, 2 * H), ("layers", "mlp", "heads"),
                          init="zeros"),
        "b_if": ParamSpec(L + (2 * H,), ("layers", "heads"), init="zeros"),
        "gnorm": ParamSpec(L + (di,), ("layers", "mlp"), init="ones"),
        "w_down": ParamSpec(L + (di, d), ("layers", "mlp", "fsdp")),
    }


def _mlstm_inner(p, xm, cfg: ArchConfig):
    """xm: (B,S,di) post-conv. Returns q,k,v,(ld,li) for the engine."""
    B, S, di = xm.shape
    d, _, H, hp = _dims(cfg)
    q = jnp.einsum("bse,eh->bsh", xm, p["wq"].astype(xm.dtype)).reshape(B, S, H, hp)
    k = jnp.einsum("bse,eh->bsh", xm, p["wk"].astype(xm.dtype)).reshape(B, S, H, hp)
    k = k / (hp ** 0.5)
    v = jnp.einsum("bse,eh->bsh", xm, p["wv"].astype(xm.dtype)).reshape(B, S, H, hp)
    gates = (jnp.einsum("bse,eh->bsh", xm, p["w_if"].astype(xm.dtype))
             .astype(jnp.float32) + p["b_if"].astype(jnp.float32))
    li, f_raw = gates[..., :H], gates[..., H:]
    ld = jax.nn.log_sigmoid(f_raw)
    return q, k, v, ld, li


def mlstm_apply(p, h, cfg: ArchConfig):
    B, S, d = h.shape
    _, di, H, hp = _dims(cfg)
    x = ops.rms_norm(h, p["norm"], cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", x, p["w_up"].astype(x.dtype))
    xm, z = up[..., :di], up[..., di:]
    xm = jax.nn.silu(_causal_conv(xm, p["conv"]).astype(jnp.float32)).astype(x.dtype)
    q, k, v, ld, li = _mlstm_inner(p, xm, cfg)
    y = la.chunked(q, k, v, ld, li, chunk=cfg.ssm.chunk,
                   normalize=True, stabilize=True)
    y = y.reshape(B, S, di)
    y = ops.rms_norm(y, p["gnorm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return h + jnp.einsum("bse,ed->bsd", y, p["w_down"].astype(x.dtype))


class MLSTMCache(NamedTuple):
    state: la.LinState
    conv: jax.Array


def mlstm_cache(cfg: ArchConfig, B):
    d, di, H, hp = _dims(cfg)
    return MLSTMCache(la.init_state(B, H, hp, hp),
                      jnp.zeros((B, cfg.ssm.conv_width - 1, di), jnp.float32))


def mlstm_decode(p, h, cfg: ArchConfig, cache: MLSTMCache):
    B, _, d = h.shape
    _, di, H, hp = _dims(cfg)
    x = ops.rms_norm(h, p["norm"], cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", x, p["w_up"].astype(x.dtype))
    xm, z = up[..., :di], up[..., di:]
    hist = jnp.concatenate([cache.conv, xm.astype(jnp.float32)], axis=1)
    xm1 = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist,
                                 p["conv"].astype(jnp.float32)))
    xm1 = xm1.astype(x.dtype)[:, None]                    # (B,1,di)
    q, k, v, ld, li = _mlstm_inner(p, xm1, cfg)
    st, y = la.decode_step(cache.state, q[:, 0], k[:, 0], v[:, 0],
                           ld[:, 0], li[:, 0], normalize=True, stabilize=True)
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = ops.rms_norm(y, p["gnorm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = h + jnp.einsum("bse,ed->bsd", y, p["w_down"].astype(x.dtype))
    return out, MLSTMCache(st, hist[:, 1:])


# --------------------------- sLSTM ----------------------------------------

def slstm_specs(cfg: ArchConfig, layers: int) -> dict:
    d, _, H, _ = _dims(cfg)
    hs = d // H                     # per-head scalar-memory width
    fup = (8 * d) // 6              # post-block gated FFN (factor 4/3)
    L = (layers,)
    return {
        "norm": ParamSpec(L + (d,), ("layers", "embed"), init="ones"),
        "w_gates": ParamSpec(L + (d, 4 * d), ("layers", "fsdp", "mlp")),
        "r_gates": ParamSpec(L + (H, hs, 4 * hs), ("layers", "heads", None, None),
                             init="normal", scale=0.5),
        "b_gates": ParamSpec(L + (4 * d,), ("layers", "mlp"), init="zeros"),
        "gnorm": ParamSpec(L + (d,), ("layers", "embed"), init="ones"),
        "up_norm": ParamSpec(L + (d,), ("layers", "embed"), init="ones"),
        "w_up": ParamSpec(L + (d, 2 * fup), ("layers", "fsdp", "mlp")),
        "w_down": ParamSpec(L + (fup, d), ("layers", "mlp", "fsdp")),
    }


class SLSTMState(NamedTuple):
    h: jax.Array   # (B, d)
    c: jax.Array
    n: jax.Array
    m: jax.Array


def slstm_state(cfg: ArchConfig, B):
    d = cfg.d_model
    z = jnp.zeros((B, d), jnp.float32)
    return SLSTMState(z, z, z, z)


def _slstm_step(p, st: SLSTMState, wx_t, cfg: ArchConfig):
    """wx_t: (B, 4d) precomputed input part. Returns (new state, h_out)."""
    d, _, H, _ = _dims(cfg)
    hs = d // H
    B = wx_t.shape[0]
    hprev = st.h.reshape(B, H, hs)
    rec = jnp.einsum("bhs,hsg->bhg", hprev,
                     p["r_gates"].astype(jnp.float32)).reshape(B, 4 * d)
    g = (wx_t + rec).reshape(B, 4, d)
    li, lf_raw, z_raw, o_raw = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
    lf = jax.nn.log_sigmoid(lf_raw)
    m_new = jnp.maximum(lf + st.m, li)
    i_p = jnp.exp(li - m_new)
    f_p = jnp.exp(lf + st.m - m_new)
    c = f_p * st.c + i_p * jnp.tanh(z_raw)
    n = f_p * st.n + i_p
    h = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1.0)
    return SLSTMState(h, c, n, m_new), h


def slstm_apply(p, hres, cfg: ArchConfig, state: SLSTMState = None):
    B, S, d = hres.shape
    x = ops.rms_norm(hres, p["norm"], cfg.norm_eps)
    wx = (jnp.einsum("bsd,dg->bsg", x, p["w_gates"].astype(x.dtype))
          .astype(jnp.float32) + p["b_gates"].astype(jnp.float32))
    st0 = state if state is not None else slstm_state(cfg, B)

    def step(st, wx_t):
        st2, h = _slstm_step(p, st, wx_t, cfg)
        return st2, h

    stN, ys = jax.lax.scan(step, st0, wx.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2).astype(x.dtype)             # (B,S,d)
    y = ops.rms_norm(y, p["gnorm"], cfg.norm_eps)
    h1 = hres + y
    # gated up/down projection (xLSTM post-sLSTM FFN, factor 4/3)
    x2 = ops.rms_norm(h1, p["up_norm"], cfg.norm_eps)
    up = jnp.einsum("bsd,df->bsf", x2, p["w_up"].astype(x2.dtype))
    a, b = jnp.split(up, 2, axis=-1)
    hmid = jax.nn.gelu(a.astype(jnp.float32)).astype(x2.dtype) * b
    out = h1 + jnp.einsum("bsf,fd->bsd", hmid, p["w_down"].astype(x2.dtype))
    return (out, stN) if state is not None else out


def slstm_decode(p, hres, cfg: ArchConfig, state: SLSTMState):
    out, st = slstm_apply(p, hres, cfg, state=state)
    return out, st
