"""Capacity-based top-k Mixture-of-Experts layer (expert-parallel).

Dispatch is the cumsum/position-in-expert formulation (Switch/T5X style),
realized with gather/scatter instead of the (tokens, experts, capacity)
one-hot einsum — the one-hot dispatch tensor is infeasible at the assigned
scales (1M tokens x 128 experts x 80k capacity). Experts are sharded over
the ``model`` mesh axis ('experts' logical axis); GSPMD turns the
scatter/gather into the expert-parallel all-to-all pattern.

DeepSeekMoE-style shared experts are dense SwiGLU paths added on top.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models.param import ParamSpec


def moe_specs(cfg: ArchConfig, layers: int) -> dict:
    d = cfg.d_model
    m = cfg.moe
    L = (layers,)
    specs = {
        "router": ParamSpec(L + (d, m.n_experts), ("layers", "fsdp", "experts")),
        "we_g": ParamSpec(L + (m.n_experts, d, m.expert_ff),
                          ("layers", "experts", "fsdp", "expert_mlp")),
        "we_u": ParamSpec(L + (m.n_experts, d, m.expert_ff),
                          ("layers", "experts", "fsdp", "expert_mlp")),
        "we_d": ParamSpec(L + (m.n_experts, m.expert_ff, d),
                          ("layers", "experts", "expert_mlp", "fsdp")),
    }
    if m.n_shared:
        f = m.expert_ff * m.n_shared
        specs["ws_g"] = ParamSpec(L + (d, f), ("layers", "fsdp", "mlp"))
        specs["ws_u"] = ParamSpec(L + (d, f), ("layers", "fsdp", "mlp"))
        specs["ws_d"] = ParamSpec(L + (f, d), ("layers", "mlp", "fsdp"))
    return specs


def capacity(T: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    c = int(math.ceil(T * m.top_k * m.capacity_factor / m.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_apply(p, x, cfg: ArchConfig):
    """x: (B, S, d) -> (out, aux_loss).

    With an active mesh whose 'model' axis divides the expert count, this
    dispatches to the shard_map expert-parallel path (each model rank owns
    E/ep experts and processes its data shard's assignments locally — no
    token all-to-all; outputs combine with one psum_scatter). Without a
    mesh (CPU tests) it runs the GSPMD/dense-dispatch reference path.
    """
    from repro.distributed import sharding as shd
    ctx = shd.current()
    T = x.shape[0] * x.shape[1]
    if ctx is not None and "model" in ctx.mesh.axis_names:
        ep = ctx.mesh.devices.shape[ctx.mesh.axis_names.index("model")]
        # EP pays one expert-weight gather per rank per layer; only worth
        # it when there is real token work (training/prefill). Decode
        # (a handful of tokens) keeps weights sharded and moves tokens.
        if ep > 1 and cfg.moe.n_experts % ep == 0 \
                and T >= 16 * cfg.moe.n_experts:
            return _moe_apply_ep(p, x, cfg, ctx, ep)
    return _moe_apply_dense(p, x, cfg)


def _moe_apply_dense(p, x, cfg: ArchConfig):
    """Reference dispatch (single device / arbitrary sharding)."""
    B, S, d = x.shape
    m = cfg.moe
    E, K = m.n_experts, m.top_k
    T = B * S
    C = capacity(T, cfg)
    xf = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xf, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # (T,E)
    gate, eid = jax.lax.top_k(probs, K)                            # (T,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch): E * mean_e(frac_e * prob_e)
    oh = jax.nn.one_hot(eid, E, dtype=jnp.float32)                 # (T,K,E)
    frac = oh.sum(axis=(0, 1)) / (T * K)
    aux = E * jnp.sum(frac * probs.mean(axis=0))

    # position-in-expert via cumsum over flattened (T*K) assignments
    oh_flat = oh.reshape(T * K, E)
    pos = jnp.cumsum(oh_flat, axis=0) - oh_flat                    # (T*K,E)
    pos_in_e = jnp.einsum("ae,ae->a", pos, oh_flat).astype(jnp.int32)
    eid_flat = eid.reshape(T * K)
    valid = pos_in_e < C
    dest = jnp.where(valid, eid_flat * C + pos_in_e, E * C)        # drop slot

    # scatter per k-slot (K small) to avoid materializing (T*K, d)
    dest_k = dest.reshape(T, K)
    buf = jnp.zeros((E * C + 1, d), x.dtype)
    for kk in range(K):
        buf = buf.at[dest_k[:, kk]].add(xf)
    xe = buf[: E * C].reshape(E, C, d)
    xe = shard(xe, "experts", None, None)

    g = jnp.einsum("ecd,edf->ecf", xe, p["we_g"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, p["we_u"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard(h, "experts", None, "expert_mlp")
    ye = jnp.einsum("ecf,efd->ecd", h, p["we_d"].astype(x.dtype))
    ye = shard(ye, "experts", None, None)

    y_flat = jnp.concatenate([ye.reshape(E * C, d),
                              jnp.zeros((1, d), x.dtype)], axis=0)
    valid_k = valid.reshape(T, K)
    y = jnp.zeros((T, d), x.dtype)
    for kk in range(K):
        w = (gate[:, kk] * valid_k[:, kk]).astype(x.dtype)[:, None]
        y = y + y_flat[dest_k[:, kk]] * w

    if m.n_shared:
        gs = jnp.einsum("td,df->tf", xf, p["ws_g"].astype(x.dtype))
        us = jnp.einsum("td,df->tf", xf, p["ws_u"].astype(x.dtype))
        hs = jax.nn.silu(gs.astype(jnp.float32)).astype(x.dtype) * us
        y = y + jnp.einsum("tf,fd->td", hs, p["ws_d"].astype(x.dtype))

    return y.reshape(B, S, d), aux


# --------------------------------------------------------------------------
# shard_map expert-parallel path
# --------------------------------------------------------------------------

def _shared_expert(p, xf, dtype):
    gs = jnp.einsum("td,df->tf", xf, p["ws_g"].astype(dtype))
    us = jnp.einsum("td,df->tf", xf, p["ws_u"].astype(dtype))
    hs = jax.nn.silu(gs.astype(jnp.float32)).astype(dtype) * us
    return jnp.einsum("tf,fd->td", hs, p["ws_d"].astype(dtype))


def _moe_apply_ep(p, x, cfg: ArchConfig, ctx, ep: int):
    """Expert-parallel MoE: expert group e on model-rank e; each rank
    processes its own data shard's assignments to its group (the tokens
    are already resident — no all-to-all); partial outputs combine with a
    single psum(_scatter) over 'model'.

    Capacity is per-(data shard) — the t5x/Switch 'group' capacity
    semantics; with one shard it equals the dense path exactly."""
    import jax.experimental.shard_map as _sm
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    m = cfg.moe
    E, K = m.n_experts, m.top_k
    E_loc = E // ep
    B, S, d = x.shape

    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = 1
    for a in dp_axes:
        dp *= sizes[a]
    b_ax = dp_axes if (dp_axes and B % dp == 0) else None
    B_loc = B // dp if b_ax else B
    seq_shard = S % ep == 0 and S > 1
    s_ax = "model" if seq_shard else None

    T_loc = B_loc * S                       # tokens per data shard
    C = capacity(T_loc, cfg)                # per-shard capacity

    x_spec = P(b_ax, s_ax, None)
    w_spec = P("model", None, None)         # expert weights by group
    r_spec = P(None, None)                  # router replicated (tiny)

    def local(xl, router, wg, wu, wd):
        if seq_shard:
            xl = jax.lax.all_gather(xl, "model", axis=1, tiled=True)
        Bl, Sl, _ = xl.shape
        T = Bl * Sl
        xf = xl.reshape(T, d)
        logits = jnp.einsum("td,de->te", xf, router.astype(xl.dtype))
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        gate, eid = jax.lax.top_k(probs, K)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        oh = jax.nn.one_hot(eid, E, dtype=jnp.float32)
        frac = oh.sum(axis=(0, 1)) / (T * K)
        aux = E * jnp.sum(frac * probs.mean(axis=0))

        base = jax.lax.axis_index("model") * E_loc
        rel = eid - base                                  # (T,K)
        mine = (rel >= 0) & (rel < E_loc)
        # position among assignments to my group (others masked out)
        oh_loc = jnp.where(mine[..., None],
                           jax.nn.one_hot(rel, E_loc, dtype=jnp.float32),
                           0.0).reshape(T * K, E_loc)
        pos = jnp.cumsum(oh_loc, axis=0) - oh_loc
        pos_in_e = jnp.einsum("ae,ae->a", pos, oh_loc).astype(jnp.int32)
        valid = mine.reshape(T * K) & (pos_in_e < C)
        dest = jnp.where(valid,
                         jnp.clip(rel.reshape(T * K), 0, E_loc - 1) * C
                         + pos_in_e, E_loc * C)
        dest_k = dest.reshape(T, K)

        buf = jnp.zeros((E_loc * C + 1, d), xl.dtype)
        for kk in range(K):
            buf = buf.at[dest_k[:, kk]].add(xf)
        xe = buf[: E_loc * C].reshape(E_loc, C, d)

        g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(xl.dtype))
        u = jnp.einsum("ecd,edf->ecf", xe, wu.astype(xl.dtype))
        hmid = jax.nn.silu(g.astype(jnp.float32)).astype(xl.dtype) * u
        ye = jnp.einsum("ecf,efd->ecd", hmid, wd.astype(xl.dtype))

        y_flat = jnp.concatenate([ye.reshape(E_loc * C, d),
                                  jnp.zeros((1, d), xl.dtype)], axis=0)
        valid_k = valid.reshape(T, K)
        y = jnp.zeros((T, d), xl.dtype)
        for kk in range(K):
            w = (gate[:, kk] * valid_k[:, kk]).astype(xl.dtype)[:, None]
            y = y + y_flat[dest_k[:, kk]] * w
        y = y.reshape(Bl, Sl, d)
        if seq_shard:
            y = jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                     tiled=True)
        else:
            y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, dp_axes) if dp_axes else aux
        return y, aux

    fn = _sm.shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, r_spec, w_spec, w_spec, w_spec),
        out_specs=(x_spec, P()),
        check_rep=False)
    y, aux = fn(x, p["router"], p["we_g"], p["we_u"], p["we_d"])
    if m.n_shared:
        xf = x.reshape(B * S, d)
        y = y + _shared_expert(p, xf, x.dtype).reshape(B, S, d)
    return y, aux
