"""Logical-axis sharding rules (MaxText-style) + activation constraints.

A global ``MeshContext`` maps *logical* axis names used by the model code
onto *physical* mesh axes. Model code calls ``shard(x, 'batch', None,
'heads', None)`` — a no-op when no mesh is active (CPU smoke tests see a
single device and zero sharding machinery).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> physical mesh axis (or tuple of axes)
# 'pod' is folded into the data-parallel dimension.
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),   # FSDP weight shard axis
    "seq": None,
    # sequence-parallel residual stream between blocks (Megatron SP):
    # the remat-saved layer inputs shard over 'model', which is what keeps
    # the 405B/235B train shapes inside HBM (see EXPERIMENTS.md §Perf).
    "residual_seq": "model",
    "kvseq": "model",          # decode KV-cache sequence sharding
    "embed": None,
    "mlp": "model",
    "heads": "model",
    "kv_heads": None,          # few KV heads: replicate, shard Q heads
    "qkv": None,
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "layers": None,
    "conv": None,
    "state": None,
    "frames": None,
    "null": None,
}

_TLS = threading.local()


class MeshContext:
    def __init__(self, mesh: Mesh, rules: Optional[dict] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def spec(self, logical: Sequence[Optional[str]]) -> P:
        axes = []
        used = set()
        for name in logical:
            phys = self.rules.get(name) if name else None
            if phys is None:
                axes.append(None)
                continue
            phys_t = phys if isinstance(phys, tuple) else (phys,)
            phys_t = tuple(a for a in phys_t
                           if a in self.mesh.axis_names and a not in used)
            used.update(phys_t)
            if not phys_t:
                axes.append(None)
            elif len(phys_t) == 1:
                axes.append(phys_t[0])
            else:
                axes.append(phys_t)
        return P(*axes)

    def sharding(self, logical) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical))


def current() -> Optional[MeshContext]:
    return getattr(_TLS, "ctx", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[dict] = None):
    prev = current()
    _TLS.ctx = MeshContext(mesh, rules)
    try:
        with mesh:
            yield _TLS.ctx
    finally:
        _TLS.ctx = prev


def shard(x, *logical):
    """Constrain activation sharding by logical axes (no-op without mesh).

    Specs are divisibility-checked against the value's shape so odd head
    counts / tiny batches degrade to replication instead of failing."""
    ctx = current()
    if ctx is None:
        return x
    spec = safe_spec(x.shape, ctx.spec(logical), ctx.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def safe_spec(shape, spec: P, mesh: Mesh) -> P:
    """Drop axes whose size does not divide the dim (e.g. 12 heads on a
    16-way model axis) so every arch x mesh combination lowers cleanly."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        # longest prefix of the axis tuple that divides the dim
        kept = None
        for n in range(len(axes), 0, -1):
            total = 1
            for a in axes[:n]:
                total *= sizes[a]
            if dim % total == 0:
                kept = axes[0] if n == 1 else axes[:n]
                break
        out.append(kept)
    return P(*out)


def safe_sharding_tree(abstract_tree, logical_tree):
    """NamedShardings for a tree of ShapeDtypeStructs/arrays, with
    divisibility-checked specs."""
    ctx = current()
    assert ctx is not None

    def one(leaf, logical):
        spec = ctx.spec(logical)
        return NamedSharding(ctx.mesh, safe_spec(leaf.shape, spec, ctx.mesh))

    leaves, treedef = jax.tree.flatten(abstract_tree)
    logs = treedef.flatten_up_to(logical_tree)
    return treedef.unflatten([one(l, lg) for l, lg in zip(leaves, logs)])


def pspec_tree(logical_tree):
    """Map a pytree of logical-axis tuples to PartitionSpecs."""
    ctx = current()
    if ctx is None:
        return jax.tree.map(lambda _: P(), logical_tree,
                            is_leaf=lambda l: isinstance(l, tuple))
    return jax.tree.map(lambda l: ctx.spec(l), logical_tree,
                        is_leaf=lambda l: isinstance(l, tuple))


def sharding_tree(logical_tree):
    ctx = current()
    assert ctx is not None, "sharding_tree requires an active mesh"
    return jax.tree.map(lambda l: ctx.sharding(l), logical_tree,
                        is_leaf=lambda l: isinstance(l, tuple))
