"""Mesh/partition-spec machinery and the sharded step builder."""
