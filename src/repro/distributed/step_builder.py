"""Sharded (pjit) train / serve step builders for the production mesh.

Builds the in/out shardings for the full train state (params + Adam
moments + error feedback), the batch, and the decode cache from the
models' logical axes, with divisibility-safe fallback, and returns
``jax.jit``-wrapped steps ready to ``.lower()`` (dry-run) or execute.

LowDiff integration on a sharded mesh: gradients live sharded (FSDP x
TP); compression must be *shard-local* (a global reshape of a 405B
gradient would gather it). ``compress_sharded`` wraps the block top-k in
a shard_map so each device compresses — and later checkpoints — exactly
its own gradient slice. The differential checkpoint is therefore sharded
the same way as the optimizer state, and recovery is shard-local too
(beyond-paper extension; see DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.compression.sparse import SparseGrad, k_for, topk_compress
from repro.core.steps import make_train_step
from repro.data.synthetic import input_specs
from repro.distributed import sharding as shd
from repro.models.param import ParamSpec, abstractify, is_spec
from repro.optim.adam import AdamState, adam_init, adam_update


# --------------------------------------------------------------------------
# sharding trees for the train state
# --------------------------------------------------------------------------

def param_shardings(model):
    abs_params = model.abstract_params()
    return shd.safe_sharding_tree(abs_params, model.logical_axes())


def state_shardings(model, *, mode: str = "lowdiff",
                    error_feedback: bool = True) -> Dict[str, Any]:
    ctx = shd.current()
    psh = param_shardings(model)
    rep = NamedSharding(ctx.mesh, P())
    out = {"params": psh,
           "opt": AdamState(mu=psh, nu=psh, count=rep),
           "step": rep}
    if mode == "lowdiff" and error_feedback:
        out["ef"] = psh
    return out


def abstract_state(model, *, mode: str = "lowdiff",
                   error_feedback: bool = True) -> Dict[str, Any]:
    sh = state_shardings(model, mode=mode, error_feedback=error_feedback)
    pdt = model.cfg.pdtype()

    def leaf(spec: ParamSpec, s, dtype=None):
        dt = jnp.dtype(spec.dtype) if spec.dtype else (dtype or pdt)
        return jax.ShapeDtypeStruct(spec.shape, dt, sharding=s)

    params = jax.tree.map(leaf, model.specs, sh["params"], is_leaf=is_spec)
    f32 = functools.partial(leaf, dtype=jnp.float32)
    mu = jax.tree.map(f32, model.specs, sh["opt"].mu, is_leaf=is_spec)
    nu = jax.tree.map(f32, model.specs, sh["opt"].nu, is_leaf=is_spec)
    out = {"params": params,
           "opt": AdamState(mu, nu, jax.ShapeDtypeStruct(
               (), jnp.int32, sharding=sh["step"])),
           "step": jax.ShapeDtypeStruct((), jnp.int32, sharding=sh["step"])}
    if "ef" in sh:
        out["ef"] = jax.tree.map(f32, model.specs, sh["ef"], is_leaf=is_spec)
    return out


def batch_shardings(model, shape_cfg):
    ctx = shd.current()
    logical = {"tokens": ("batch", None), "targets": ("batch", None),
               "loss_mask": ("batch", None),
               "patch_embeds": ("batch", None, None),
               "src_embeds": ("batch", None, None), "pos": ()}
    specs = input_specs(model.cfg, shape_cfg)
    return {k: NamedSharding(ctx.mesh,
                             shd.safe_spec(v.shape, ctx.spec(logical[k]),
                                           ctx.mesh))
            for k, v in specs.items()}


def abstract_batch(model, shape_cfg):
    sh = batch_shardings(model, shape_cfg)
    return input_specs(model.cfg, shape_cfg, shardings=sh)


# --------------------------------------------------------------------------
# shard-local gradient compression (shard_map)
# --------------------------------------------------------------------------

def compress_sharded(grads, pspecs, mesh, rho: float):
    """Blockwise top-k on each device's *local* gradient shard."""
    leaves, treedef = jax.tree.flatten(grads)
    spec_leaves = treedef.flatten_up_to(pspecs)

    def out_spec(spec: P) -> P:
        used = []
        for ax in spec:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                used.append(a)
        first = tuple(used) if used else None
        return (P(first, None), P(first, None))

    outs = []
    for g, spec in zip(leaves, spec_leaves):
        sp = spec.spec if isinstance(spec, NamedSharding) else spec

        def local(x):
            sg = topk_compress(x, rho)
            return sg.values, sg.indices

        fn = shard_map(local, mesh=mesh, in_specs=(sp,),
                       out_specs=out_spec(sp), check_rep=False)
        vals, idx = fn(g)
        # NOTE: block order follows the shard layout (each device's local
        # flatten); the differential checkpoint is saved and replayed
        # per-shard with the same sharding, so order is consistent.
        outs.append(SparseGrad(vals, idx, g.shape))
    return jax.tree.unflatten(treedef, outs)


# --------------------------------------------------------------------------
# step builders
# --------------------------------------------------------------------------

def effective_accum(cfg_accum: int, global_batch: int, dp: int) -> int:
    """Largest accum <= cfg_accum such that the microbatch still spans
    the data-parallel shards evenly."""
    limit = max(1, global_batch // dp)
    a = min(cfg_accum, limit)
    while a > 1 and (global_batch % a or (global_batch // a) % dp):
        a -= 1
    return max(a, 1)


def make_sharded_train_step(model, shape_cfg, *, mode: str = "dense",
                            rho: float = 0.01, lr: float = 1e-3,
                            error_feedback: bool = False,
                            donate: bool = True):
    """Returns (jitted_step, abstract_state, abstract_batch)."""
    ctx = shd.current()
    mesh = ctx.mesh
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.devices.shape[mesh.axis_names.index(a)]
    accum = effective_accum(model.cfg.grad_accum, shape_cfg.global_batch, dp)
    from repro.models.registry import build_model
    model = build_model(model.cfg.replace(grad_accum=accum))

    st_sh = state_shardings(model, mode=mode, error_feedback=error_feedback)

    if mode == "lowdiff_sharded":
        # paper-faithful step with the differential-checkpoint output: the
        # dense step emits the synchronized gradient; compression happens
        # shard-locally so no gather of a sharded gradient ever occurs.
        inner = make_train_step(model, mode="lowdiff_plus", rho=rho, lr=lr,
                                jit=False)
        pspecs = jax.tree.map(lambda s: s.spec, st_sh["params"])

        def step(state, batch):
            new_state, metrics, grads = inner(state, batch)
            cg = compress_sharded(grads, pspecs, mesh, rho)
            return new_state, metrics, cg
    else:
        step = make_train_step(model, mode=mode, rho=rho, lr=lr,
                               error_feedback=error_feedback, jit=False)

    jstep = jax.jit(
        step,
        in_shardings=(st_sh, batch_shardings(model, shape_cfg)),
        out_shardings=(st_sh, None, None),
        donate_argnums=(0,) if donate else (),
    )
    return jstep, abstract_state(model, mode=mode,
                                 error_feedback=error_feedback), \
        abstract_batch(model, shape_cfg)


def make_sharded_prefill_step(model, shape_cfg):
    """Full-sequence forward to final-position logits (inference prefill)."""
    psh = param_shardings(model)
    abs_params = jax.tree.map(
        lambda sds, s: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=s),
        model.abstract_params(), psh)
    jstep = jax.jit(model.logits_fn,
                    in_shardings=(psh, batch_shardings(model, shape_cfg)),
                    out_shardings=None)
    return jstep, abs_params, abstract_batch(model, shape_cfg)


def make_sharded_serve_step(model, shape_cfg, *, donate: bool = True):
    """Single-token decode step with sharded KV cache."""
    ctx = shd.current()
    seq_len = shape_cfg.seq_len
    B = shape_cfg.global_batch

    psh = param_shardings(model)
    abs_params = jax.tree.map(
        lambda sds, s: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=s),
        model.abstract_params(), psh)
    cache_abs = model.init_cache(B, seq_len, abstract=True)
    cache_sh = shd.safe_sharding_tree(cache_abs, model.cache_logical())
    cache_abs = jax.tree.map(
        lambda sds, s: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=s),
        cache_abs, cache_sh)
    bsh = batch_shardings(model, shape_cfg)
    babs = abstract_batch(model, shape_cfg)

    def step(params, cache, batch):
        return model.decode_step(params, cache, batch, seq_len)

    jstep = jax.jit(step,
                    in_shardings=(psh, cache_sh, bsh),
                    out_shardings=(None, cache_sh),
                    donate_argnums=(1,) if donate else ())
    return jstep, abs_params, cache_abs, babs
