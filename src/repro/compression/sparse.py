"""Blockwise top-k / random-k gradient sparsification.

TPU adaptation of the paper's top-k sparsification (ρ ∈ [0.001, 0.1],
paper default 0.01): instead of a *global* sort (a GPU idiom), selection
is *block-local* — each 1024-element block keeps its own top-k by
magnitude. This keeps selection, decompression (block-local scatter) and
accumulation MXU/VPU-friendly and makes indices small (<= 10 bits).

The representation is a ``SparseGrad`` per tensor: values (nb, k) and
block-local indices (nb, k). A Pallas kernel (repro.kernels.topk)
accelerates selection on TPU; this module is the pure-jnp reference
implementation used on CPU and as the kernel oracle.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 1024


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SparseGrad:
    """Blockwise top-k compressed tensor."""
    values: jax.Array            # (nb, k)
    indices: jax.Array           # (nb, k) int32, block-local
    shape: Tuple[int, ...]       # original dense shape
    block: int = BLOCK

    def tree_flatten(self):
        return (self.values, self.indices), (self.shape, self.block)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])

    @property
    def nbytes(self) -> int:
        # indices fit in int16 on disk (block-local < 1024)
        return int(self.values.size * self.values.dtype.itemsize
                   + self.indices.size * 2)

    def dense(self) -> jax.Array:
        return topk_decompress(self)


def _pad_len(n: int, block: int) -> int:
    return (block - n % block) % block


def k_for(rho: float, block: int = BLOCK) -> int:
    return max(1, int(math.ceil(rho * block)))


def topk_compress(x: jax.Array, rho: float, *, block: int = BLOCK) -> SparseGrad:
    """Blockwise top-|x| selection keeping k = ceil(rho * block) per block."""
    shape = x.shape
    flat = x.reshape(-1)
    pad = _pad_len(flat.size, block)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    xb = flat.reshape(-1, block)
    k = k_for(rho, block)
    mag = jnp.abs(xb.astype(jnp.float32))
    _, idx = jax.lax.top_k(mag, k)                    # (nb, k)
    vals = jnp.take_along_axis(xb, idx, axis=1)
    return SparseGrad(vals, idx.astype(jnp.int32), shape, block)


def topk_decompress(sg: SparseGrad) -> jax.Array:
    nb, k = sg.values.shape
    out = jnp.zeros((nb, sg.block), sg.values.dtype)
    out = jax.vmap(lambda o, i, v: o.at[i].add(v))(out, sg.indices, sg.values)
    flat = out.reshape(-1)
    n = int(np.prod(sg.shape)) if sg.shape else 1
    return flat[:n].reshape(sg.shape)


def randomk_compress(x: jax.Array, rho: float, rng, *,
                     block: int = BLOCK) -> SparseGrad:
    """Random-k sparsification (same container, uniform random indices)."""
    shape = x.shape
    flat = x.reshape(-1)
    pad = _pad_len(flat.size, block)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    xb = flat.reshape(-1, block)
    k = k_for(rho, block)
    nb = xb.shape[0]
    noise = jax.random.uniform(rng, (nb, block))
    _, idx = jax.lax.top_k(noise, k)
    vals = jnp.take_along_axis(xb, idx, axis=1) * (block / k)  # unbiased
    return SparseGrad(vals, idx.astype(jnp.int32), shape, block)


def sparse_add(a: SparseGrad, b: SparseGrad) -> jax.Array:
    """Accumulate two compressed grads (batched-write 'sum' mode) — dense."""
    assert a.shape == b.shape and a.block == b.block
    return topk_decompress(a) + topk_decompress(b)


# ------------------------- pytree-level API --------------------------------

def compress_tree(grads, rho: float):
    return jax.tree.map(lambda g: topk_compress(g, rho), grads)


def decompress_tree(cg):
    return jax.tree.map(topk_decompress, cg,
                        is_leaf=lambda x: isinstance(x, SparseGrad))


def tree_nbytes(cg) -> int:
    return sum(l.nbytes for l in
               jax.tree.leaves(cg, is_leaf=lambda x: isinstance(x, SparseGrad))
               if isinstance(l, SparseGrad))


def dense_nbytes(tree) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))
