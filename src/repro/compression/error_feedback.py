"""Error-feedback (memory) for biased compressors [Stich et al.'18].

The residual of each compression step is added back before the next
compression — standard practice with top-k sparsification and required
for convergence claims. State is a dense pytree like the gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compression.sparse import SparseGrad, topk_compress, topk_decompress


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_tree_with(grads, ef_state, compress_fn, decompress_fn):
    """Generic EF loop for any biased (compress, decompress) pair:
    compresses ``grad + residual`` per leaf and keeps the new residual
    (which absorbs sparsification *and* quantization error alike).
    Returns (compressed tree, new ef state)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        cg = compress_fn(corrected)
        residual = corrected - decompress_fn(cg).astype(jnp.float32)
        return cg, residual

    g_flat, treedef = jax.tree.flatten(grads)
    e_flat = treedef.flatten_up_to(ef_state)
    pairs = [one(g, e) for g, e in zip(g_flat, e_flat)]
    cg = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    ef = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return cg, ef


def ef_compress_tree(grads, ef_state, rho: float):
    """Returns (compressed tree, new ef state) — top-k instance."""
    return ef_compress_tree_with(
        grads, ef_state,
        lambda g: topk_compress(g, rho),
        topk_decompress)
