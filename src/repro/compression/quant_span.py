"""Quantized row-span differentials: the wire currency for 4/8-bit
row patches.

A :class:`QuantSpan` is the quantized sibling of
:class:`repro.checkpoint.patchset.RowUpdate`: the same disjoint
axis-0 row intervals of one leaf, but each interval's rows carried as
int8 (or nibble-packed int4) values plus one f32 absmax scale per row
instead of raw fp32. The replica quantizes with the pure-numpy codec
here; device recovery dequantizes with the fused Pallas
``quant_span_apply`` kernel. Both sides perform the identical f32 op
sequence (absmax reduce, divide, round-ties-to-even, clip, cast), so
host overlay and device overlay of the same payload produce the same
bytes — the bit-identity the recovery tests assert.

Quantization error is **never** allowed to compound down a chain: the
payload is dequantized exactly once (at ``merge_updates`` overlay or at
fold time, where spans are written *raw* into the base frame), and the
replica holds per-row error-feedback residuals so the deferred error is
added back into the next quantization of the same rows instead of
silently drifting (Check-N-Run §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import jax
import numpy as np

# NOTE: repro.checkpoint.patchset is imported lazily inside the methods
# that build Spans — importing it here would cycle through
# repro.checkpoint.__init__ -> backends -> io -> this module.

DIFF_QUANTS = ("off", "int8", "int4")

_QMAX = {8: 127.0, 4: 7.0}


def quant_bits(diff_quant: str) -> int:
    """CLI value ("int8"/"int4") -> bit width."""
    return {"int8": 8, "int4": 4}[diff_quant]


# ----------------------------------------------------------------------
# pure-numpy codec — bit-identical to pack.span_pack / span_decode_ref
# ----------------------------------------------------------------------

def encode_rows(a: np.ndarray, bits: int) -> Tuple[np.ndarray, np.ndarray]:
    """Quantize a (n, *tail) row block with per-row absmax scales.
    Returns (q (n, wire_cols), scale (n, 1) f32); wire_cols is
    prod(tail) for int8, ceil(prod(tail)/2) for nibble-packed int4.
    Every operation is an IEEE f32 op that jnp performs identically, so
    the wire bytes match the Pallas pack kernel bit for bit."""
    a2 = np.ascontiguousarray(np.asarray(a, np.float32)).reshape(
        a.shape[0], -1)
    n, cols = a2.shape
    qmax = np.float32(_QMAX[bits])
    if cols == 0:
        return (np.zeros((n, 0), np.int8 if bits == 8 else np.uint8),
                np.full((n, 1), 1e-12, np.float32))
    absmax = np.max(np.abs(a2), axis=1, keepdims=True)
    # multiply by the pre-rounded reciprocal instead of dividing by
    # qmax: XLA rewrites division-by-constant to reciprocal-multiply,
    # so a literal division here would put the numpy codec one ulp off
    # the kernels on some inputs and break the bit-parity contract
    scale = np.maximum(absmax * np.float32(1.0 / float(qmax)),
                       np.float32(1e-12)).astype(np.float32)
    qi = np.clip(np.round(a2 / scale), -qmax, qmax).astype(np.int32)
    if bits == 8:
        return qi.astype(np.int8), scale
    if cols % 2:
        qi = np.pad(qi, ((0, 0), (0, 1)))
    lo = qi[:, 0::2] & 0xF
    hi = qi[:, 1::2] & 0xF
    return (lo | (hi << 4)).astype(np.uint8), scale


def decode_rows(q: np.ndarray, scale: np.ndarray, cols: int,
                bits: int) -> np.ndarray:
    """Inverse of :func:`encode_rows` -> f32 (n, cols)."""
    n = q.shape[0]
    if cols == 0:
        return np.zeros((n, 0), np.float32)
    if bits == 8:
        g = q.astype(np.float32)
    else:
        u = q.astype(np.int32)
        lo = u & 0xF
        hi = (u >> 4) & 0xF
        lo = np.where(lo > 7, lo - 16, lo)
        hi = np.where(hi > 7, hi - 16, hi)
        g = np.empty((n, 2 * q.shape[1]), np.float32)
        g[:, 0::2] = lo
        g[:, 1::2] = hi
    return (g[:, :cols] * scale).astype(np.float32)


# ----------------------------------------------------------------------
# the container
# ----------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantSpan:
    """Quantized row-span update for one leaf: disjoint axis-0 intervals
    carried as per-row absmax-quantized payloads.

    ``starts[i]`` is the first row of span i; ``qs[i]`` its wire bytes
    ((rows_i, wire_cols) int8 or nibble-packed uint8); ``scales[i]`` its
    (rows_i, 1) f32 per-row scales. ``shape`` is the full leaf shape,
    ``bits`` 8 or 4, ``dtype`` the leaf dtype name the dequantized rows
    are cast back to."""

    starts: Tuple[int, ...]
    qs: List[np.ndarray]
    scales: List[np.ndarray]
    shape: Tuple[int, ...]
    bits: int
    dtype: str = "float32"

    def tree_flatten(self):
        return ((tuple(self.qs), tuple(self.scales)),
                (tuple(int(s) for s in self.starts), tuple(self.shape),
                 int(self.bits), self.dtype))

    @classmethod
    def tree_unflatten(cls, aux, children):
        qs, scales = children
        starts, shape, bits, dtype = aux
        return cls(starts=starts, qs=list(qs), scales=list(scales),
                   shape=shape, bits=bits, dtype=dtype)

    # -- geometry ------------------------------------------------------
    @property
    def cols(self) -> int:
        c = 1
        for d in self.shape[1:]:
            c *= int(d)
        return c

    @property
    def rows(self) -> int:
        return int(sum(q.shape[0] for q in self.qs))

    def extents(self) -> List[Tuple[int, int]]:
        """[(start, stop)) per span — same surface as RowUpdate."""
        return [(int(s), int(s) + int(q.shape[0]))
                for s, q in zip(self.starts, self.qs)]

    # -- sizes ---------------------------------------------------------
    @property
    def nbytes(self) -> int:
        """Stored wire bytes (quantized payload + scales)."""
        return int(sum(q.nbytes + s.nbytes
                       for q, s in zip(self.qs, self.scales)))

    @property
    def logical_nbytes(self) -> int:
        """Bytes the same rows would occupy raw (the RowUpdate size)."""
        item = np.dtype(self.dtype).itemsize
        return int(self.rows * self.cols * item)

    # -- dequantization (the one place wire bytes become values) -------
    def spans(self) -> List["Span"]:
        """Dequantized raw spans, cast to the leaf dtype — feeds the
        same newest-wins merge / overlay paths as RowUpdate.spans()."""
        import time

        from repro.checkpoint.patchset import Span
        t0 = time.perf_counter()
        tail = tuple(int(d) for d in self.shape[1:])
        dt = np.dtype(self.dtype)
        out = []
        for s, q, sc in zip(self.starts, self.qs, self.scales):
            rows = decode_rows(np.asarray(q), np.asarray(sc), self.cols,
                               self.bits)
            out.append(Span(int(s),
                            rows.reshape((q.shape[0],) + tail).astype(dt)))
        QUANT_METER.add_decode(time.perf_counter() - t0)
        return out

    # -- constructors --------------------------------------------------
    @classmethod
    def from_rows(cls, starts: Sequence[int], blocks: Sequence[np.ndarray],
                  shape: Tuple[int, ...], bits: int,
                  dtype: Any = None) -> "QuantSpan":
        """Quantize raw row blocks (host codec). ``blocks[i]`` holds the
        rows starting at ``starts[i]``; dtype defaults to the blocks'."""
        if dtype is None:
            dtype = blocks[0].dtype if blocks else np.float32
        qs, scales = [], []
        for b in blocks:
            q, sc = encode_rows(np.asarray(b), bits)
            qs.append(q)
            scales.append(sc)
        return cls(starts=tuple(int(s) for s in starts), qs=qs,
                   scales=scales, shape=tuple(int(d) for d in shape),
                   bits=int(bits), dtype=np.dtype(dtype).name)

    @classmethod
    def from_row_update(cls, ru: "RowUpdate", bits: int) -> "QuantSpan":
        return cls.from_rows([sp.start for sp in ru.spans()],
                             [sp.data for sp in ru.spans()],
                             tuple(ru.shape), bits,
                             dtype=ru.rows[0].dtype if ru.rows
                             else np.float32)


# ----------------------------------------------------------------------
# metering
# ----------------------------------------------------------------------

class QuantMeter:
    """Process-wide quantized-differential codec meter: encode/decode
    wall time plus logical-in vs stored-out byte counters (the realized
    compression ratio of the quantized patch stream)."""

    #: stats() keys, synced against the instrument set by
    #: tests/test_observability.py (``ratio`` is derived)
    KEYS = ("encode_s", "decode_s", "bytes_in", "bytes_out")

    def __init__(self):
        from repro.obs.metrics import InstrumentSet
        self._inst = InstrumentSet("quant")
        self._encode = self._inst.histogram("encode_s")
        self._decode = self._inst.histogram("decode_s")
        self._bytes_in = self._inst.counter("bytes_in")
        self._bytes_out = self._inst.counter("bytes_out")

    @property
    def encode_s(self) -> float:
        return self._encode.sum

    @property
    def decode_s(self) -> float:
        return self._decode.sum

    @property
    def bytes_in(self) -> int:
        return int(self._bytes_in.value)

    @property
    def bytes_out(self) -> int:
        return int(self._bytes_out.value)

    def add_encode(self, seconds: float, bytes_in: int,
                   bytes_out: int) -> None:
        self._encode.observe(float(seconds))
        self._bytes_in.add(int(bytes_in))
        self._bytes_out.add(int(bytes_out))

    def add_decode(self, seconds: float) -> None:
        self._decode.observe(float(seconds))

    def ratio(self):
        """Logical bytes per stored byte (None until an encode ran)."""
        if self.bytes_out <= 0:
            return None
        return self.bytes_in / self.bytes_out

    def instruments(self):
        return self._inst

    def stats(self) -> Dict[str, Any]:
        out = {k: getattr(self, k) for k in self.KEYS}
        out["ratio"] = self.ratio()
        return out

    def reset(self) -> None:
        self._encode.reset()
        self._decode.reset()
        self._bytes_in.reset()
        self._bytes_out.reset()


QUANT_METER = QuantMeter()
