from repro.compression.sparse import (  # noqa: F401
    BLOCK, SparseGrad, compress_tree, decompress_tree, dense_nbytes,
    k_for, randomk_compress, sparse_add, topk_compress, topk_decompress,
    tree_nbytes,
)
from repro.compression.quant import QuantGrad, quant_compress, quant_decompress  # noqa: F401
from repro.compression.error_feedback import ef_compress_tree, ef_init  # noqa: F401
from repro.compression.quant_span import (  # noqa: F401
    DIFF_QUANTS, QUANT_METER, QuantMeter, QuantSpan, quant_bits,
)
