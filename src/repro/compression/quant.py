"""Blockwise int8 quantization (the paper's alternative compression family).

Per 1024-element block: scale = absmax/127, q = round(x/scale). 4x
smaller than f32 (2x vs bf16). Used by LowDiff when the training system's
communication compression is quantization rather than sparsification.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.sparse import BLOCK, _pad_len


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantGrad:
    q: jax.Array                 # (nb, block) int8
    scale: jax.Array             # (nb,) f32
    shape: Tuple[int, ...]
    block: int = BLOCK

    def tree_flatten(self):
        return (self.q, self.scale), (self.shape, self.block)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])

    @property
    def nbytes(self) -> int:
        return int(self.q.size + self.scale.size * 4)

    def dense(self) -> jax.Array:
        return quant_decompress(self)


def quant_compress(x: jax.Array, *, block: int = BLOCK) -> QuantGrad:
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    pad = _pad_len(flat.size, block)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    xb = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(xb), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return QuantGrad(q, scale, shape, block)


def quant_decompress(qg: QuantGrad) -> jax.Array:
    flat = (qg.q.astype(jnp.float32) * qg.scale[:, None]).reshape(-1)
    n = int(np.prod(qg.shape)) if qg.shape else 1
    return flat[:n].reshape(qg.shape)
