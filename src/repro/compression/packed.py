"""Wire-format packed differential: fused top-k + int8 quantization.

``PackedDiff`` is the container emitted by the fused Pallas
compress-and-pack kernel (``repro.kernels.pack``): per 1024-element
block, the top-k values quantized to int8 against a per-block absmax
scale, plus the block-local indices. The three buffers (q / indices /
scale) are each contiguous and exactly what the frame serializer puts
on the wire — the differential comes off the device already in its
persisted layout, so the write path never re-encodes it.

Size per block: k int8 values + k int16-representable indices + one f32
scale — ~4x smaller than the f32 ``SparseGrad`` values at the same rho.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax

from repro.compression.sparse import BLOCK


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedDiff:
    """Blockwise top-k selected, int8-quantized compressed tensor."""
    q: jax.Array                 # (nb, k) int8 — quantized top-k values
    indices: jax.Array           # (nb, k) int32, block-local
    scale: jax.Array             # (nb, 1) f32 per-block dequant scale
    shape: Tuple[int, ...]       # original dense shape
    block: int = BLOCK

    def tree_flatten(self):
        return (self.q, self.indices, self.scale), (self.shape, self.block)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], children[2], aux[0], aux[1])

    @property
    def nbytes(self) -> int:
        # indices fit in int16 on disk (block-local < 1024)
        return int(self.q.size + self.indices.size * 2 + self.scale.size * 4)

    def dense(self) -> jax.Array:
        from repro.kernels.ops import packed_decompress
        return packed_decompress(self)
