"""Pluggable checkpoint storage backends.

A backend is a key -> pytree blob store; :class:`repro.checkpoint.store.
CheckpointStore` layers the full/diff/batch chain semantics, the
manifest journal, and garbage collection on top. Three implementations:

* :class:`LocalFSBackend` — one atomic file per key on a local
  directory: a streamed ``.ckpt`` frame (default) or legacy ``.npz``.
* :class:`MemoryTierBackend` — TierCheck-style CPU-RAM tier: writes land
  in host memory at memcpy speed and are flushed asynchronously to an
  optional lower backend; reads hit RAM first. A byte capacity bounds
  the tier; the oldest blobs spill to the lower tier (or are dropped,
  ring-buffer style, when no lower tier exists).
* :class:`ShardedBackend` — splits pytree leaves across per-host shard
  directories and writes/reads the shards concurrently. The split axis
  per leaf comes from ``split_axis_fn``: by default the largest
  dimension; pass ``make_pspec_splitter(logical)`` to follow the
  active mesh's partition specs (``repro.distributed.sharding``) so
  on-disk shards line up with the device layout. Small leaves are
  placed whole on the least-loaded shard. ``get`` re-assembles sharded
  leaves bit-exactly.
"""
from __future__ import annotations

import abc
import json
import os
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import io as cio
from repro.checkpoint.patchset import PatchSet
from repro.obs.trace import trace_span


def split_sizes(extent: int, parts: int) -> List[int]:
    """Piece sizes ``np.array_split(a, parts)`` produces along an axis of
    ``extent`` — the boundary math backends need to re-split a row range
    per shard placement without materializing the full leaf."""
    base, rem = divmod(int(extent), int(parts))
    return [base + 1 if i < rem else base for i in range(parts)]


class StorageBackend(abc.ABC):
    """Key -> checkpoint blob store. Keys are flat path-safe strings."""

    name = "abstract"
    #: directory where durable metadata (the manifest journal) can live;
    #: None for purely in-memory backends.
    persist_root: Optional[str] = None
    #: serialization format new blobs are written in ("frame" or "npz");
    #: recorded per manifest entry by the chain store. Read side always
    #: sniffs, so mixed-format chains recover transparently.
    fmt: str = "frame"

    @property
    def provenance(self) -> str:
        """Durability class recorded per manifest entry (``tier`` tag):
        where an acked put actually lives. Recovery orders fulls
        source-aware with it — a peer-served replica must never shadow
        a newer durable full. Wrapping tiers forward their lower tier's
        provenance; the RAM tier reports "memory" (its ack is
        RAM-durable only until the async write-back lands)."""
        return self.name

    @abc.abstractmethod
    def put(self, key: str, obj: Any) -> int:
        """Durably (or tier-durably) store obj. Returns bytes written."""

    @abc.abstractmethod
    def get(self, key: str) -> Any:
        """Load and return the blob. Raises FileNotFoundError if absent."""

    @abc.abstractmethod
    def delete(self, key: str) -> None:
        """Remove the blob (idempotent)."""

    @abc.abstractmethod
    def exists(self, key: str) -> bool: ...

    @abc.abstractmethod
    def keys(self) -> List[str]: ...

    def url(self, key: str) -> str:
        """Human-readable locator for manifest entries / logs."""
        return f"{self.name}://{key}"

    def patch(self, key: str, patch: PatchSet) -> int:
        """In-place partial update of a stored frame blob: overwrite the
        patched row ranges of the named payload leaves (``a0..aN``, same
        dtype and tail shape — the layout never moves) at their recorded
        offsets and refresh the header checksums, instead of re-writing
        the whole blob. ``patch`` is a :class:`PatchSet` (implementations
        coerce, so legacy ``{name: whole_array}`` dicts keep working).
        The incremental-merging persistence engine's fold step calls
        this with exactly the row ranges a patch chain dirtied, so fold
        I/O is O(changed bytes), not O(model). Returns bytes written.
        Backends that cannot patch raise ``NotImplementedError``; npz
        blobs are rejected with ``ValueError`` (zip members cannot be
        pwritten)."""
        raise NotImplementedError(
            f"{self.name} backend cannot patch blobs in place")

    def protect(self, keys) -> None:
        """Advise the backend that ``keys`` form the newest full
        checkpoint's replay chain: a capacity-bounded tier must never
        evict them from its fastest level. Default: no-op (durable
        backends have nothing to evict)."""

    def verify(self, key: str) -> Optional[str]:
        """Integrity-check the blob without returning it: None when
        intact, else a human-readable corruption reason (the
        maintenance scrubber quarantines the entry). Raises
        FileNotFoundError when the blob is absent; infrastructure
        errors (e.g. a remote tier's exhausted transient retries)
        propagate — only *corruption* is reported as a reason. The
        default loads the blob and treats any decode failure as
        corruption."""
        try:
            self.get(key)
        except FileNotFoundError:
            raise
        except Exception as e:  # decode/checksum/struct failures
            return f"{type(e).__name__}: {e}"
        return None

    def sweep_orphans(self, min_age_s: float = 60.0) -> int:
        """Best-effort cleanup of storage debris no committed blob
        references (crashed half-writes, superseded generations).
        Returns the number of objects removed. Never touches committed
        data; ``min_age_s`` shields writes that are in flight right
        now. Default: nothing to sweep."""
        return 0

    def flush(self) -> None:
        """Block until every accepted put is durable at the lowest tier."""

    def close(self) -> None:
        self.flush()

    def stats(self) -> Dict[str, Any]:
        return {"backend": self.name}


# ----------------------------------------------------------------------
# Local filesystem
# ----------------------------------------------------------------------

class LocalFSBackend(StorageBackend):
    """One atomic file per key: ``<key>.ckpt`` streamed frames (the
    default fast path — leaf buffers go straight from the snapshot into
    the file, reads are lazy ``np.memmap`` views) or ``<key>.npz``
    (``fmt="npz"``, the seed format). Reads sniff the magic, so a
    directory holding a mixed-format chain keeps recovering."""

    name = "local"
    SUFFIXES = {"frame": ".ckpt", "npz": ".npz"}

    def __init__(self, root: str, *, fmt: str = "frame",
                 mmap_reads: bool = True):
        if fmt not in self.SUFFIXES:
            raise ValueError(f"fmt must be one of {tuple(self.SUFFIXES)}")
        self.root = root
        self.persist_root = root
        self.fmt = fmt
        self.mmap_reads = mmap_reads
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str, fmt: Optional[str] = None) -> str:
        return os.path.join(self.root,
                            f"{key}{self.SUFFIXES[fmt or self.fmt]}")

    def _find(self, key: str) -> Optional[str]:
        # configured format first: if both suffixes somehow exist, the
        # one this backend writes is the authoritative copy
        for fmt in sorted(self.SUFFIXES, key=lambda f: f != self.fmt):
            p = self._path(key, fmt)
            if os.path.exists(p):
                return p
        return None

    def put(self, key: str, obj: Any) -> int:
        with trace_span("backend.put", "backend", tier=self.name,
                        key=key) as sp:
            if self.fmt == "frame":
                n = cio.save_frame(self._path(key), obj)
            else:
                n = cio.save(self._path(key), obj)
            sp.set(bytes=n)
        # a re-put after a format switch must not leave the key's
        # other-suffix file behind: a stale cross-format blob would
        # shadow (or survive delete alongside) the fresh write
        for fmt in self.SUFFIXES:
            if fmt != self.fmt:
                try:
                    os.unlink(self._path(key, fmt))
                except FileNotFoundError:
                    pass
        return n

    def get(self, key: str) -> Any:
        path = self._find(key)
        if path is None:
            raise FileNotFoundError(f"no blob {key!r} in {self.root}")
        return cio.load_any(path, mmap=self.mmap_reads)

    def patch(self, key: str, patch: PatchSet) -> int:
        path = self._find(key)
        if path is None:
            raise FileNotFoundError(f"no blob {key!r} in {self.root}")
        if not cio.is_frame_file(path):
            raise ValueError(
                f"cannot patch npz blob {key!r} in place; incremental "
                f"persistence requires the frame format")
        return cio.patch_frame(path, PatchSet.coerce(patch))

    def delete(self, key: str) -> None:
        for fmt in self.SUFFIXES:
            try:
                os.unlink(self._path(key, fmt))
            except FileNotFoundError:
                pass

    def exists(self, key: str) -> bool:
        return self._find(key) is not None

    def verify(self, key: str) -> Optional[str]:
        """Re-verify the blob's integrity on disk: every frame leaf's
        sha256 is recomputed against the header (the full-read check
        ``get``'s lazy memmap path skips); npz blobs are fully decoded."""
        path = self._find(key)
        if path is None:
            raise FileNotFoundError(f"no blob {key!r} in {self.root}")
        try:
            if cio.is_frame_file(path):
                cio.read_frame(path, verify=True)
            else:
                cio.load_any(path, mmap=False)
        except FileNotFoundError:
            raise
        except Exception as e:
            return f"{type(e).__name__}: {e}"
        return None

    def sweep_orphans(self, min_age_s: float = 60.0) -> int:
        """Remove ``.tmp`` debris from atomic writes that crashed before
        their rename. Age-gated so a write in flight right now is never
        swept from under its own fsync."""
        removed = 0
        cutoff = time.time() - min_age_s
        for f in os.listdir(self.root):
            if not f.endswith(".tmp"):
                continue
            p = os.path.join(self.root, f)
            try:
                if os.path.getmtime(p) <= cutoff:
                    os.unlink(p)
                    removed += 1
            except OSError:
                pass
        return removed

    def keys(self) -> List[str]:
        out = set()
        for f in os.listdir(self.root):
            for suffix in self.SUFFIXES.values():
                if f.endswith(suffix):
                    out.add(f[:-len(suffix)])
        return sorted(out)

    def url(self, key: str) -> str:
        return self._find(key) or self._path(key)


# ----------------------------------------------------------------------
# CPU-memory tier with asynchronous spill/flush
# ----------------------------------------------------------------------

class MemoryTierBackend(StorageBackend):
    """CPU-RAM checkpoint tier (TierCheck / Gemini style).

    ``put`` packs the pytree into host arrays and returns immediately;
    when a ``lower`` backend is given every put is also enqueued for
    asynchronous write-back (of the packed snapshot, so later caller
    mutation cannot diverge the tiers), making the RAM tier a
    write-through cache whose reads never touch storage.
    ``capacity_bytes`` bounds resident bytes: victim blobs are evicted
    after their write-back lands. A capacity without a lower tier would
    silently drop checkpoints the manifest still references, so it is
    rejected.

    Eviction policy (``eviction``): victims are drawn from size-class
    buckets (power-of-two ``nbytes`` classes) — the bucket holding the
    most evictable bytes is victimized first, so one large stale full
    goes before dozens of small hot differentials. Within the bucket,
    ``"fifo"`` evicts insertion order and ``"lru"`` least-recently-used
    (``get`` refreshes recency, so recovery reads keep their chain warm
    — the read-heavy recovery workload the LRU variant exists for).
    Either way the chain-protection guard is absolute: protected keys
    are never victims.
    """

    name = "memory"
    EVICTION_POLICIES = ("fifo", "lru")

    def __init__(self, lower: Optional[StorageBackend] = None, *,
                 capacity_bytes: Optional[int] = None,
                 eviction: str = "fifo"):
        if capacity_bytes is not None and lower is None:
            raise ValueError(
                "capacity_bytes requires a lower backend to spill to; "
                "a pure-RAM tier must hold every live checkpoint")
        if eviction not in self.EVICTION_POLICIES:
            raise ValueError(f"eviction must be one of "
                             f"{self.EVICTION_POLICIES}")
        self.lower = lower
        self.persist_root = lower.persist_root if lower is not None else None
        self.fmt = lower.fmt if lower is not None else "memory"
        self.capacity_bytes = capacity_bytes
        self.eviction = eviction
        self._mem: "OrderedDict[str, Tuple[dict, List[np.ndarray], int]]" \
            = OrderedDict()
        self._bytes = 0
        #: size-class buckets: class index -> insertion/recency-ordered
        #: keys, plus per-class resident byte totals (victim selection)
        self._buckets: Dict[int, "OrderedDict[str, None]"] = {}
        self._bucket_bytes: Dict[int, int] = {}
        self._class_of: Dict[str, int] = {}
        self._lock = threading.Lock()
        self._writeback: Optional[ThreadPoolExecutor] = (
            ThreadPoolExecutor(max_workers=1, thread_name_prefix="spill")
            if lower is not None else None)
        self._inflight: Dict[str, Future] = {}
        # write-backs that completed with an exception: the failure must
        # surface from flush(), never be silently pruned — the journal
        # already references the blob, and losing it mid-chain would
        # hand recovery a hole
        self._wb_errors: List[Tuple[str, BaseException]] = []
        #: keys in the newest full checkpoint's replay chain — never
        #: evicted from RAM (chain-aware eviction: recovery of the
        #: latest chain must hit memory, not the slow tier)
        self._protected: frozenset = frozenset()
        self.evictions = 0
        self.spills = 0
        self.evictions_skipped = 0

    # -- size-class bucket bookkeeping (all callers hold self._lock) ---
    @staticmethod
    def _size_class(nbytes: int) -> int:
        return int(nbytes).bit_length()

    def _bucket_add(self, key: str, nbytes: int):
        c = self._size_class(nbytes)
        self._class_of[key] = c
        self._buckets.setdefault(c, OrderedDict())[key] = None
        self._buckets[c].move_to_end(key)
        self._bucket_bytes[c] = self._bucket_bytes.get(c, 0) + nbytes

    def _bucket_remove(self, key: str, nbytes: int):
        c = self._class_of.pop(key, None)
        if c is None:
            return
        self._buckets[c].pop(key, None)
        self._bucket_bytes[c] -= nbytes
        if not self._buckets[c]:
            del self._buckets[c], self._bucket_bytes[c]

    def _bucket_touch(self, key: str):
        c = self._class_of.get(key)
        if c is not None:
            self._buckets[c].move_to_end(key)

    def put(self, key: str, obj: Any) -> int:
        with trace_span("backend.put", "backend", tier=self.name,
                        key=key):
            return self._put_impl(key, obj)

    def _put_impl(self, key: str, obj: Any) -> int:
        struct, arrays = cio.pack(obj)
        # np.array COPIES: the tier must own its bytes — a caller
        # mutating its leaves after put() must not alter the checkpoint
        arrays = [np.array(a) for a in arrays]
        nbytes = int(sum(a.nbytes for a in arrays))
        self._prune_done()
        with self._lock:
            if key in self._mem:
                self._bytes -= self._mem[key][2]
                self._bucket_remove(key, self._mem[key][2])
            self._mem[key] = (struct, arrays, nbytes)
            self._mem.move_to_end(key)
            self._bucket_add(key, nbytes)
            self._bytes += nbytes
        if self._writeback is not None:
            # write back the packed snapshot, not the caller's live obj:
            # the disk copy must match what the RAM tier serves even if
            # the caller mutates leaves after put() returns
            snap = cio.unpack(struct, arrays)
            fut = self._writeback.submit(self.lower.put, key, snap)
            self._inflight[key] = fut
            self.spills += 1
        self._evict()
        return nbytes

    def _prune_done(self):
        """Drop completed write-back futures so _inflight stays O(pending)
        over a long per-iteration-checkpointing run; failed ones are
        recorded and re-raised from flush()."""
        for k, fut in list(self._inflight.items()):
            if fut.done():
                err = fut.exception()
                if err is not None:
                    self._wb_errors.append((k, err))
                self._inflight.pop(k, None)

    def protect(self, keys) -> None:
        with self._lock:
            shrank = not self._protected <= frozenset(keys)
            self._protected = frozenset(keys)
        if shrank:
            # blobs just un-protected (a new full superseded their
            # chain) become eviction candidates immediately
            self._evict()

    def _pick_victim(self) -> Optional[str]:
        """Victim under the active policy (caller holds the lock): the
        size-class bucket with the most evictable bytes first; within
        it, oldest (fifo) / least-recently-used (lru) unprotected key.
        A blob in the newest full's chain is never a victim — evicting
        it would push latest-chain recovery down to the slow tier, or
        lose it outright if the write-back later failed."""
        for c in sorted(self._bucket_bytes,
                        key=self._bucket_bytes.get, reverse=True):
            for k in self._buckets[c]:
                if k not in self._protected:
                    return k
        return None

    def _evict(self):
        if self.capacity_bytes is None:
            return
        while True:
            with self._lock:
                if self._bytes <= self.capacity_bytes or len(self._mem) <= 1:
                    return
                # only *evictable* keys are candidates (soft cap: the
                # protected chain may hold the tier over capacity)
                key = self._pick_victim()
                if key is None:
                    self.evictions_skipped += 1
                    return
            fut = self._inflight.pop(key, None)
            if fut is not None:
                fut.result()  # never drop RAM before the spill lands
            with self._lock:
                item = self._mem.pop(key, None)
                if item is not None:
                    self._bytes -= item[2]
                    self._bucket_remove(key, item[2])
                    self.evictions += 1

    def get(self, key: str) -> Any:
        with self._lock:
            item = self._mem.get(key)
            if item is not None and self.eviction == "lru":
                # recency refresh — recovery reads keep their chain warm
                self._mem.move_to_end(key)
                self._bucket_touch(key)
        if item is not None:
            struct, arrays, _ = item
            # copy out: callers may mutate the returned tree (resumed
            # training state) without corrupting the tier's checkpoint
            return cio.unpack(struct, [np.array(a) for a in arrays])
        if self.lower is not None:
            fut = self._inflight.get(key)
            if fut is not None:
                fut.result()
            return self.lower.get(key)
        raise FileNotFoundError(f"memory tier has no blob {key!r}")

    def patch(self, key: str, patch: PatchSet) -> int:
        """Patch the resident packed arrays in place — whole-leaf spans
        replace the array (copied; the tier must still own its bytes),
        row spans are spliced into the resident buffer — and forward
        the patch to the lower tier through the same FIFO write-back
        worker — it lands strictly after the base blob's own
        write-back, so the tiers never diverge."""
        ps = PatchSet.coerce(patch)
        self._prune_done()
        n = 0
        with self._lock:
            item = self._mem.get(key)
            if item is not None:
                _, arrays, _ = item
                for name in ps:
                    i = int(name[1:])
                    base = arrays[i]
                    for sp in ps[name]:
                        a = np.asarray(sp.data)
                        whole = sp.start == 0 and a.shape == base.shape
                        if base.dtype != a.dtype or not (
                                whole or (base.ndim >= 1 and a.ndim >= 1
                                          and a.shape[1:] == base.shape[1:]
                                          and sp.stop <= base.shape[0])):
                            raise ValueError(
                                f"leaf {name!r} layout mismatch on "
                                f"{key!r}: rows [{sp.start}, {sp.stop}) "
                                f"of {a.dtype}{a.shape} != "
                                f"{base.dtype}{base.shape}")
                        if whole:
                            arrays[i] = base = np.array(a)
                        else:
                            if not base.flags.writeable:
                                base = np.array(base)
                                arrays[i] = base
                            base[sp.start:sp.stop] = a
                        n += int(a.nbytes)
        if item is None and self.lower is None:
            raise FileNotFoundError(f"memory tier has no blob {key!r}")
        if self._writeback is not None:
            snap = ps.copy()
            # replacing a still-pending future for this key would lose
            # its eventual error (patches, unlike re-puts, are not
            # self-healing): collect the predecessor's outcome inside
            # the new task — the single FIFO worker guarantees it has
            # finished by then, so exception() never blocks
            prev = self._inflight.get(key)

            def run(prev=prev, snap=snap):
                if prev is not None:
                    err = prev.exception()
                    if err is not None:
                        self._wb_errors.append((key, err))
                return self.lower.patch(key, snap)

            self._inflight[key] = self._writeback.submit(run)
            self.spills += 1
            if item is None:
                n = snap.nbytes
        return n

    def delete(self, key: str) -> None:
        fut = self._inflight.pop(key, None)
        if fut is not None:
            fut.result()
        with self._lock:
            item = self._mem.pop(key, None)
            if item is not None:
                self._bytes -= item[2]
                self._bucket_remove(key, item[2])
        if self.lower is not None:
            self.lower.delete(key)

    def verify(self, key: str) -> Optional[str]:
        """Scrub the *cold* copy: the RAM tier's arrays are live process
        memory, so integrity questions are about what the lower tier
        holds. Blobs resident only in RAM verify trivially."""
        if self.lower is not None:
            fut = self._inflight.get(key)
            if fut is not None:
                fut.result()       # let an in-flight write-back land
            if self.lower.exists(key):
                return self.lower.verify(key)
        with self._lock:
            if key in self._mem:
                return None
        raise FileNotFoundError(f"memory tier has no blob {key!r}")

    def sweep_orphans(self, min_age_s: float = 60.0) -> int:
        return (self.lower.sweep_orphans(min_age_s)
                if self.lower is not None else 0)

    def exists(self, key: str) -> bool:
        with self._lock:
            if key in self._mem:
                return True
        return self.lower.exists(key) if self.lower is not None else False

    def keys(self) -> List[str]:
        with self._lock:
            ks = set(self._mem)
        if self.lower is not None:
            ks.update(self.lower.keys())
        return sorted(ks)

    def url(self, key: str) -> str:
        return f"memory://{key}"

    def flush(self) -> None:
        for key in list(self._inflight):
            fut = self._inflight.pop(key, None)
            if fut is not None:
                try:
                    fut.result()
                except BaseException as e:
                    self._wb_errors.append((key, e))
        if self._wb_errors:
            key, err = self._wb_errors[0]
            raise RuntimeError(
                f"async write-back of {key!r} failed "
                f"({len(self._wb_errors) - 1} more); the RAM tier still "
                f"holds the blob but the lower tier does not") from err
        if self.lower is not None:
            self.lower.flush()

    def close(self) -> None:
        try:
            self.flush()
        finally:
            if self._writeback is not None:
                self._writeback.shutdown(wait=True)
            if self.lower is not None:
                self.lower.close()

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            resident = len(self._mem)
            nbytes = self._bytes
        return {"backend": self.name, "resident_blobs": resident,
                "resident_bytes": nbytes, "evictions": self.evictions,
                "evictions_skipped": self.evictions_skipped,
                "protected": len(self._protected),
                "eviction_policy": self.eviction,
                "size_classes": len(self._buckets),
                "spills": self.spills,
                "writeback_errors": len(self._wb_errors),
                "lower": self.lower.stats() if self.lower else None}


# ----------------------------------------------------------------------
# Sharded concurrent backend
# ----------------------------------------------------------------------

def pspec_split_axis(shape: Tuple[int, ...],
                     logical: Optional[Tuple[Optional[str], ...]] = None
                     ) -> Optional[int]:
    """Pick the split axis for a leaf from the active mesh's partition
    specs (``repro.distributed.sharding``): the first dimension the spec
    shards. Falls back to the largest dimension when no mesh is active
    or the leaf has no logical axes."""
    from repro.distributed import sharding
    ctx = sharding.current()
    if ctx is not None and logical is not None:
        spec = sharding.safe_spec(shape, ctx.spec(logical), ctx.mesh)
        for i, ax in enumerate(spec):
            if ax is not None:
                return i
    if not shape:
        return None
    return int(np.argmax(shape))


def default_split_axis(arr: np.ndarray) -> Optional[int]:
    """Default per-array split-axis choice: the largest dimension (the
    backend has no logical axis names for packed leaves)."""
    return pspec_split_axis(arr.shape)


def make_pspec_splitter(logical_by_shape: Dict[Tuple[int, ...],
                                               Tuple[Optional[str], ...]]):
    """Build a ``split_axis_fn`` for :class:`ShardedBackend` that follows
    the active mesh's partition specs. ``logical_by_shape`` maps a leaf
    shape to its logical axis names (e.g. ``{(4096, 1024): ('embed',
    'mlp')}`` — shapes are the stable handle once pytrees are packed to
    flat array lists). Leaves without an entry fall back to the
    largest-dimension default."""
    def split_axis(arr: np.ndarray) -> Optional[int]:
        return pspec_split_axis(arr.shape,
                                logical_by_shape.get(tuple(arr.shape)))
    return split_axis


class ShardedBackend(StorageBackend):
    """Per-host shard directories with concurrent shard I/O.

    Layout::

        <root>/<key>.meta.json            # struct + placement (commit point)
        <root>/shard_000/<key>.ckpt       # host 0's leaf pieces (frame;
        <root>/shard_001/<key>.ckpt       # .npz with fmt="npz") ...

    ``put`` packs the pytree (``repro.checkpoint.io.pack``), splits each
    large array along ``split_axis_fn(arr)`` into ``num_shards`` pieces
    (``np.array_split``, so ragged dims work), assigns small arrays
    whole to the least-loaded shard, writes all shard files concurrently
    and fsync'd, then commits by atomically writing the meta file — a
    reader never observes a torn checkpoint. ``get`` loads the shard
    files concurrently and re-assembles every leaf bit-exactly.
    """

    name = "sharded"
    META_SUFFIX = ".meta.json"
    SHARD_SUFFIXES = {"frame": ".ckpt", "npz": ".npz"}

    def __init__(self, root: str, num_shards: int = 4, *,
                 split_threshold_bytes: int = 1 << 16,
                 split_axis_fn=default_split_axis,
                 max_workers: Optional[int] = None, fmt: str = "frame"):
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if fmt not in self.SHARD_SUFFIXES:
            raise ValueError(f"fmt must be one of "
                             f"{tuple(self.SHARD_SUFFIXES)}")
        self.root = root
        self.persist_root = root
        self.num_shards = num_shards
        self.split_threshold_bytes = split_threshold_bytes
        self.split_axis_fn = split_axis_fn
        self.fmt = fmt
        os.makedirs(root, exist_ok=True)
        for k in range(num_shards):
            os.makedirs(self._shard_dir(k), exist_ok=True)
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers or num_shards,
            thread_name_prefix="shard-io")
        # keys whose shard files are being written right now (meta not
        # yet committed): the orphan sweeper must not reap them
        self._active_puts: set = set()
        self._active_lock = threading.Lock()

    # ------------------------------------------------------------------
    def _shard_dir(self, k: int) -> str:
        return os.path.join(self.root, f"shard_{k:03d}")

    def _shard_path(self, k: int, key: str,
                    fmt: Optional[str] = None) -> str:
        return os.path.join(self._shard_dir(k),
                            f"{key}{self.SHARD_SUFFIXES[fmt or self.fmt]}")

    def _find_shard(self, k: int, key: str) -> str:
        for fmt in sorted(self.SHARD_SUFFIXES, key=lambda f: f != self.fmt):
            p = self._shard_path(k, key, fmt)
            if os.path.exists(p):
                return p
        raise FileNotFoundError(
            f"no shard file for {key!r} in {self._shard_dir(k)}")

    def _save_shard(self, k: int, key: str,
                    payload: Dict[str, np.ndarray]) -> int:
        if self.fmt == "frame":
            # streamed: each leaf piece goes straight into the shard
            # file via memoryview — no intermediate npz/zip blob
            n = cio.save_frame_payload(self._shard_path(k, key), payload)
        else:
            n = cio.save_npz(self._shard_path(k, key), payload)
        for fmt in self.SHARD_SUFFIXES:   # drop a stale cross-format file
            if fmt != self.fmt:
                try:
                    os.unlink(self._shard_path(k, key, fmt))
                except FileNotFoundError:
                    pass
        return n

    def _load_shard(self, k: int, key: str) -> Dict[str, np.ndarray]:
        path = self._find_shard(k, key)
        if cio.is_frame_file(path):
            return cio.read_frame(path)[1]
        return cio.load_npz(path)

    def _meta_path(self, key: str) -> str:
        return os.path.join(self.root, f"{key}{self.META_SUFFIX}")

    # ------------------------------------------------------------------
    def put(self, key: str, obj: Any) -> int:
        with self._active_lock:
            self._active_puts.add(key)
        try:
            with trace_span("backend.put", "backend", tier=self.name,
                            key=key, shards=self.num_shards):
                return self._put(key, obj)
        finally:
            with self._active_lock:
                self._active_puts.discard(key)

    def _put(self, key: str, obj: Any) -> int:
        struct, arrays = cio.pack(obj)
        payloads: List[Dict[str, np.ndarray]] = [
            {} for _ in range(self.num_shards)]
        loads = [0] * self.num_shards
        placements = []
        for i, arr in enumerate(arrays):
            arr = np.asarray(arr)
            axis = (self.split_axis_fn(arr)
                    if arr.nbytes >= self.split_threshold_bytes
                    and arr.ndim >= 1 else None)
            if (axis is not None and self.num_shards > 1
                    and arr.shape[axis] >= self.num_shards):
                pieces = np.array_split(arr, self.num_shards, axis=axis)
                for k, piece in enumerate(pieces):
                    payloads[k][f"a{i}"] = piece
                    loads[k] += piece.nbytes
                placements.append({"kind": "split", "axis": int(axis)})
            else:
                k = int(np.argmin(loads))
                payloads[k][f"a{i}"] = arr
                loads[k] += max(arr.nbytes, 1)
                placements.append({"kind": "whole", "shard": k})
        used = [k for k in range(self.num_shards) if payloads[k]]
        futs = {k: self._pool.submit(self._save_shard, k, key, payloads[k])
                for k in used}
        nbytes = sum(f.result() for f in futs.values())
        meta = {"struct": struct, "placements": placements, "shards": used,
                "num_shards": self.num_shards, "nbytes": nbytes,
                "format": self.fmt}
        meta_bytes = cio.atomic_write(
            self._meta_path(key),
            lambda f: f.write(json.dumps(meta).encode("utf-8")))
        return nbytes + meta_bytes

    def get(self, key: str) -> Any:
        try:
            with open(self._meta_path(key), encoding="utf-8") as f:
                meta = json.load(f)
        except FileNotFoundError:
            raise FileNotFoundError(f"no sharded blob {key!r} in {self.root}")
        futs = {k: self._pool.submit(self._load_shard, k, key)
                for k in meta["shards"]}
        shard_data = {k: f.result() for k, f in futs.items()}
        arrays: List[np.ndarray] = []
        for i, pl in enumerate(meta["placements"]):
            name = f"a{i}"
            if pl["kind"] == "whole":
                arrays.append(shard_data[pl["shard"]][name])
            else:
                pieces = [shard_data[k][name] for k in meta["shards"]
                          if name in shard_data[k]]
                arrays.append(np.concatenate(pieces, axis=pl["axis"]))
        return cio.unpack(meta["struct"], arrays)

    def patch(self, key: str, patch: PatchSet) -> int:
        """Patch a sharded blob range-wise: re-split each span exactly
        as ``put`` placed its leaf (same axis, same ``array_split``
        boundaries) and pwrite the intersecting pieces into their shard
        frames concurrently — a row range touching one shard's slice
        writes only that shard. The meta file never changes —
        placements and sizes are invariant under an in-place patch."""
        ps = PatchSet.coerce(patch)
        try:
            with open(self._meta_path(key), encoding="utf-8") as f:
                meta = json.load(f)
        except FileNotFoundError:
            raise FileNotFoundError(f"no sharded blob {key!r} in {self.root}")
        if meta.get("format", "npz") != "frame":
            raise ValueError(
                f"cannot patch npz shards of {key!r} in place; "
                f"incremental persistence requires the frame format")
        parts = int(meta["num_shards"])
        per_shard: Dict[int, PatchSet] = {}
        for name in ps:
            i = int(name[1:])
            pl = meta["placements"][i]
            shape = ps.shape_of(name)
            if pl["kind"] == "whole":
                tgt = per_shard.setdefault(pl["shard"], PatchSet())
                for sp in ps[name]:
                    tgt.add(name, sp.start, sp.data, shape)
                continue
            axis = int(pl["axis"])
            sizes = split_sizes(shape[axis], parts)
            bounds = np.cumsum([0] + sizes).tolist()
            for sp in ps[name]:
                a = np.asarray(sp.data)
                for k in range(parts):
                    lo, hi = int(bounds[k]), int(bounds[k + 1])
                    if lo == hi:
                        continue
                    if axis == 0:
                        # the split axis is the span axis: intersect the
                        # row range with this shard's slice
                        s, e = max(sp.start, lo), min(sp.stop, hi)
                        if s >= e:
                            continue
                        piece_shape = (sizes[k],) + tuple(shape[1:])
                        per_shard.setdefault(k, PatchSet()).add(
                            name, s - lo, a[s - sp.start:e - sp.start],
                            piece_shape)
                    else:
                        # split along a tail axis: every shard holds all
                        # rows, so the span start carries over and only
                        # the tail columns are sliced
                        sel = [slice(None)] * a.ndim
                        sel[axis] = slice(lo, hi)
                        piece_shape = tuple(
                            hi - lo if d == axis else shape[d]
                            for d in range(len(shape)))
                        per_shard.setdefault(k, PatchSet()).add(
                            name, sp.start, a[tuple(sel)], piece_shape)
        futs = {k: self._pool.submit(self._patch_shard, k, key, upd)
                for k, upd in per_shard.items()}
        return sum(f.result() for f in futs.values())

    def _patch_shard(self, k: int, key: str, updates: PatchSet) -> int:
        return cio.patch_frame(self._find_shard(k, key), updates)

    def delete(self, key: str) -> None:
        try:
            os.unlink(self._meta_path(key))
        except FileNotFoundError:
            pass
        # scan the shard dirs present on disk, not range(num_shards): the
        # blob may have been written under a different shard count (or a
        # different format)
        for d in os.listdir(self.root):
            if not d.startswith("shard_"):
                continue
            for suffix in self.SHARD_SUFFIXES.values():
                try:
                    os.unlink(os.path.join(self.root, d, f"{key}{suffix}"))
                except FileNotFoundError:
                    pass

    def exists(self, key: str) -> bool:
        return os.path.exists(self._meta_path(key))

    def verify(self, key: str) -> Optional[str]:
        """Re-verify every shard file: frame shards recompute each leaf
        piece's sha256, npz shards fully decode. The meta file itself is
        validated as JSON first."""
        try:
            with open(self._meta_path(key), encoding="utf-8") as f:
                meta = json.load(f)
        except FileNotFoundError:
            raise FileNotFoundError(f"no sharded blob {key!r} in {self.root}")
        except Exception as e:
            return f"meta: {type(e).__name__}: {e}"
        for k in meta["shards"]:
            try:
                path = self._find_shard(k, key)
                if cio.is_frame_file(path):
                    cio.read_frame(path, verify=True)
                else:
                    cio.load_npz(path)
            except Exception as e:
                # a missing shard file *is* corruption here: the meta
                # commit point says the blob should be whole
                return f"shard {k}: {type(e).__name__}: {e}"
        return None

    def sweep_orphans(self, min_age_s: float = 60.0) -> int:
        """Reap shard files whose key has no committed meta file — the
        leftovers of a put that crashed before its commit point. Keys
        with a put in flight right now are skipped."""
        with self._active_lock:
            active = set(self._active_puts)
        removed = 0
        cutoff = time.time() - min_age_s
        for d in os.listdir(self.root):
            if not d.startswith("shard_"):
                continue
            for f in os.listdir(os.path.join(self.root, d)):
                for suffix in self.SHARD_SUFFIXES.values():
                    if not f.endswith(suffix):
                        continue
                    key = f[:-len(suffix)]
                    if key in active or self.exists(key):
                        continue
                    p = os.path.join(self.root, d, f)
                    try:
                        if os.path.getmtime(p) <= cutoff:
                            os.unlink(p)
                            removed += 1
                    except OSError:
                        pass
        return removed

    def keys(self) -> List[str]:
        n = len(self.META_SUFFIX)
        return sorted(f[:-n] for f in os.listdir(self.root)
                      if f.endswith(self.META_SUFFIX))

    def url(self, key: str) -> str:
        return self._meta_path(key)

    def close(self) -> None:
        self.flush()
        self._pool.shutdown(wait=True)

    def stats(self) -> Dict[str, Any]:
        return {"backend": self.name, "num_shards": self.num_shards}


# ----------------------------------------------------------------------
# Factory
# ----------------------------------------------------------------------

BACKENDS = ("local", "memory", "sharded", "remote")


def make_backend(name: str, root: Optional[str], *, shards: int = 4,
                 capacity_mb: Optional[float] = None,
                 memory_spill: bool = True,
                 remote_url: Optional[str] = None,
                 chunk_mb: float = 4.0, max_retries: int = 4,
                 remote_fault_rate: float = 0.0,
                 fmt: str = "frame",
                 eviction: str = "fifo") -> StorageBackend:
    """Deprecated shim: build a backend by legacy name. New code should
    declare the stack with :class:`repro.checkpoint.config.StoreConfig`
    / :class:`~repro.checkpoint.config.TierSpec` — this delegates the
    name -> tier-list interpretation to
    :meth:`StoreConfig.from_legacy` and builds from there."""
    import warnings
    warnings.warn(
        "make_backend() is deprecated; declare the tier stack with "
        "repro.checkpoint.config.StoreConfig and call build_backend()",
        DeprecationWarning, stacklevel=2)
    from repro.checkpoint.config import StoreConfig
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; choose from {BACKENDS}")
    if name == "local" and root is None:
        raise ValueError("local backend requires a root directory")
    if name == "sharded" and root is None:
        raise ValueError("sharded backend requires a root directory")
    if name == "remote" and remote_url is None and root is None:
        raise ValueError("remote backend requires --remote-url or a root "
                         "directory (which becomes file://<root>)")
    if name == "memory" and not memory_spill:
        root = None  # pure-RAM tier: no lower backend to spill to
    cfg = StoreConfig.from_legacy(
        root, backend=name, shards=shards, capacity_mb=capacity_mb,
        remote_url=remote_url, chunk_mb=chunk_mb, max_retries=max_retries,
        remote_fault_rate=remote_fault_rate, fmt=fmt, eviction=eviction)
    return cfg.build_backend()
