"""Checkpoint chain store: full/diff/batch semantics over any backend.

The store maps the paper's checkpoint chain (full model states,
per-iteration differentials, batched differentials) onto a pluggable
:class:`repro.checkpoint.backends.StorageBackend` and keeps the index in
an append-only :class:`repro.checkpoint.journal.ManifestJournal` —
O(1) journal bytes per write instead of the seed's full
``manifest.json`` rewrite, with periodic compaction.

Keys (backend-independent)::

    full_00000010                # model state M_t
    diff_00000011                # one differential (G̃_t)
    batch_00000012_00000015      # batched differentials
    patch_00000013               # incremental persist: dirty leaves only

The ``patches`` kind is the incremental-merging persistence engine
(LowDiff+ §VI): each patch blob holds just the leaves that changed
since the previous persist, against a ``base`` full whose manifest
entry records the path -> frame-leaf-name map. Recovery loads the base
and overlays the ordered patch chain (:meth:`load_latest_state`); the
background fold (:meth:`fold_plan` / :meth:`fold_updates` /
:meth:`fold_slice` / :meth:`fold_commit`, driven by the maintenance
service) pwrites the accumulated dirty leaves into the base frame in
place (``StorageBackend.patch``) and retires the chain, so
``load_full`` stays one frame read and the chain never grows
unboundedly. Crash consistency: a patch blob is durable and journaled
*before* any in-place fold touches the base, so recovery after a kill
at any fold point replays the chain over the base and lands
bit-identical on the last committed persist.

Chain-aware garbage collection (`gc`) deletes full checkpoints and
differential blobs superseded by a newer full, keeping
``retention_fulls`` fulls plus everything needed to replay the latest
chain — Check-N-Run-style quota management for differential chains.
The mark phase (:meth:`gc_plan`) and sweep phase (:meth:`gc_apply`)
are split so the background maintenance service can journal its
progress and sweep in bounded slices; :meth:`gc` composes them for the
synchronous fallback path.

``host_id`` selects the multi-controller journal: each host appends to
its own :class:`~repro.checkpoint.journal.SegmentedManifestJournal`
segment, and every reader reconstructs the same merged manifest.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.obs.trace import trace_span

from repro.checkpoint import io as cio
from repro.checkpoint.backends import LocalFSBackend, StorageBackend
from repro.checkpoint.patchset import (PatchSet, RowUpdate, Span,
                                       merge_span_chain)
from repro.compression.quant_span import QuantSpan
from repro.checkpoint.journal import (JournalTap, ManifestJournal,
                                      MemoryJournal,
                                      SegmentedManifestJournal, _entry_key)

#: manifest kinds that reference a backend blob (chain entries)
CHAIN_KINDS = ("fulls", "diffs", "batches", "patches")

#: source-aware durability ranking for recovery's fallback order: a
#: peer-adopted entry (bytes only reachable over the network, possibly
#: a pre-fold snapshot) ranks below the RAM tier, which ranks below any
#: durable tier. Entries without a tier tag (pre-provenance manifests)
#: are treated as durable — exactly the old behavior.
DURABILITY_RANK = {"peer": 0, "memory": 1}


def entry_rank(entry: dict) -> int:
    return DURABILITY_RANK.get(entry.get("tier"), 2)


def order_fulls(fulls: List[dict]) -> List[dict]:
    """Recovery preference order over full-checkpoint entries, newest
    and most-durable first: by the state the blob actually represents
    (``state_step`` — a folded base has advanced past its nominal
    ``step``), then by nominal step, then by source durability. The
    provenance tie-break is the stale-shadow guard: a peer-served
    replica of some step can never shadow a durable full whose folded
    state is at least as new."""
    return sorted(fulls,
                  key=lambda e: (int(e.get("state_step", e["step"])),
                                 int(e["step"]), entry_rank(e)),
                  reverse=True)


def walk_leaves(tree, prefix: str = ""):
    """Yield ``(path, leaf)`` for every array leaf of a nested
    dict/list/tuple state, depth-first in insertion order — the same
    traversal :func:`repro.checkpoint.io.pack` uses, so paths line up
    1:1 with frame payload names."""
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from walk_leaves(v, f"{prefix}{k}/")
    elif isinstance(tree, (RowUpdate, QuantSpan)):
        # a row-sparse (or quantized) leaf update is itself a leaf: its
        # spans address one frame payload array, not nested children
        yield prefix[:-1], tree
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from walk_leaves(v, f"{prefix}{i}/")
    else:
        yield prefix[:-1], tree


def payload_names(state) -> Dict[str, str]:
    """Map each array leaf's path to its frame payload name (``aN``).

    Uses array identity: ``pack`` appends ``np.asarray(leaf)`` — the
    *same object* for ndarray leaves — so matching ids recovers exactly
    the name each leaf serializes under, with no assumption about
    pack's traversal order. Non-array leaves (python scalars live in
    the struct, not the data section) are skipped: they cannot be
    patched in place."""
    _, arrays = cio.pack(state)
    by_id = {id(a): f"a{i}" for i, a in enumerate(arrays)}
    names = {}
    for path, leaf in walk_leaves(state):
        if isinstance(leaf, np.ndarray):
            name = by_id.get(id(leaf))
            if name is not None:
                names[path] = name
    return names


def merge_updates(state, updates) -> None:
    """Overlay a patch blob's partial state dict onto ``state`` in
    place (leaf-wise; nested dicts merge, a :class:`RowUpdate` or
    :class:`~repro.compression.quant_span.QuantSpan` splices its row
    spans into the base leaf, anything else replaces). A QuantSpan is
    dequantized *here*, exactly once: the merged state always holds raw
    rows, so a later persist or fold can never re-quantize an already
    quantized value."""
    for k, v in updates.items():
        if isinstance(v, dict) and isinstance(state.get(k), dict):
            merge_updates(state[k], v)
        elif isinstance(v, (RowUpdate, QuantSpan)):
            # base leaves are often read-only memmap views of the full
            # frame — splice into a private copy, never the file
            # (QuantSpan.spans() yields dequantized raw rows)
            base = np.array(state[k])
            for sp in v.spans():
                base[sp.start:sp.stop] = sp.data
            state[k] = base
        else:
            state[k] = v


class CheckpointStore:
    def __init__(self, root: Optional[str] = None, *,
                 backend: Optional[StorageBackend] = None,
                 retention_fulls: int = 0, compact_every: int = 256,
                 host_id: Optional[str] = None):
        if backend is None:
            if root is None:
                raise ValueError("CheckpointStore needs a root or a backend")
            backend = LocalFSBackend(root)
        self.backend = backend
        self.root = root if root is not None else backend.persist_root
        self.retention_fulls = retention_fulls
        self._lock = threading.RLock()
        if backend.persist_root is not None:
            if host_id is not None:
                self.journal = SegmentedManifestJournal(
                    backend.persist_root, host=host_id,
                    compact_every=compact_every)
            else:
                self.journal = ManifestJournal(backend.persist_root,
                                               compact_every=compact_every)
        else:
            self.journal = MemoryJournal()
        # a backend that replicates manifest records to peers (the peer
        # tier) taps every journal append; the journal implementations
        # stay oblivious
        tap = getattr(backend, "on_journal_append", None)
        if tap is not None:
            self.journal = JournalTap(self.journal, tap)
        self.host_id = host_id
        #: attached background MaintenanceService (see
        #: repro.maintenance); None means synchronous fallbacks
        self.maintenance = None
        from repro.obs.metrics import InstrumentSet
        self._inst = InstrumentSet("store")
        self._bytes_written = self._inst.counter("bytes_written")
        self._writes = self._inst.counter("writes")
        self._gc_deleted = self._inst.counter("gc_deleted")
        self._quarantined = self._inst.counter("quarantined")
        self._folds = self._inst.counter("folds")
        self._fold_bytes = self._inst.counter("fold_bytes")
        self._folded_patches = self._inst.counter("folded_patches")
        #: highest chain-read amplification observed (chain overlay
        #: bytes / base frame bytes) — the adaptive fold trigger's input
        self._max_amplification = self._inst.gauge("max_amplification")
        #: per-save backend write latency (save_full/diff/batch/patch)
        self._write_time = self._inst.histogram("write_time_s")
        self._prune_missing()
        self._update_protected()

    # legacy attribute surface: tests and benchmarks read these raw
    @property
    def bytes_written(self) -> int:
        return int(self._bytes_written.value)

    @property
    def writes(self) -> int:
        return int(self._writes.value)

    @property
    def gc_deleted(self) -> int:
        return int(self._gc_deleted.value)

    @property
    def quarantined_count(self) -> int:
        return int(self._quarantined.value)

    @property
    def folds(self) -> int:
        return int(self._folds.value)

    @property
    def fold_bytes(self) -> int:
        return int(self._fold_bytes.value)

    @property
    def folded_patches(self) -> int:
        return int(self._folded_patches.value)

    @property
    def max_amplification(self) -> float:
        return float(self._max_amplification.value)

    def instruments(self):
        """The backing :class:`~repro.obs.metrics.InstrumentSet`."""
        return self._inst

    # ------------------------------------------------------------------
    @property
    def manifest(self) -> Dict[str, List[dict]]:
        return self.journal.manifest

    def _record(self, kind: str, entry: dict, nbytes: int):
        # tag each entry with the serialization format the backend wrote
        # (frame / npz) — mixed-format chains stay self-describing in
        # the journal even though readers also sniff the magic bytes
        entry.setdefault("format", getattr(self.backend, "fmt", "npz"))
        # source provenance: which durability class acked this put (see
        # order_fulls — recovery's source-aware fallback order)
        entry.setdefault("tier", getattr(self.backend, "provenance",
                                         getattr(self.backend, "name",
                                                 "local")))
        with self._lock:
            self.journal.append("add", kind, entry=entry)
        self._bytes_written.add(nbytes)
        self._writes.add(1)

    # ------------------------------------------------------------------
    def save_full(self, step: int, state, *, record_names: bool = False)\
            -> str:
        key = f"full_{step:08d}"
        # pre-protect: eviction runs inside put(), before the journal
        # records the entry — the incoming blob must already be exempt
        self._update_protected(extra={key})
        with trace_span("store.save_full", "store", key=key) as sp:
            t0 = time.perf_counter()
            n = self.backend.put(key, state)
            self._write_time.observe(time.perf_counter() - t0)
            sp.set(bytes=n)
        entry = {"step": step, "key": key,
                 "path": self.backend.url(key), "bytes": n}
        if record_names:
            # path -> frame leaf name map: what lets a later patch chain
            # address this full's leaves for the in-place fold
            entry["names"] = payload_names(state)
        self._record("fulls", entry, n)
        self._update_protected()
        if self.retention_fulls:
            self.request_gc()
        return key

    def save_patch(self, step: int, base_key: str, updates) -> str:
        """Persist only what changed since the last persist, as a
        durable patch blob chained onto ``base_key`` — the incremental-
        merging persistence write path. ``updates`` is a partial state
        dict (same nesting as the base full): whole dirty leaves, or
        :class:`RowUpdate` values carrying just the dirty row spans.
        Row extents are journaled in the manifest entry, so the chain's
        shape is inspectable without loading blobs. The blob lands and
        is journaled *before* any in-place fold touches the base frame,
        so it doubles as the fold's write-ahead log."""
        if getattr(self.backend, "fmt", "npz") == "npz":
            raise ValueError(
                "incremental persistence (save_patch) requires the "
                "frame checkpoint format; this store writes npz — use "
                "--format frame or --persist-mode full")
        key = f"patch_{step:08d}"
        self._update_protected(extra={key})
        with trace_span("store.save_patch", "store", key=key) as sp:
            t0 = time.perf_counter()
            n = self.backend.put(key, {"base": base_key, "step": step,
                                       "updates": updates})
            self._write_time.observe(time.perf_counter() - t0)
            sp.set(bytes=n)
        entry = {"step": step, "key": key, "base": base_key,
                 "path": self.backend.url(key), "bytes": n}
        extents = {}
        span_bytes = 0
        codecs = set()
        for path, leaf in walk_leaves(updates):
            if isinstance(leaf, (RowUpdate, QuantSpan)):
                extents[path] = leaf.extents()
                if isinstance(leaf, QuantSpan):
                    codecs.add(f"int{leaf.bits}")
                    span_bytes += leaf.logical_nbytes
                else:
                    span_bytes += leaf.nbytes
        if extents:
            entry["extents"] = extents
            # logical (dequantized-overlay) span bytes, alongside the
            # stored "bytes" the amplification trigger reads — the gap
            # between the two is the quantizer's realized ratio
            entry["span_bytes"] = int(span_bytes)
        if codecs:
            entry["codec"] = sorted(codecs)
        self._record("patches", entry, n)
        self._update_protected()
        with self._lock:
            self._max_amplification.set(
                max(self.max_amplification, self.chain_amplification()))
        return key

    def chain_amplification(self, base_key: Optional[str] = None) -> float:
        """Chain-read amplification of a base full's patch chain:
        **stored** chain bytes recovery must read on top of the base
        frame, divided by the base frame's own bytes. Each patch entry's
        ``bytes`` is what ``StorageBackend.put`` actually wrote — the
        post-codec wire size — so a quantized chain (``--diff-quant``)
        that is 4-8x smaller on disk amplifies 4-8x less and does *not*
        trigger early folds on its logical (dequantized) span size;
        that logical size is journaled separately as ``span_bytes``.
        Defaults to the newest addressable full (the chain ``fold_plan``
        would pick). 0.0 when there is no chain. Lock-only — cheap
        enough to evaluate per persist, which is exactly what the
        adaptive fold trigger does."""
        with self._lock:
            if base_key is None:
                fulls = [e for e in self.manifest["fulls"] if "names" in e]
                if not fulls:
                    return 0.0
                entry = max(fulls, key=lambda e: int(e["step"]))
                base_key = self._entry_key(entry)
            else:
                entry = next((e for e in self.manifest["fulls"]
                              if self._entry_key(e) == base_key), None)
                if entry is None:
                    return 0.0
            base_bytes = max(int(entry.get("bytes", 0)), 1)
            chain = sum(int(e.get("bytes", 0))
                        for e in self.manifest.get("patches", [])
                        if e.get("base") == base_key)
        return chain / base_bytes

    def save_diff(self, step: int, payload) -> str:
        key = f"diff_{step:08d}"
        self._update_protected(extra={key})
        with trace_span("store.save_diff", "store", key=key) as sp:
            t0 = time.perf_counter()
            n = self.backend.put(key, payload)
            self._write_time.observe(time.perf_counter() - t0)
            sp.set(bytes=n)
        self._record("diffs", {"step": step, "key": key,
                               "path": self.backend.url(key), "bytes": n}, n)
        self._update_protected()
        return key

    def save_batch(self, first: int, last: int, payloads: list,
                   mode: str = "concat") -> str:
        """One I/O operation carrying differentials [first..last]."""
        key = f"batch_{first:08d}_{last:08d}"
        self._update_protected(extra={key})
        with trace_span("store.save_batch", "store", key=key,
                        n=len(payloads)) as sp:
            t0 = time.perf_counter()
            n = self.backend.put(key, {"mode": mode, "first": first,
                                       "last": last, "payloads": payloads})
            self._write_time.observe(time.perf_counter() - t0)
            sp.set(bytes=n)
        self._record("batches", {"first": first, "last": last, "key": key,
                                 "path": self.backend.url(key),
                                 "bytes": n}, n)
        self._update_protected()
        return key

    # ------------------------------------------------------------------
    def _update_protected(self, extra=()):
        """Tell a capacity-bounded backend tier which blobs form the
        newest full's replay chain (the full itself plus every
        diff/batch after its step): chain-aware eviction must keep
        those resident — they are exactly what recovery reads.
        ``extra`` pre-protects a key whose put is about to run.

        protect() is called while still holding the store lock:
        computing the set and applying it must be atomic, or two
        concurrent writers (the batch consumer and the full-persist
        pool) could apply their sets out of order and un-protect the
        newest chain."""
        keys = set(extra)
        with self._lock:
            fulls = self.manifest["fulls"]
            if not fulls and not keys:
                return
            if fulls:
                newest = max(fulls, key=lambda e: e["step"])
                cutoff = newest["step"]
                keys.add(self._entry_key(newest))
                keys.update(self._entry_key(e)
                            for e in self.manifest["diffs"]
                            if e["step"] > cutoff)
                keys.update(self._entry_key(e)
                            for e in self.manifest["batches"]
                            if e["last"] > cutoff)
                keys.update(self._entry_key(e)
                            for e in self.manifest.get("patches", [])
                            if e["step"] > cutoff)
            self.backend.protect(keys)

    # ------------------------------------------------------------------
    @staticmethod
    def _entry_key(entry: dict) -> str:
        return _entry_key(entry)

    def _prune_missing(self):
        """Drop manifest entries whose blob never became durable — e.g. a
        crash after the journal append but before an async tier's
        write-back landed. Write-back is FIFO, so the missing blobs are a
        suffix of the write order and pruning restores the seed's
        guarantee: recovery always sees a consistent chain prefix."""
        with self._lock:
            for kind in CHAIN_KINDS:
                for e in list(self.manifest.get(kind, [])):
                    key = self._entry_key(e)
                    if not self.backend.exists(key):
                        self.journal.append("del", kind, key=key)

    def latest_full(self) -> Optional[dict]:
        with self._lock:
            fulls = sorted(self.manifest["fulls"], key=lambda e: e["step"])
        return fulls[-1] if fulls else None

    def load_full(self, entry: dict):
        return self.backend.get(self._entry_key(entry))

    def diffs_after(self, step: int) -> List[Tuple[int, Any]]:
        """Ordered (step, payload) list of differentials with step > given.

        Each step appears exactly once: a differential present both as a
        standalone ``diff_*`` blob and inside a ``batch_*`` blob (e.g. a
        retried write that landed twice) is returned from the standalone
        blob only — replaying it twice through Adam would advance the
        moment estimates twice and corrupt the recovered state.
        Non-overlapping batches, and batches every step of which is
        already covered, are skipped without touching storage."""
        with self._lock:
            diffs = list(self.manifest["diffs"])
            batches = list(self.manifest["batches"])
        chosen: Dict[int, dict] = {}
        for e in diffs:                 # duplicate steps: latest entry wins
            if e["step"] > step:
                chosen[e["step"]] = e
        out = {s: self.backend.get(self._entry_key(e))
               for s, e in chosen.items()}
        for e in batches:
            if e["last"] <= step:
                continue
            lo = max(step, e["first"] - 1)
            if all(s in out for s in range(lo + 1, e["last"] + 1)):
                continue                # fully covered: skip the fetch
            blob = self.backend.get(self._entry_key(e))
            for i, pay in enumerate(blob["payloads"]):
                s = blob["first"] + i
                if s > step and s not in out:
                    out[s] = pay
        return sorted(out.items())

    # ------------------------------------------------------------------
    # incremental-merging persistence: patch chains + background fold
    # ------------------------------------------------------------------
    def patch_chain(self, base_key: str) -> List[dict]:
        """Ordered patch entries chained onto ``base_key``."""
        with self._lock:
            return sorted((e for e in self.manifest.get("patches", [])
                           if e.get("base") == base_key),
                          key=lambda e: e["step"])

    def load_latest_state(self):
        """Newest persisted state: the latest loadable full overlaid
        with its ordered patch chain. Returns ``(state, step)`` where
        ``step`` is the last committed persist the state represents
        (the last patch's step, or the full's folded-through step).
        Unreadable fulls fall back to older ones (as in
        ``load_latest_chain``); an unreadable patch cuts the chain at
        the gap — the prefix is still a committed persist. Raises
        FileNotFoundError when no full checkpoint is loadable."""
        from repro.checkpoint.io import FrameCorruptionError
        from repro.checkpoint.remote import RetryExhaustedError
        with self._lock:
            fulls = order_fulls(self.manifest["fulls"])
        if not fulls:
            raise FileNotFoundError("no persisted checkpoint")
        last_err = None
        for entry in fulls:
            try:
                state = self.load_full(entry)
            except (FileNotFoundError, RetryExhaustedError,
                    FrameCorruptionError) as e:
                last_err = e
                continue
            step = int(entry.get("state_step", entry["step"]))
            for pe in self.patch_chain(self._entry_key(entry)):
                try:
                    blob = self.backend.get(self._entry_key(pe))
                except (FileNotFoundError, RetryExhaustedError,
                        FrameCorruptionError):
                    break            # cut at the gap: prefix is committed
                merge_updates(state, blob["updates"])
                step = max(step, int(pe["step"]))
            return state, step
        raise FileNotFoundError(
            f"none of {len(fulls)} full checkpoints is loadable "
            f"(last error: {last_err})")

    # ------------------------------------------------------------------
    # peer-manifest adoption (replacement-host recovery)
    # ------------------------------------------------------------------
    def adopt_peer_manifest(self, src: Optional[str] = None) -> int:
        """Rebuild a dead host's manifest from the records its peers
        hold (the peer tier replicates every journal append via the
        journal tap). Called on a replacement host whose local journal
        is empty — or on a restarted host to pick up entries it lost.

        Semantics:

        * empty local manifest (no fulls): the peers' record stream is
          replayed verbatim — add / del / replace in order — so the
          adopted manifest is exactly the dead host's, with every
          adopted entry re-tagged ``tier="peer"`` (its bytes are only
          reachable over the network until re-persisted).
        * local fulls already exist (restart with intact storage): only
          ``add`` records for keys the local manifest does not know are
          adopted — a peer's del/replace must never regress local
          durable state, and the ``tier="peer"`` tag plus
          :func:`order_fulls` guarantee an adopted entry cannot shadow
          a newer durable full.

        Adopted appends bypass the journal tap (no echo back to the
        peers), and entries whose blob is reachable neither locally nor
        on any peer are pruned afterwards. Returns the number of
        records applied; stores without a peer tier return 0."""
        fetch = getattr(self.backend, "peer_manifest", None)
        if fetch is None:
            return 0
        records = fetch(src)
        applied = 0
        with self._lock:
            append = getattr(self.journal, "append_untapped",
                             self.journal.append)
            have_fulls = bool(self.manifest.get("fulls"))
            known = {(kind, self._entry_key(e))
                     for kind, entries in self.manifest.items()
                     for e in entries if isinstance(e, dict)}
            for _, _, rec in records:
                op, kind = rec.get("op"), rec.get("kind")
                entry, key = rec.get("entry"), rec.get("key")
                if op == "add" and entry is not None:
                    k = (kind, self._entry_key(entry))
                    if k in known:
                        continue
                    e = dict(entry)
                    e["tier"] = "peer"
                    append("add", kind, entry=e)
                    known.add(k)
                    applied += 1
                elif have_fulls:
                    continue   # never let peers mutate durable state
                elif op == "del" and key is not None:
                    append("del", kind, key=key)
                    known.discard((kind, key))
                    applied += 1
                elif op == "replace" and entry is not None:
                    e = dict(entry)
                    e["tier"] = "peer"
                    append("replace", kind, key=key, entry=e)
                    known.add((kind, self._entry_key(e)))
                    applied += 1
        self._prune_missing()
        self._update_protected()
        return applied

    def fold_plan(self):
        """Mark phase of the incremental merge: ``(base_key,
        [patch keys in step order], state_step)`` for the newest
        foldable patch chain, or None when there is nothing to fold.
        Fulls are considered newest-first, but an *older* full's chain
        is still foldable — a restart cuts a fresh base and would
        otherwise orphan the previous chain forever (it remains the
        recovery fallback if the newest full turns unreadable, and it
        must stay bounded). Lock-only — no I/O — so the maintenance
        service can journal the plan before touching storage."""
        with self._lock:
            fulls = sorted(self.manifest["fulls"],
                           key=lambda e: e["step"], reverse=True)
            for entry in fulls:
                if "names" not in entry:
                    continue   # no leaf-name map: frame not addressable
                base_key = self._entry_key(entry)
                patches = sorted(
                    (e for e in self.manifest.get("patches", [])
                     if e.get("base") == base_key),
                    key=lambda e: e["step"])
                if patches:
                    return (base_key,
                            [self._entry_key(e) for e in patches],
                            int(patches[-1]["step"]))
        return None

    def fold_updates(self, base_key: str,
                     patch_keys: List[str]) -> Optional[PatchSet]:
        """Load the planned patch chain and merge it into a
        :class:`PatchSet` ready for ``backend.patch``. Overlapping row
        ranges merge *newest-wins* — walking the chain newest-first,
        each span contributes only the rows no later patch rewrote, so
        a thousand tiny patches of the same rows fold into one span of
        zero-copy views. A whole-leaf update is the full-cover span, so
        mixed leaf-/row-granular chains merge under the same rule.
        Returns None when the chain or its base is gone — superseded or
        already folded since the plan."""
        with self._lock:
            entry = next((e for e in self.manifest["fulls"]
                          if self._entry_key(e) == base_key), None)
            names = dict(entry["names"]) if entry and "names" in entry \
                else None
        if names is None:
            return None
        chains: Dict[str, List[List[Span]]] = {}
        shapes: Dict[str, tuple] = {}
        for key in patch_keys:
            try:
                blob = self.backend.get(key)
            except FileNotFoundError:
                return None
            for path, leaf in walk_leaves(blob["updates"]):
                if isinstance(leaf, (RowUpdate, QuantSpan)):
                    # QuantSpan.spans() dequantizes: the fold is
                    # dequantize -> newest-wins merge -> write *raw*
                    # into the base frame, so a folded base never holds
                    # (and can never re-quantize) quantized bytes
                    spans = leaf.spans()
                    shapes[path] = tuple(int(x) for x in leaf.shape)
                else:
                    a = np.asarray(leaf)
                    spans = [Span(0, a)]
                    shapes[path] = a.shape
                chains.setdefault(path, []).append(spans)
        out = PatchSet()
        for path, chain in chains.items():
            name = names.get(path)
            if name is None:
                raise KeyError(
                    f"patch leaf {path!r} is not addressable in base "
                    f"{base_key!r} (missing from its name map)")
            out.add_spans(name, merge_span_chain(chain), shapes[path])
        return out

    def fold_slice(self, base_key: str, updates) -> int:
        """Sweep phase, one bounded slice: pwrite these leaves into the
        base frame in place. Blob I/O only — never under the manifest
        lock."""
        with trace_span("store.fold_slice", "maintenance",
                        key=base_key) as sp:
            n = self.backend.patch(base_key, updates)
            sp.set(bytes=n)
        self._fold_bytes.add(n)
        return n

    def fold_commit(self, base_key: str, patch_keys: List[str],
                    state_step: int) -> None:
        """Retire a fully folded chain: advance the base entry's
        ``state_step`` (the persist step its bytes now represent)
        *first*, then delete the patch records and blobs. Idempotent at
        every boundary — a crash between any two deletions leaves a
        suffix of the chain, which recovery replays over the folded
        base to identical bytes."""
        with self._lock:
            entry = next((e for e in self.manifest["fulls"]
                          if self._entry_key(e) == base_key), None)
            if entry is not None and \
                    int(entry.get("state_step", entry["step"])) < state_step:
                e2 = dict(entry)
                e2["state_step"] = int(state_step)
                # one atomic journal record: a kill between a del and a
                # separate re-add would erase the only base full from
                # the manifest
                self.journal.append("replace", "fulls", entry=e2,
                                    key=base_key)
        for key in patch_keys:
            with self._lock:
                self.journal.append("del", "patches", key=key)
            self.backend.delete(key)
        self._folds.add(1)
        self._folded_patches.add(len(patch_keys))
        self._update_protected()

    def fold_sync(self, merge_slice: Optional[int] = None) -> int:
        """Synchronous fold (the ``--maintenance off`` path and tests):
        mark, sweep in ``merge_slice``-leaf slices, commit. Returns the
        number of patches folded."""
        plan = self.fold_plan()
        if plan is None:
            return 0
        base_key, patch_keys, state_step = plan
        updates = self.fold_updates(base_key, patch_keys)
        if updates is None:
            return 0
        names = updates.names()
        width = max(1, int(merge_slice)) if merge_slice else len(names) or 1
        for i in range(0, len(names), width):
            self.fold_slice(base_key, updates.subset(names[i:i + width]))
        self.fold_commit(base_key, patch_keys, state_step)
        return len(patch_keys)

    def request_fold(self) -> None:
        """Route the incremental merge off the hot path: schedule it on
        the attached maintenance service (non-blocking, journaled,
        sliced) or fall back to a synchronous fold. Either way the
        caller's persist thread never waits for the base rewrite."""
        svc = self.maintenance
        if svc is not None and svc.running:
            svc.request_fold()
            return
        self.fold_sync()

    # ------------------------------------------------------------------
    # garbage collection: mark (plan) / sweep (apply)
    # ------------------------------------------------------------------
    def gc_plan(self, retention_fulls: Optional[int] = None
                ) -> List[Tuple[str, str]]:
        """Mark phase: compute the ``[(kind, key), ...]`` list of blobs
        superseded by a newer full checkpoint — no I/O, manifest lock
        only. Keeps the newest ``retention_fulls`` fulls and every
        differential/batch that could still be needed to replay a chain
        from the *oldest retained* full (a batch straddling the cutoff
        is kept whole)."""
        keep = (self.retention_fulls if retention_fulls is None
                else retention_fulls)
        if keep < 1:
            return []
        doomed: List[Tuple[str, str]] = []
        with self._lock:
            fulls = sorted(self.manifest["fulls"], key=lambda e: e["step"])
            if len(fulls) <= keep:
                return doomed
            cutoff = fulls[-keep]["step"]
            doomed_fulls = set()
            for e in fulls[:-keep]:
                doomed_fulls.add(self._entry_key(e))
                doomed.append(("fulls", self._entry_key(e)))
            for e in self.manifest["diffs"]:
                if e["step"] <= cutoff:
                    doomed.append(("diffs", self._entry_key(e)))
            for e in self.manifest["batches"]:
                if e["last"] <= cutoff:
                    doomed.append(("batches", self._entry_key(e)))
            for e in self.manifest.get("patches", []):
                # a patch is dead once its base full is (it can only be
                # replayed over that exact frame) or once a newer
                # retained full supersedes its step
                if e["step"] <= cutoff or e.get("base") in doomed_fulls:
                    doomed.append(("patches", self._entry_key(e)))
        return doomed

    def _live_chain_keys(self, keep: int) -> set:
        """Keys the newest ``keep`` retained chains still need — the
        retained fulls plus every diff/batch replayable after the
        oldest retained full."""
        keys = set()
        with self._lock:
            fulls = sorted(self.manifest["fulls"], key=lambda e: e["step"])
            retained = fulls[-max(keep, 1):]
            if not retained:
                return keys
            cutoff = retained[0]["step"]
            retained_keys = {self._entry_key(e) for e in retained}
            keys.update(retained_keys)
            keys.update(self._entry_key(e) for e in self.manifest["diffs"]
                        if e["step"] > cutoff)
            keys.update(self._entry_key(e) for e in self.manifest["batches"]
                        if e["last"] > cutoff)
            keys.update(self._entry_key(e)
                        for e in self.manifest.get("patches", [])
                        if e["step"] > cutoff
                        and e.get("base") in retained_keys)
        return keys

    def gc_apply(self, doomed: List[Tuple[str, str]],
                 retention_fulls: Optional[int] = None,
                 crash_hook=None) -> Dict[str, int]:
        """Sweep phase: journal the deletion, then delete the blob, for
        each marked ``(kind, key)``. Idempotent — re-applying a slice
        after a crash re-journals a no-op del and re-deletes an absent
        blob. A key that re-entered the newest retained chains since the
        plan was computed (a stale plan after a same-step re-put) is
        skipped: the sweep must never delete a live-chain blob.

        Blob I/O runs *outside* the manifest lock so a background sweep
        never stalls the training hot path's journal appends.
        ``crash_hook(point, key)`` is a test seam fired between the
        journal del and the backend delete."""
        keep = (self.retention_fulls if retention_fulls is None
                else retention_fulls)
        live = self._live_chain_keys(keep)
        removed = {"fulls": 0, "diffs": 0, "batches": 0}
        for kind, key in doomed:
            if key in live:
                continue
            with self._lock:
                self.journal.append("del", kind, key=key)
            if crash_hook is not None:
                crash_hook("gc:mid_delete", key)
            self.backend.delete(key)
            removed[kind] = removed.get(kind, 0) + 1
            self._gc_deleted.add(1)
        self._update_protected()
        return removed

    def gc(self, retention_fulls: Optional[int] = None) -> Dict[str, int]:
        """Synchronous mark + sweep (the ``--maintenance off`` path and
        explicit calls). Returns per-kind delete counts."""
        doomed = self.gc_plan(retention_fulls)
        if not doomed:
            keep = (self.retention_fulls if retention_fulls is None
                    else retention_fulls)
            return {} if keep < 1 else {"fulls": 0, "diffs": 0,
                                        "batches": 0}
        return self.gc_apply(doomed, retention_fulls)

    def request_gc(self, retention_fulls: Optional[int] = None):
        """Route GC off the hot path: schedule it on the attached
        maintenance service (non-blocking) or fall back to a
        synchronous sweep when no service is attached."""
        svc = self.maintenance
        if svc is not None and svc.running:
            svc.request_gc(retention_fulls)
            return None
        return self.gc(retention_fulls)

    def scrub_targets(self) -> List[Tuple[str, str]]:
        """Every chain entry the integrity scrubber should walk, as
        ``(kind, key)`` — a point-in-time snapshot under the lock."""
        with self._lock:
            return [(kind, self._entry_key(e))
                    for kind in CHAIN_KINDS
                    for e in self.manifest.get(kind, [])]

    def merge_journal(self):
        """Fold journal state into its snapshot under the store lock: a
        segmented journal merges every host's segment (the
        multi-controller merge step); a plain journal just compacts."""
        with self._lock:
            self.journal.compact()

    # ------------------------------------------------------------------
    # quarantine (integrity scrubber)
    # ------------------------------------------------------------------
    def quarantine(self, kind: str, key: str, reason: str) -> bool:
        """Move a corrupt blob's manifest entry out of its chain kind
        into the ``quarantined`` list: recovery skips it proactively
        (`load_latest_chain` falls back to an older full / the chain
        cuts at the gap) instead of tripping over the corruption at
        restore time. The blob itself is kept for forensics; GC of
        quarantined entries is explicit (:meth:`drop_quarantined`)."""
        with self._lock:
            entry = next((e for e in self.manifest.get(kind, [])
                          if self._entry_key(e) == key), None)
            if entry is None:
                return False
            self.journal.append("del", kind, key=key)
            q = dict(entry)
            q.update({"key": key, "src_kind": kind, "reason": reason})
            self.journal.append("add", "quarantined", entry=q)
        self._quarantined.add(1)
        self._update_protected()
        return True

    def drop_quarantined(self) -> int:
        """Delete quarantined blobs and their records. Returns count."""
        with self._lock:
            entries = list(self.manifest.get("quarantined", []))
        n = 0
        for e in entries:
            key = self._entry_key(e)
            with self._lock:
                self.journal.append("del", "quarantined", key=key)
            self.backend.delete(key)
            n += 1
        return n

    # ------------------------------------------------------------------
    def flush(self, timeout: Optional[float] = None):
        """Block until every accepted write is durable at the lowest
        backend tier AND every pending maintenance slice has drained —
        same deadline/error-surfacing contract as the persist queue
        (maintenance task failures re-raise here as
        ``CheckpointingError``)."""
        self.backend.flush()
        if self.maintenance is not None:
            self.maintenance.drain(timeout)

    def attach_maintenance(self, service):
        """Attach (or detach with None) a background MaintenanceService;
        `save_full`'s retention GC and `flush`/`close` route through
        it once attached."""
        self.maintenance = service

    def close(self):
        svc, self.maintenance = self.maintenance, None
        if svc is not None:
            svc.stop()
            # stats() keeps reporting the service's final numbers after
            # close — the launcher prints strategy stats post-close
            self._maint_final = svc.stats()
        self.backend.close()
        self.journal.close()

    def stats(self):
        with self._lock:
            return {"writes": self.writes, "bytes": self.bytes_written,
                    "fulls": len(self.manifest["fulls"]),
                    "diffs": len(self.manifest["diffs"]),
                    "batches": len(self.manifest["batches"]),
                    "patches": len(self.manifest.get("patches", [])),
                    "folds": self.folds, "fold_bytes": self.fold_bytes,
                    "folded_patches": self.folded_patches,
                    "chain_amplification": self.chain_amplification(),
                    "max_amplification": self.max_amplification,
                    "gc_deleted": self.gc_deleted,
                    "quarantined": len(self.manifest.get("quarantined", [])),
                    "journal": self.journal.stats(),
                    "backend": self.backend.stats(),
                    "maintenance": (self.maintenance.stats()
                                    if self.maintenance is not None
                                    else getattr(self, "_maint_final",
                                                 None))}
