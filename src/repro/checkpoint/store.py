"""Checkpoint chain store: full/diff/batch semantics over any backend.

The store maps the paper's checkpoint chain (full model states,
per-iteration differentials, batched differentials) onto a pluggable
:class:`repro.checkpoint.backends.StorageBackend` and keeps the index in
an append-only :class:`repro.checkpoint.journal.ManifestJournal` —
O(1) journal bytes per write instead of the seed's full
``manifest.json`` rewrite, with periodic compaction.

Keys (backend-independent)::

    full_00000010                # model state M_t
    diff_00000011                # one differential (G̃_t)
    batch_00000012_00000015      # batched differentials

Chain-aware garbage collection (`gc`) deletes full checkpoints and
differential blobs superseded by a newer full, keeping
``retention_fulls`` fulls plus everything needed to replay the latest
chain — Check-N-Run-style quota management for differential chains.
The mark phase (:meth:`gc_plan`) and sweep phase (:meth:`gc_apply`)
are split so the background maintenance service can journal its
progress and sweep in bounded slices; :meth:`gc` composes them for the
synchronous fallback path.

``host_id`` selects the multi-controller journal: each host appends to
its own :class:`~repro.checkpoint.journal.SegmentedManifestJournal`
segment, and every reader reconstructs the same merged manifest.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint.backends import LocalFSBackend, StorageBackend
from repro.checkpoint.journal import (ManifestJournal, MemoryJournal,
                                      SegmentedManifestJournal, _entry_key)


class CheckpointStore:
    def __init__(self, root: Optional[str] = None, *,
                 backend: Optional[StorageBackend] = None,
                 retention_fulls: int = 0, compact_every: int = 256,
                 host_id: Optional[str] = None):
        if backend is None:
            if root is None:
                raise ValueError("CheckpointStore needs a root or a backend")
            backend = LocalFSBackend(root)
        self.backend = backend
        self.root = root if root is not None else backend.persist_root
        self.retention_fulls = retention_fulls
        self._lock = threading.RLock()
        if backend.persist_root is not None:
            if host_id is not None:
                self.journal = SegmentedManifestJournal(
                    backend.persist_root, host=host_id,
                    compact_every=compact_every)
            else:
                self.journal = ManifestJournal(backend.persist_root,
                                               compact_every=compact_every)
        else:
            self.journal = MemoryJournal()
        self.host_id = host_id
        #: attached background MaintenanceService (see
        #: repro.maintenance); None means synchronous fallbacks
        self.maintenance = None
        self.bytes_written = 0
        self.writes = 0
        self.gc_deleted = 0
        self.quarantined = 0
        self._prune_missing()
        self._update_protected()

    # ------------------------------------------------------------------
    @property
    def manifest(self) -> Dict[str, List[dict]]:
        return self.journal.manifest

    def _record(self, kind: str, entry: dict, nbytes: int):
        # tag each entry with the serialization format the backend wrote
        # (frame / npz) — mixed-format chains stay self-describing in
        # the journal even though readers also sniff the magic bytes
        entry.setdefault("format", getattr(self.backend, "fmt", "npz"))
        with self._lock:
            self.journal.append("add", kind, entry=entry)
            self.bytes_written += nbytes
            self.writes += 1

    # ------------------------------------------------------------------
    def save_full(self, step: int, state) -> str:
        key = f"full_{step:08d}"
        # pre-protect: eviction runs inside put(), before the journal
        # records the entry — the incoming blob must already be exempt
        self._update_protected(extra={key})
        n = self.backend.put(key, state)
        self._record("fulls", {"step": step, "key": key,
                               "path": self.backend.url(key), "bytes": n}, n)
        self._update_protected()
        if self.retention_fulls:
            self.request_gc()
        return key

    def save_diff(self, step: int, payload) -> str:
        key = f"diff_{step:08d}"
        self._update_protected(extra={key})
        n = self.backend.put(key, payload)
        self._record("diffs", {"step": step, "key": key,
                               "path": self.backend.url(key), "bytes": n}, n)
        self._update_protected()
        return key

    def save_batch(self, first: int, last: int, payloads: list,
                   mode: str = "concat") -> str:
        """One I/O operation carrying differentials [first..last]."""
        key = f"batch_{first:08d}_{last:08d}"
        self._update_protected(extra={key})
        n = self.backend.put(key, {"mode": mode, "first": first,
                                   "last": last, "payloads": payloads})
        self._record("batches", {"first": first, "last": last, "key": key,
                                 "path": self.backend.url(key),
                                 "bytes": n}, n)
        self._update_protected()
        return key

    # ------------------------------------------------------------------
    def _update_protected(self, extra=()):
        """Tell a capacity-bounded backend tier which blobs form the
        newest full's replay chain (the full itself plus every
        diff/batch after its step): chain-aware eviction must keep
        those resident — they are exactly what recovery reads.
        ``extra`` pre-protects a key whose put is about to run.

        protect() is called while still holding the store lock:
        computing the set and applying it must be atomic, or two
        concurrent writers (the batch consumer and the full-persist
        pool) could apply their sets out of order and un-protect the
        newest chain."""
        keys = set(extra)
        with self._lock:
            fulls = self.manifest["fulls"]
            if not fulls and not keys:
                return
            if fulls:
                newest = max(fulls, key=lambda e: e["step"])
                cutoff = newest["step"]
                keys.add(self._entry_key(newest))
                keys.update(self._entry_key(e)
                            for e in self.manifest["diffs"]
                            if e["step"] > cutoff)
                keys.update(self._entry_key(e)
                            for e in self.manifest["batches"]
                            if e["last"] > cutoff)
            self.backend.protect(keys)

    # ------------------------------------------------------------------
    @staticmethod
    def _entry_key(entry: dict) -> str:
        return _entry_key(entry)

    def _prune_missing(self):
        """Drop manifest entries whose blob never became durable — e.g. a
        crash after the journal append but before an async tier's
        write-back landed. Write-back is FIFO, so the missing blobs are a
        suffix of the write order and pruning restores the seed's
        guarantee: recovery always sees a consistent chain prefix."""
        with self._lock:
            for kind in ("fulls", "diffs", "batches"):
                for e in list(self.manifest[kind]):
                    key = self._entry_key(e)
                    if not self.backend.exists(key):
                        self.journal.append("del", kind, key=key)

    def latest_full(self) -> Optional[dict]:
        with self._lock:
            fulls = sorted(self.manifest["fulls"], key=lambda e: e["step"])
        return fulls[-1] if fulls else None

    def load_full(self, entry: dict):
        return self.backend.get(self._entry_key(entry))

    def diffs_after(self, step: int) -> List[Tuple[int, Any]]:
        """Ordered (step, payload) list of differentials with step > given.

        Each step appears exactly once: a differential present both as a
        standalone ``diff_*`` blob and inside a ``batch_*`` blob (e.g. a
        retried write that landed twice) is returned from the standalone
        blob only — replaying it twice through Adam would advance the
        moment estimates twice and corrupt the recovered state.
        Non-overlapping batches, and batches every step of which is
        already covered, are skipped without touching storage."""
        with self._lock:
            diffs = list(self.manifest["diffs"])
            batches = list(self.manifest["batches"])
        chosen: Dict[int, dict] = {}
        for e in diffs:                 # duplicate steps: latest entry wins
            if e["step"] > step:
                chosen[e["step"]] = e
        out = {s: self.backend.get(self._entry_key(e))
               for s, e in chosen.items()}
        for e in batches:
            if e["last"] <= step:
                continue
            lo = max(step, e["first"] - 1)
            if all(s in out for s in range(lo + 1, e["last"] + 1)):
                continue                # fully covered: skip the fetch
            blob = self.backend.get(self._entry_key(e))
            for i, pay in enumerate(blob["payloads"]):
                s = blob["first"] + i
                if s > step and s not in out:
                    out[s] = pay
        return sorted(out.items())

    # ------------------------------------------------------------------
    # garbage collection: mark (plan) / sweep (apply)
    # ------------------------------------------------------------------
    def gc_plan(self, retention_fulls: Optional[int] = None
                ) -> List[Tuple[str, str]]:
        """Mark phase: compute the ``[(kind, key), ...]`` list of blobs
        superseded by a newer full checkpoint — no I/O, manifest lock
        only. Keeps the newest ``retention_fulls`` fulls and every
        differential/batch that could still be needed to replay a chain
        from the *oldest retained* full (a batch straddling the cutoff
        is kept whole)."""
        keep = (self.retention_fulls if retention_fulls is None
                else retention_fulls)
        if keep < 1:
            return []
        doomed: List[Tuple[str, str]] = []
        with self._lock:
            fulls = sorted(self.manifest["fulls"], key=lambda e: e["step"])
            if len(fulls) <= keep:
                return doomed
            cutoff = fulls[-keep]["step"]
            for e in fulls[:-keep]:
                doomed.append(("fulls", self._entry_key(e)))
            for e in self.manifest["diffs"]:
                if e["step"] <= cutoff:
                    doomed.append(("diffs", self._entry_key(e)))
            for e in self.manifest["batches"]:
                if e["last"] <= cutoff:
                    doomed.append(("batches", self._entry_key(e)))
        return doomed

    def _live_chain_keys(self, keep: int) -> set:
        """Keys the newest ``keep`` retained chains still need — the
        retained fulls plus every diff/batch replayable after the
        oldest retained full."""
        keys = set()
        with self._lock:
            fulls = sorted(self.manifest["fulls"], key=lambda e: e["step"])
            retained = fulls[-max(keep, 1):]
            if not retained:
                return keys
            cutoff = retained[0]["step"]
            keys.update(self._entry_key(e) for e in retained)
            keys.update(self._entry_key(e) for e in self.manifest["diffs"]
                        if e["step"] > cutoff)
            keys.update(self._entry_key(e) for e in self.manifest["batches"]
                        if e["last"] > cutoff)
        return keys

    def gc_apply(self, doomed: List[Tuple[str, str]],
                 retention_fulls: Optional[int] = None,
                 crash_hook=None) -> Dict[str, int]:
        """Sweep phase: journal the deletion, then delete the blob, for
        each marked ``(kind, key)``. Idempotent — re-applying a slice
        after a crash re-journals a no-op del and re-deletes an absent
        blob. A key that re-entered the newest retained chains since the
        plan was computed (a stale plan after a same-step re-put) is
        skipped: the sweep must never delete a live-chain blob.

        Blob I/O runs *outside* the manifest lock so a background sweep
        never stalls the training hot path's journal appends.
        ``crash_hook(point, key)`` is a test seam fired between the
        journal del and the backend delete."""
        keep = (self.retention_fulls if retention_fulls is None
                else retention_fulls)
        live = self._live_chain_keys(keep)
        removed = {"fulls": 0, "diffs": 0, "batches": 0}
        for kind, key in doomed:
            if key in live:
                continue
            with self._lock:
                self.journal.append("del", kind, key=key)
            if crash_hook is not None:
                crash_hook("gc:mid_delete", key)
            self.backend.delete(key)
            removed[kind] = removed.get(kind, 0) + 1
            with self._lock:
                self.gc_deleted += 1
        self._update_protected()
        return removed

    def gc(self, retention_fulls: Optional[int] = None) -> Dict[str, int]:
        """Synchronous mark + sweep (the ``--maintenance off`` path and
        explicit calls). Returns per-kind delete counts."""
        doomed = self.gc_plan(retention_fulls)
        if not doomed:
            keep = (self.retention_fulls if retention_fulls is None
                    else retention_fulls)
            return {} if keep < 1 else {"fulls": 0, "diffs": 0,
                                        "batches": 0}
        return self.gc_apply(doomed, retention_fulls)

    def request_gc(self, retention_fulls: Optional[int] = None):
        """Route GC off the hot path: schedule it on the attached
        maintenance service (non-blocking) or fall back to a
        synchronous sweep when no service is attached."""
        svc = self.maintenance
        if svc is not None and svc.running:
            svc.request_gc(retention_fulls)
            return None
        return self.gc(retention_fulls)

    def scrub_targets(self) -> List[Tuple[str, str]]:
        """Every chain entry the integrity scrubber should walk, as
        ``(kind, key)`` — a point-in-time snapshot under the lock."""
        with self._lock:
            return [(kind, self._entry_key(e))
                    for kind in ("fulls", "diffs", "batches")
                    for e in self.manifest[kind]]

    def merge_journal(self):
        """Fold journal state into its snapshot under the store lock: a
        segmented journal merges every host's segment (the
        multi-controller merge step); a plain journal just compacts."""
        with self._lock:
            self.journal.compact()

    # ------------------------------------------------------------------
    # quarantine (integrity scrubber)
    # ------------------------------------------------------------------
    def quarantine(self, kind: str, key: str, reason: str) -> bool:
        """Move a corrupt blob's manifest entry out of its chain kind
        into the ``quarantined`` list: recovery skips it proactively
        (`load_latest_chain` falls back to an older full / the chain
        cuts at the gap) instead of tripping over the corruption at
        restore time. The blob itself is kept for forensics; GC of
        quarantined entries is explicit (:meth:`drop_quarantined`)."""
        with self._lock:
            entry = next((e for e in self.manifest.get(kind, [])
                          if self._entry_key(e) == key), None)
            if entry is None:
                return False
            self.journal.append("del", kind, key=key)
            q = dict(entry)
            q.update({"key": key, "src_kind": kind, "reason": reason})
            self.journal.append("add", "quarantined", entry=q)
            self.quarantined += 1
        self._update_protected()
        return True

    def drop_quarantined(self) -> int:
        """Delete quarantined blobs and their records. Returns count."""
        with self._lock:
            entries = list(self.manifest.get("quarantined", []))
        n = 0
        for e in entries:
            key = self._entry_key(e)
            with self._lock:
                self.journal.append("del", "quarantined", key=key)
            self.backend.delete(key)
            n += 1
        return n

    # ------------------------------------------------------------------
    def flush(self, timeout: Optional[float] = None):
        """Block until every accepted write is durable at the lowest
        backend tier AND every pending maintenance slice has drained —
        same deadline/error-surfacing contract as the persist queue
        (maintenance task failures re-raise here as
        ``CheckpointingError``)."""
        self.backend.flush()
        if self.maintenance is not None:
            self.maintenance.drain(timeout)

    def attach_maintenance(self, service):
        """Attach (or detach with None) a background MaintenanceService;
        `save_full`'s retention GC and `flush`/`close` route through
        it once attached."""
        self.maintenance = service

    def close(self):
        svc, self.maintenance = self.maintenance, None
        if svc is not None:
            svc.stop()
            # stats() keeps reporting the service's final numbers after
            # close — the launcher prints strategy stats post-close
            self._maint_final = svc.stats()
        self.backend.close()
        self.journal.close()

    def stats(self):
        with self._lock:
            return {"writes": self.writes, "bytes": self.bytes_written,
                    "fulls": len(self.manifest["fulls"]),
                    "diffs": len(self.manifest["diffs"]),
                    "batches": len(self.manifest["batches"]),
                    "gc_deleted": self.gc_deleted,
                    "quarantined": len(self.manifest.get("quarantined", [])),
                    "journal": self.journal.stats(),
                    "backend": self.backend.stats(),
                    "maintenance": (self.maintenance.stats()
                                    if self.maintenance is not None
                                    else getattr(self, "_maint_final",
                                                 None))}
