"""Checkpoint directory layout + manifest for full/differential chains.

Layout::

    <dir>/manifest.json                      # index of everything below
    <dir>/full_00000010.npz                  # model state M_t
    <dir>/diff_00000011.npz                  # one differential (G̃_t)
    <dir>/batch_00000012_00000015.npz        # batched differentials

The manifest is rewritten atomically after each successful write, so
recovery always sees a consistent chain prefix.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint import io as cio


class CheckpointStore:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()
        self.manifest: Dict[str, Any] = {"fulls": [], "diffs": [], "batches": []}
        self._load_manifest()
        self.bytes_written = 0
        self.writes = 0

    # ------------------------------------------------------------------
    def _manifest_path(self):
        return os.path.join(self.root, "manifest.json")

    def _load_manifest(self):
        if os.path.exists(self._manifest_path()):
            with open(self._manifest_path()) as f:
                self.manifest = json.load(f)

    def _write_manifest(self):
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(self.manifest, f)
        os.replace(tmp, self._manifest_path())

    def _record(self, kind: str, entry: dict, nbytes: int):
        with self._lock:
            self.manifest[kind].append(entry)
            self.bytes_written += nbytes
            self.writes += 1
            self._write_manifest()

    # ------------------------------------------------------------------
    def save_full(self, step: int, state) -> str:
        path = os.path.join(self.root, f"full_{step:08d}.npz")
        n = cio.save(path, state)
        self._record("fulls", {"step": step, "path": path, "bytes": n}, n)
        return path

    def save_diff(self, step: int, payload) -> str:
        path = os.path.join(self.root, f"diff_{step:08d}.npz")
        n = cio.save(path, payload)
        self._record("diffs", {"step": step, "path": path, "bytes": n}, n)
        return path

    def save_batch(self, first: int, last: int, payloads: list,
                   mode: str = "concat") -> str:
        """One I/O operation carrying differentials [first..last]."""
        path = os.path.join(self.root, f"batch_{first:08d}_{last:08d}.npz")
        n = cio.save(path, {"mode": mode, "first": first, "last": last,
                            "payloads": payloads})
        self._record("batches", {"first": first, "last": last, "path": path,
                                 "bytes": n}, n)
        return path

    # ------------------------------------------------------------------
    def latest_full(self) -> Optional[dict]:
        fulls = sorted(self.manifest["fulls"], key=lambda e: e["step"])
        return fulls[-1] if fulls else None

    def load_full(self, entry: dict):
        return cio.load(entry["path"])

    def diffs_after(self, step: int) -> List[Tuple[int, Any]]:
        """Ordered (step, payload) list of differentials with step > given."""
        out = []
        for e in self.manifest["diffs"]:
            if e["step"] > step:
                out.append((e["step"], cio.load(e["path"])))
        for e in self.manifest["batches"]:
            blob = None
            if e["last"] > step:
                blob = cio.load(e["path"])
                for i, pay in enumerate(blob["payloads"]):
                    s = blob["first"] + i
                    if s > step:
                        out.append((s, pay))
        out.sort(key=lambda t: t[0])
        return out

    def stats(self):
        return {"writes": self.writes, "bytes": self.bytes_written,
                "fulls": len(self.manifest["fulls"]),
                "diffs": len(self.manifest["diffs"]),
                "batches": len(self.manifest["batches"])}
