"""Checkpoint chain store: full/diff/batch semantics over any backend.

The store maps the paper's checkpoint chain (full model states,
per-iteration differentials, batched differentials) onto a pluggable
:class:`repro.checkpoint.backends.StorageBackend` and keeps the index in
an append-only :class:`repro.checkpoint.journal.ManifestJournal` —
O(1) journal bytes per write instead of the seed's full
``manifest.json`` rewrite, with periodic compaction.

Keys (backend-independent)::

    full_00000010                # model state M_t
    diff_00000011                # one differential (G̃_t)
    batch_00000012_00000015      # batched differentials

Chain-aware garbage collection (`gc`) deletes full checkpoints and
differential blobs superseded by a newer full, keeping
``retention_fulls`` fulls plus everything needed to replay the latest
chain — Check-N-Run-style quota management for differential chains.
"""
from __future__ import annotations

import os
import threading
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint.backends import LocalFSBackend, StorageBackend
from repro.checkpoint.journal import (ManifestJournal, MemoryJournal,
                                      _entry_key)


class CheckpointStore:
    def __init__(self, root: Optional[str] = None, *,
                 backend: Optional[StorageBackend] = None,
                 retention_fulls: int = 0, compact_every: int = 256):
        if backend is None:
            if root is None:
                raise ValueError("CheckpointStore needs a root or a backend")
            backend = LocalFSBackend(root)
        self.backend = backend
        self.root = root if root is not None else backend.persist_root
        self.retention_fulls = retention_fulls
        self._lock = threading.RLock()
        if backend.persist_root is not None:
            self.journal = ManifestJournal(backend.persist_root,
                                           compact_every=compact_every)
        else:
            self.journal = MemoryJournal()
        self.bytes_written = 0
        self.writes = 0
        self.gc_deleted = 0
        self._prune_missing()
        self._update_protected()

    # ------------------------------------------------------------------
    @property
    def manifest(self) -> Dict[str, List[dict]]:
        return self.journal.manifest

    def _record(self, kind: str, entry: dict, nbytes: int):
        # tag each entry with the serialization format the backend wrote
        # (frame / npz) — mixed-format chains stay self-describing in
        # the journal even though readers also sniff the magic bytes
        entry.setdefault("format", getattr(self.backend, "fmt", "npz"))
        with self._lock:
            self.journal.append("add", kind, entry=entry)
            self.bytes_written += nbytes
            self.writes += 1

    # ------------------------------------------------------------------
    def save_full(self, step: int, state) -> str:
        key = f"full_{step:08d}"
        # pre-protect: eviction runs inside put(), before the journal
        # records the entry — the incoming blob must already be exempt
        self._update_protected(extra={key})
        n = self.backend.put(key, state)
        self._record("fulls", {"step": step, "key": key,
                               "path": self.backend.url(key), "bytes": n}, n)
        self._update_protected()
        if self.retention_fulls:
            self.gc()
        return key

    def save_diff(self, step: int, payload) -> str:
        key = f"diff_{step:08d}"
        self._update_protected(extra={key})
        n = self.backend.put(key, payload)
        self._record("diffs", {"step": step, "key": key,
                               "path": self.backend.url(key), "bytes": n}, n)
        self._update_protected()
        return key

    def save_batch(self, first: int, last: int, payloads: list,
                   mode: str = "concat") -> str:
        """One I/O operation carrying differentials [first..last]."""
        key = f"batch_{first:08d}_{last:08d}"
        self._update_protected(extra={key})
        n = self.backend.put(key, {"mode": mode, "first": first,
                                   "last": last, "payloads": payloads})
        self._record("batches", {"first": first, "last": last, "key": key,
                                 "path": self.backend.url(key),
                                 "bytes": n}, n)
        self._update_protected()
        return key

    # ------------------------------------------------------------------
    def _update_protected(self, extra=()):
        """Tell a capacity-bounded backend tier which blobs form the
        newest full's replay chain (the full itself plus every
        diff/batch after its step): chain-aware eviction must keep
        those resident — they are exactly what recovery reads.
        ``extra`` pre-protects a key whose put is about to run.

        protect() is called while still holding the store lock:
        computing the set and applying it must be atomic, or two
        concurrent writers (the batch consumer and the full-persist
        pool) could apply their sets out of order and un-protect the
        newest chain."""
        keys = set(extra)
        with self._lock:
            fulls = self.manifest["fulls"]
            if not fulls and not keys:
                return
            if fulls:
                newest = max(fulls, key=lambda e: e["step"])
                cutoff = newest["step"]
                keys.add(self._entry_key(newest))
                keys.update(self._entry_key(e)
                            for e in self.manifest["diffs"]
                            if e["step"] > cutoff)
                keys.update(self._entry_key(e)
                            for e in self.manifest["batches"]
                            if e["last"] > cutoff)
            self.backend.protect(keys)

    # ------------------------------------------------------------------
    @staticmethod
    def _entry_key(entry: dict) -> str:
        return _entry_key(entry)

    def _prune_missing(self):
        """Drop manifest entries whose blob never became durable — e.g. a
        crash after the journal append but before an async tier's
        write-back landed. Write-back is FIFO, so the missing blobs are a
        suffix of the write order and pruning restores the seed's
        guarantee: recovery always sees a consistent chain prefix."""
        with self._lock:
            for kind in ("fulls", "diffs", "batches"):
                for e in list(self.manifest[kind]):
                    key = self._entry_key(e)
                    if not self.backend.exists(key):
                        self.journal.append("del", kind, key=key)

    def latest_full(self) -> Optional[dict]:
        with self._lock:
            fulls = sorted(self.manifest["fulls"], key=lambda e: e["step"])
        return fulls[-1] if fulls else None

    def load_full(self, entry: dict):
        return self.backend.get(self._entry_key(entry))

    def diffs_after(self, step: int) -> List[Tuple[int, Any]]:
        """Ordered (step, payload) list of differentials with step > given.

        Each step appears exactly once: a differential present both as a
        standalone ``diff_*`` blob and inside a ``batch_*`` blob (e.g. a
        retried write that landed twice) is returned from the standalone
        blob only — replaying it twice through Adam would advance the
        moment estimates twice and corrupt the recovered state.
        Non-overlapping batches, and batches every step of which is
        already covered, are skipped without touching storage."""
        with self._lock:
            diffs = list(self.manifest["diffs"])
            batches = list(self.manifest["batches"])
        chosen: Dict[int, dict] = {}
        for e in diffs:                 # duplicate steps: latest entry wins
            if e["step"] > step:
                chosen[e["step"]] = e
        out = {s: self.backend.get(self._entry_key(e))
               for s, e in chosen.items()}
        for e in batches:
            if e["last"] <= step:
                continue
            lo = max(step, e["first"] - 1)
            if all(s in out for s in range(lo + 1, e["last"] + 1)):
                continue                # fully covered: skip the fetch
            blob = self.backend.get(self._entry_key(e))
            for i, pay in enumerate(blob["payloads"]):
                s = blob["first"] + i
                if s > step and s not in out:
                    out[s] = pay
        return sorted(out.items())

    # ------------------------------------------------------------------
    def gc(self, retention_fulls: Optional[int] = None) -> Dict[str, int]:
        """Delete blobs superseded by a newer full checkpoint.

        Keeps the newest ``retention_fulls`` fulls and every
        differential/batch that could still be needed to replay a chain
        from the *oldest retained* full (a batch straddling the cutoff
        is kept whole). Returns per-kind delete counts.
        """
        keep = (self.retention_fulls if retention_fulls is None
                else retention_fulls)
        if keep < 1:
            return {}
        removed = {"fulls": 0, "diffs": 0, "batches": 0}
        with self._lock:
            fulls = sorted(self.manifest["fulls"], key=lambda e: e["step"])
            if len(fulls) <= keep:
                return removed
            cutoff = fulls[-keep]["step"]
            doomed: List[Tuple[str, dict]] = []
            for e in fulls[:-keep]:
                doomed.append(("fulls", e))
            for e in self.manifest["diffs"]:
                if e["step"] <= cutoff:
                    doomed.append(("diffs", e))
            for e in self.manifest["batches"]:
                if e["last"] <= cutoff:
                    doomed.append(("batches", e))
            for kind, e in doomed:
                key = self._entry_key(e)
                self.journal.append("del", kind, key=key)
                self.backend.delete(key)
                removed[kind] += 1
                self.gc_deleted += 1
        self._update_protected()
        return removed

    # ------------------------------------------------------------------
    def flush(self):
        """Block until every accepted write is durable at the lowest
        backend tier."""
        self.backend.flush()

    def close(self):
        self.backend.close()
        self.journal.close()

    def stats(self):
        with self._lock:
            return {"writes": self.writes, "bytes": self.bytes_written,
                    "fulls": len(self.manifest["fulls"]),
                    "diffs": len(self.manifest["diffs"]),
                    "batches": len(self.manifest["batches"]),
                    "gc_deleted": self.gc_deleted,
                    "journal": self.journal.stats(),
                    "backend": self.backend.stats()}
