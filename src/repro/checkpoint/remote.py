"""Remote object-store checkpoint tier (S3/GCS-style).

Production checkpointing ultimately lands on remote object storage
(Check-N-Run, TierCheck): high-latency, quota-bounded, and failure-prone
enough that every transfer needs integrity checks and retries. This
module provides

* :class:`ObjectStore` — the minimal byte-level client abstraction
  (put/get/delete/list). Two hermetic implementations ship with it:
  :class:`FakeObjectStore` (in-process dict, optional fault injection
  and simulated latency — tests and benchmarks) and
  :class:`FilesystemObjectStore` (a directory standing in for a mounted
  bucket — crash/recovery tests). A real S3/GCS client only has to
  implement the four byte-level methods; no SDK is baked into the image.
* :class:`RemoteObjectBackend` — a :class:`~repro.checkpoint.backends.
  StorageBackend` over any ObjectStore: blobs are content-chunked
  (``chunk_bytes``), every chunk carries a sha256 checksum, and an index
  object written *last* is the commit point (a crash mid-upload leaves
  no index, so ``exists`` is false and the store's ``_prune_missing``
  drops the manifest entry). Reads verify each chunk's checksum and
  re-fetch corrupted chunks; every transfer is wrapped in bounded
  retries with exponential backoff.

The backend is intended to sit as the *lowest* tier under
:class:`~repro.checkpoint.backends.MemoryTierBackend`: the RAM tier's
asynchronous write-back absorbs remote put latency, so per-iteration
differential checkpointing never stalls the training loop on the
object store.
"""
from __future__ import annotations

import abc
import hashlib
import json
import os
import random
import struct as _struct
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import io as cio
from repro.checkpoint.backends import StorageBackend
from repro.checkpoint.patchset import PatchSet
from repro.obs.trace import trace_span


class TransientStoreError(Exception):
    """A retryable object-store failure (timeout, dropped connection,
    throttling). :class:`RemoteObjectBackend` retries these."""


class ChecksumError(TransientStoreError):
    """A fetched chunk failed checksum verification; retryable — the
    next fetch may return clean bytes."""


class RetryExhaustedError(RuntimeError):
    """Bounded retries were exhausted without a successful transfer."""


# ----------------------------------------------------------------------
# fault injection
# ----------------------------------------------------------------------

class FaultInjector:
    """Configurable transient-fault schedule for hermetic stores.

    Deterministic counts are consumed first, in call order:

    * ``drop_puts`` — first N ``put_object`` calls raise
      :class:`TransientStoreError` (the chunk never lands).
    * ``drop_gets`` — first N ``get_object`` calls raise
      :class:`TransientStoreError`.
    * ``flip_gets`` — first N ``get_object`` calls return the stored
      bytes with one byte corrupted (a checksum flip in flight).

    After the counts are spent, ``rate`` injects random transient drops
    on both puts and gets with a seeded RNG — statistical soak mode for
    benchmarks. Thread-safe (the write-back thread and the reader race).
    """

    def __init__(self, *, drop_puts: int = 0, drop_gets: int = 0,
                 flip_gets: int = 0, rate: float = 0.0, seed: int = 0):
        self.drop_puts = drop_puts
        self.drop_gets = drop_gets
        self.flip_gets = flip_gets
        self.rate = rate
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self.injected = 0

    def _roll(self) -> bool:
        return self.rate > 0.0 and self._rng.random() < self.rate

    def on_put(self, name: str) -> None:
        with self._lock:
            if self.drop_puts > 0:
                self.drop_puts -= 1
                self.injected += 1
                raise TransientStoreError(f"injected put drop: {name}")
            if self._roll():
                self.injected += 1
                raise TransientStoreError(f"injected put drop: {name}")

    def on_get(self, name: str, data: bytes) -> bytes:
        with self._lock:
            if self.drop_gets > 0:
                self.drop_gets -= 1
                self.injected += 1
                raise TransientStoreError(f"injected get drop: {name}")
            if self.flip_gets > 0 and data:
                self.flip_gets -= 1
                self.injected += 1
                return bytes([data[0] ^ 0xFF]) + data[1:]
            if self._roll():
                self.injected += 1
                raise TransientStoreError(f"injected get drop: {name}")
        return data


# ----------------------------------------------------------------------
# object-store clients
# ----------------------------------------------------------------------

class ObjectStore(abc.ABC):
    """Minimal byte-level object-store client. Names are '/'-separated
    path-safe strings; values are opaque byte blobs."""

    scheme = "abstract"

    @abc.abstractmethod
    def put_object(self, name: str, data: bytes) -> None: ...

    @abc.abstractmethod
    def get_object(self, name: str) -> bytes:
        """Raises FileNotFoundError when the object is absent."""

    @abc.abstractmethod
    def delete_object(self, name: str) -> None:
        """Idempotent."""

    @abc.abstractmethod
    def list_objects(self, prefix: str = "") -> List[str]: ...

    def has_object(self, name: str) -> bool:
        """Metadata-only presence check (HEAD-style). The default
        downloads the body; real clients should override."""
        try:
            self.get_object(name)
            return True
        except FileNotFoundError:
            return False


class FakeObjectStore(ObjectStore):
    """In-process object store: a dict behind a lock, with optional
    fault injection and simulated per-byte latency. Hermetic stand-in
    for S3/GCS in tests and benchmarks."""

    scheme = "fake"

    def __init__(self, faults: Optional[FaultInjector] = None, *,
                 latency_s_per_mb: float = 0.0):
        self._objects: Dict[str, bytes] = {}
        self._lock = threading.Lock()
        self.faults = faults
        self.latency_s_per_mb = latency_s_per_mb
        self.put_calls = 0
        self.get_calls = 0

    def _simulate_latency(self, nbytes: int):
        if self.latency_s_per_mb > 0.0:
            time.sleep(self.latency_s_per_mb * nbytes / 2**20)

    def put_object(self, name: str, data: bytes) -> None:
        with self._lock:
            self.put_calls += 1
        if self.faults is not None:
            self.faults.on_put(name)
        self._simulate_latency(len(data))
        with self._lock:
            self._objects[name] = bytes(data)

    def get_object(self, name: str) -> bytes:
        with self._lock:
            self.get_calls += 1
            data = self._objects.get(name)
        if data is None:
            raise FileNotFoundError(f"fake://{name}")
        if self.faults is not None:
            data = self.faults.on_get(name, data)
        self._simulate_latency(len(data))
        return data

    def delete_object(self, name: str) -> None:
        with self._lock:
            self._objects.pop(name, None)

    def list_objects(self, prefix: str = "") -> List[str]:
        with self._lock:
            return sorted(n for n in self._objects if n.startswith(prefix))

    def has_object(self, name: str) -> bool:
        with self._lock:
            return name in self._objects


class FilesystemObjectStore(ObjectStore):
    """A local directory standing in for a mounted bucket. Objects are
    files under ``root`` (atomic tmp+rename writes); '/' in names maps
    to subdirectories. Survives process restarts, so crash/recovery
    tests can model 'the bucket outlives the trainer'."""

    scheme = "file"

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, name: str) -> str:
        return os.path.join(self.root, name)

    def put_object(self, name: str, data: bytes) -> None:
        cio.atomic_write(self._path(name), lambda f: f.write(data))

    def get_object(self, name: str) -> bytes:
        try:
            with open(self._path(name), "rb") as f:
                return f.read()
        except FileNotFoundError:
            raise FileNotFoundError(f"file://{self._path(name)}")

    def delete_object(self, name: str) -> None:
        try:
            os.unlink(self._path(name))
        except FileNotFoundError:
            pass

    def has_object(self, name: str) -> bool:
        return os.path.exists(self._path(name))

    def list_objects(self, prefix: str = "") -> List[str]:
        # a '/'-terminated directory component in the prefix scopes the
        # walk to that subtree — a per-key listing must not pay a
        # full-bucket scan
        base = self.root
        head = prefix.rsplit("/", 1)[0] if "/" in prefix else ""
        if head:
            base = os.path.join(self.root, *head.split("/"))
            if not os.path.isdir(base):
                return []
        out = []
        for dirpath, _, files in os.walk(base):
            rel = os.path.relpath(dirpath, self.root)
            for f in files:
                if f.endswith(".tmp"):
                    continue
                name = f if rel == "." else f"{rel}/{f}".replace(os.sep, "/")
                if name.startswith(prefix):
                    out.append(name)
        return sorted(out)


# ----------------------------------------------------------------------
# the storage backend
# ----------------------------------------------------------------------

def _sha256(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


class RemoteObjectBackend(StorageBackend):
    """StorageBackend over an :class:`ObjectStore` with chunking,
    per-chunk sha256 checksums, and bounded-retry transfers.

    Object layout per key::

        <key>/000000.chunk ... <key>/NNNNNN.chunk
        <key>/index.json      # chunk list + checksums (commit point)

    ``put`` serializes the pytree (same npz encoding as the local
    backend), splits the bytes into ``chunk_bytes`` pieces, uploads each
    with retries, then uploads the index — the commit point. ``get``
    fetches the index, then each chunk with checksum verification;
    a corrupted chunk is re-fetched (checksum mismatch is treated as a
    transient fault). Exhausted retries raise
    :class:`RetryExhaustedError`.

    ``journal_root`` is where the chain store's manifest journal lives
    (a *local* directory — the journal needs appendable files, which an
    object store does not give you). None means the manifest is held in
    memory only, which is fine for a FakeObjectStore whose contents die
    with the process anyway.
    """

    name = "remote"
    INDEX = "index.json"

    def __init__(self, store: ObjectStore, *, chunk_bytes: int = 4 << 20,
                 max_retries: int = 4, backoff_s: float = 0.01,
                 backoff_max_s: float = 2.0,
                 journal_root: Optional[str] = None, fmt: str = "frame"):
        if chunk_bytes < 1:
            raise ValueError("chunk_bytes must be >= 1")
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if fmt not in cio.FORMATS:
            raise ValueError(f"fmt must be one of {cio.FORMATS}")
        self.fmt = fmt
        self.store = store
        self.chunk_bytes = chunk_bytes
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.backoff_max_s = backoff_max_s
        self.persist_root = journal_root
        if journal_root is not None:
            os.makedirs(journal_root, exist_ok=True)
        self._lock = threading.Lock()
        #: key -> generation of the last index this backend committed;
        #: lets put() skip the stale-chunk sweep on first writes (the
        #: overwhelmingly common case under step-named keys)
        self._live_gens: Dict[str, str] = {}
        #: keys with an upload in flight (chunks landing, index not yet
        #: committed): the maintenance orphan sweep must not reap them
        self._active_puts: set = set()
        from repro.obs.metrics import InstrumentSet
        self._inst = InstrumentSet("remote")
        #: stats() counter keys, synced by tests/test_observability.py
        self.KEYS = ("puts", "gets", "patches", "retries",
                     "checksum_failures", "bytes_up", "bytes_down")
        for k in self.KEYS:
            self._inst.counter(k)

    def __getattr__(self, name):
        # legacy attribute surface: self.puts etc. read the counters
        if name != "KEYS" and name in getattr(self, "KEYS", ()):
            return int(self._inst.get(name).value)
        raise AttributeError(name)

    def instruments(self):
        """The backing :class:`~repro.obs.metrics.InstrumentSet`."""
        return self._inst

    # ------------------------------------------------------------------
    def _count(self, attr: str, n: int = 1):
        self._inst.counter(attr).add(n)

    def _with_retries(self, fn, desc: str):
        delay = self.backoff_s
        last: Optional[BaseException] = None
        for attempt in range(self.max_retries + 1):
            try:
                return fn()
            except TransientStoreError as e:
                last = e
                if attempt == self.max_retries:
                    break              # budget spent: no sleep, no retry
                self._count("retries")
                time.sleep(min(delay, self.backoff_max_s))
                delay *= 2.0
        raise RetryExhaustedError(
            f"{desc}: no success in {self.max_retries + 1} attempts "
            f"(last: {last})") from last

    def _chunk_name(self, key: str, gen: str, i: int) -> str:
        return f"{key}/{gen}.{i:06d}.chunk"

    def _index_name(self, key: str) -> str:
        return f"{key}/{self.INDEX}"

    # ------------------------------------------------------------------
    def _chunk_iter(self, obj: Any):
        """Iterator of wire chunks (each <= chunk_bytes). The frame path
        streams zero-copy views straight out of the snapshot buffers;
        the npz path materializes the blob and re-slices it (two full
        host copies, metered)."""
        if self.fmt == "frame":
            payload, extra = cio.frame_payload(obj)
            return cio.frame_chunks(payload, self.chunk_bytes, extra)
        blob = cio.dumps(obj)          # copy 1 (metered inside dumps)
        cio.COPY_METER.add(len(blob))  # copy 2: the chunk re-slice below
        return (blob[o:o + self.chunk_bytes]
                for o in range(0, len(blob), self.chunk_bytes))

    def put(self, key: str, obj: Any) -> int:
        with self._lock:
            self._active_puts.add(key)
        try:
            with trace_span("backend.put", "backend", tier=self.name,
                            key=key):
                return self._put(key, obj)
        finally:
            with self._lock:
                self._active_puts.discard(key)

    def _put(self, key: str, obj: Any) -> int:
        # chunks carry a per-put generation prefix so a re-put never
        # overwrites the chunks the live index points at: until the new
        # index commits, the old version stays fully readable
        gen = os.urandom(4).hex()
        index = {"gen": gen, "format": self.fmt, "chunks": []}
        nbytes = 0
        for i, chunk in enumerate(self._chunk_iter(obj)):
            name = self._chunk_name(key, gen, i)
            self._with_retries(
                lambda n=name, c=chunk: self.store.put_object(n, c),
                f"put {name}")
            index["chunks"].append({"name": name, "sha256": _sha256(chunk),
                                    "size": len(chunk)})
            nbytes += len(chunk)
        index["nbytes"] = nbytes
        # the index is the commit point: a crash before this line leaves
        # no index (or the previous one), exists()/get() keep answering
        # for the last committed version, and the chain store's
        # _prune_missing drops a never-committed manifest entry on reopen
        index_bytes = json.dumps(index).encode()
        self._with_retries(
            lambda: self.store.put_object(self._index_name(key), index_bytes),
            f"put {self._index_name(key)}")
        self._count("puts")
        self._count("bytes_up", nbytes + len(index_bytes))
        with self._lock:
            prev = self._live_gens.get(key)
            self._live_gens[key] = gen
        if prev is not None and prev != gen:
            # only a re-put leaves a superseded generation; first writes
            # (every step-named key, i.e. nearly all of them) skip the
            # listing entirely
            self._sweep_stale(key, {c["name"] for c in index["chunks"]})
        return nbytes

    def _sweep_stale(self, key: str, live_names: set) -> None:
        """Best-effort GC of chunk objects the live index no longer
        references: superseded generations and crashed uploads. Liveness
        is the index's chunk *name list*, not a generation prefix — a
        patched index legitimately mixes generations, reusing unchanged
        chunks from older ones. Failures are harmless: orphans cost
        bucket bytes, never correctness."""
        for name in self.store.list_objects(f"{key}/"):
            if name == self._index_name(key) or name in live_names:
                continue
            try:
                self.store.delete_object(name)
            except TransientStoreError:
                pass

    # ------------------------------------------------------------------
    # in-place patching (incremental-merging persistence)
    # ------------------------------------------------------------------
    def _read_frame_header(self, chunks: List[dict]
                           ) -> Tuple[dict, int, int, Dict[int, bytes]]:
        """Fetch just enough leading chunks to parse the frame header.
        Returns (header dict, header json byte length, data_start,
        fetched chunk bytes by index — the caller splices into these
        same chunks, so re-downloading them would double the traffic)."""
        magic_len = len(cio.FRAME_MAGIC)
        head = bytearray()
        fetched: Dict[int, bytes] = {}
        ci = 0
        need = magic_len + 8
        hlen = 0
        while True:
            if len(head) >= magic_len + 8:
                (hlen,) = _struct.unpack(
                    "<Q", bytes(head[magic_len:magic_len + 8]))
                need = magic_len + 8 + hlen
                if len(head) >= need:
                    break
            if ci >= len(chunks):
                raise cio.FrameCorruptionError(
                    "remote frame shorter than its header")
            fetched[ci] = self._fetch_chunk(chunks[ci])
            head += fetched[ci]
            ci += 1
        if bytes(head[:magic_len]) != cio.FRAME_MAGIC:
            raise cio.FrameCorruptionError(
                "remote blob is not a frame (bad magic)")
        header = json.loads(bytes(head[magic_len + 8:need]).decode("utf-8"))
        return header, hlen, need + (-need) % cio.FRAME_ALIGN, fetched

    def patch(self, key: str, patch: PatchSet) -> int:
        with self._lock:
            self._active_puts.add(key)
        try:
            return self._patch(key, patch)
        finally:
            with self._lock:
                self._active_puts.discard(key)

    def _patch(self, key: str, patch: PatchSet) -> int:
        """Re-put only the chunk objects a dirty row range's bytes (or
        the rewritten header) intersect, under a fresh generation; the
        new index references the new chunks *and* every untouched chunk
        of the previous generation by name — unchanged bytes are never
        re-uploaded. A partially-patched leaf's sha256 must cover its
        retained rows too, so those (and only those) chunks are
        downloaded once and spliced. The index write is the commit
        point, exactly as in ``put``: a crash mid-patch leaves the old
        index live and only orphan chunks behind."""
        ps = PatchSet.coerce(patch)
        index = self._load_index(key)
        if index.get("format", "npz") != "frame":
            raise ValueError(
                f"cannot patch npz remote blob {key!r} in place; "
                f"incremental persistence requires the frame format")
        chunks = list(index["chunks"])
        header, hlen, data_start, fetched = self._read_frame_header(chunks)
        down = [sum(len(b) for b in fetched.values())]
        offs = [0]
        for c in chunks:
            offs.append(offs[-1] + int(c["size"]))

        def read_range(lo: int, hi: int) -> bytes:
            """Committed frame bytes [lo, hi), fetching (and caching)
            only the chunks the range touches."""
            out = bytearray(hi - lo)
            for i, c in enumerate(chunks):
                clo, chi = offs[i], offs[i + 1]
                if chi <= lo or clo >= hi:
                    continue
                b = fetched.get(i)
                if b is None:
                    b = self._fetch_chunk(c)
                    fetched[i] = b
                    down[0] += len(b)
                s, e = max(lo, clo), min(hi, chi)
                out[s - lo:e - lo] = b[s - clo:e - clo]
            return bytes(out)

        by_name = {leaf["name"]: leaf for leaf in header["leaves"]}
        magic_len = len(cio.FRAME_MAGIC)
        # dirty byte ranges: each patched span, plus the header rewrite
        ranges: List[Tuple[int, bytes]] = []
        for name in ps:
            rec = by_name.get(name)
            if rec is None:
                raise ValueError(f"remote frame {key!r} has no leaf {name!r}")
            rshape = tuple(rec["shape"])
            rows = rshape[0] if rshape else 1
            stride = int(rec["nbytes"]) // rows if rows else 0
            leaf_lo = data_start + rec["offset"]
            span_raws: List[Tuple[int, bytes]] = []
            for sp in ps[name]:
                a = np.asarray(sp.data)
                span_rows = int(a.shape[0]) if a.ndim else 1
                if a.dtype.str != rec["dtype"] or (
                        (sp.start != 0 or list(a.shape) != rec["shape"])
                        and (not rshape or a.ndim == 0
                             or a.shape[1:] != rshape[1:]
                             or sp.start + span_rows > rows)):
                    raise ValueError(
                        f"leaf {name!r} layout mismatch on {key!r}: rows "
                        f"[{sp.start}, {sp.start + span_rows}) of "
                        f"{a.dtype.str}{a.shape} != {rec['dtype']}{rshape}")
                raw = np.ascontiguousarray(a).tobytes()
                ranges.append((leaf_lo + sp.start * stride, raw))
                span_raws.append((sp.start * stride, raw))
            if ps.is_whole(name):
                rec["sha256"] = _sha256(span_raws[0][1])
            else:
                # digest spans committed-retained + patched bytes
                buf = bytearray(read_range(leaf_lo,
                                           leaf_lo + int(rec["nbytes"])))
                for off, raw in span_raws:
                    buf[off:off + len(raw)] = raw
                rec["sha256"] = _sha256(bytes(buf))
        hjson = json.dumps(header).encode("utf-8")
        if len(hjson) != hlen:
            raise ValueError(f"patched header for {key!r} length diverged "
                             f"({len(hjson)} != {hlen})")
        ranges.append((magic_len + 8, hjson))
        gen = os.urandom(4).hex()
        new_chunks: List[dict] = []
        nbytes_up = 0
        lo = 0
        for i, c in enumerate(chunks):
            hi = lo + int(c["size"])
            touching = [(o, b) for o, b in ranges
                        if o < hi and o + len(b) > lo]
            if not touching:
                new_chunks.append(c)          # reuse by name: not re-put
            else:
                old = fetched.get(i)
                if old is None:
                    old = self._fetch_chunk(c)
                    down[0] += len(old)
                data = bytearray(old)
                for o, b in touching:
                    s, e = max(lo, o), min(hi, o + len(b))
                    data[s - lo:e - lo] = b[s - o:e - o]
                blob = bytes(data)
                name = self._chunk_name(key, gen, i)
                self._with_retries(
                    lambda n=name, d=blob: self.store.put_object(n, d),
                    f"put {name}")
                new_chunks.append({"name": name, "sha256": _sha256(blob),
                                   "size": len(blob)})
                nbytes_up += len(blob)
            lo = hi
        new_index = {"gen": gen, "format": "frame", "chunks": new_chunks,
                     "nbytes": index["nbytes"]}
        index_bytes = json.dumps(new_index).encode()
        self._with_retries(
            lambda: self.store.put_object(self._index_name(key), index_bytes),
            f"put {self._index_name(key)}")
        self._count("patches")
        self._count("bytes_up", nbytes_up + len(index_bytes))
        self._count("bytes_down", down[0])
        with self._lock:
            self._live_gens[key] = gen
        self._sweep_stale(key, {c["name"] for c in new_chunks})
        return nbytes_up + len(index_bytes)

    def _load_index(self, key: str) -> dict:
        def fetch():
            data = self.store.get_object(self._index_name(key))
            try:
                return json.loads(data.decode("utf-8"))
            except (json.JSONDecodeError, UnicodeDecodeError) as e:
                # a corrupted index is as retryable as a corrupted chunk
                self._count("checksum_failures")
                raise ChecksumError(
                    f"index for {key!r} failed to parse") from e
        return self._with_retries(fetch, f"get {self._index_name(key)}")

    def _fetch_chunk(self, entry: dict) -> bytes:
        def fetch():
            data = self.store.get_object(entry["name"])
            if _sha256(data) != entry["sha256"]:
                self._count("checksum_failures")
                raise ChecksumError(
                    f"chunk {entry['name']} checksum mismatch")
            return data
        return self._with_retries(fetch, f"get {entry['name']}")

    def get(self, key: str) -> Any:
        index = self._load_index(key)
        blob = b"".join(self._fetch_chunk(e) for e in index["chunks"])
        self._count("gets")
        self._count("bytes_down", len(blob))
        # magic-sniffed: old npz uploads and new frame uploads both load
        # (chunk sha256s already verified each piece in _fetch_chunk)
        return cio.loads_any(blob)

    def delete(self, key: str) -> None:
        # index first: a crash mid-delete leaves orphan chunks (harmless,
        # swept by the next delete) rather than an index pointing at
        # missing chunks
        self.store.delete_object(self._index_name(key))
        for name in self.store.list_objects(f"{key}/"):
            self.store.delete_object(name)
        with self._lock:
            self._live_gens.pop(key, None)

    def verify(self, key: str) -> Optional[str]:
        """Scrub hook: re-fetch the index and every chunk, checking each
        sha256, without deserializing the pytree. A checksum that stays
        wrong through the bounded retries is *corruption* (returned as a
        reason, the caller quarantines); transient-exhaustion on clean
        infrastructure errors propagates — a flaky wire must not
        quarantine an intact blob."""
        try:
            index = self._load_index(key)
        except FileNotFoundError:
            raise
        except RetryExhaustedError as e:
            if isinstance(e.__cause__, ChecksumError):
                return f"index for {key!r} unparseable"
            raise
        for entry in index["chunks"]:
            try:
                self._fetch_chunk(entry)
            except FileNotFoundError:
                return f"chunk {entry['name']} missing under live index"
            except RetryExhaustedError as e:
                if isinstance(e.__cause__, ChecksumError):
                    return f"chunk {entry['name']} sha256 mismatch"
                raise
        return None

    def sweep_orphans(self, min_age_s: float = 60.0) -> int:
        """Reap chunk objects no committed index references: superseded
        generations a crashed re-put never swept, and uploads that died
        before their commit point. Keys with a put in flight are
        skipped (this backend is the single writer for its key space).
        Object stores expose no reliable mtime here, so ``min_age_s``
        is advisory only. Failures are harmless — orphans cost bucket
        bytes, never correctness."""
        with self._lock:
            active = set(self._active_puts)
        by_key: Dict[str, List[str]] = {}
        for name in self.store.list_objects():
            if "/" not in name:
                continue
            key, _, leaf = name.rpartition("/")
            if leaf == self.INDEX:
                continue
            by_key.setdefault(key, []).append(name)
        removed = 0
        for key, names in by_key.items():
            if key in active:
                continue
            try:
                # liveness = the names the index references (a patched
                # index mixes generations), not a generation prefix
                live = {c["name"]
                        for c in self._load_index(key)["chunks"]}
            except FileNotFoundError:
                live = None              # no commit point: all orphans
            except (RetryExhaustedError, TransientStoreError):
                continue                 # unreadable index: leave alone
            for name in names:
                if live is not None and name in live:
                    continue
                try:
                    self.store.delete_object(name)
                    removed += 1
                except TransientStoreError:
                    pass
        return removed

    def exists(self, key: str) -> bool:
        # metadata-only, but still fault-prone on a real wire: retry
        # transients rather than mis-reporting a reachable blob as
        # missing (which would make _prune_missing drop live chain
        # entries on reopen)
        return self._with_retries(
            lambda: self.store.has_object(self._index_name(key)),
            f"head {self._index_name(key)}")

    def keys(self) -> List[str]:
        suffix = f"/{self.INDEX}"
        return sorted(n[:-len(suffix)] for n in self.store.list_objects()
                      if n.endswith(suffix))

    def url(self, key: str) -> str:
        return f"{self.store.scheme}://{key}"

    def flush(self) -> None:
        """Puts are synchronous at this tier; nothing buffered."""

    def close(self) -> None:
        self.flush()

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {"backend": self.name, "scheme": self.store.scheme,
                    "chunk_bytes": self.chunk_bytes,
                    "puts": self.puts, "gets": self.gets,
                    "patches": self.patches, "retries": self.retries,
                    "checksum_failures": self.checksum_failures,
                    "bytes_up": self.bytes_up,
                    "bytes_down": self.bytes_down}


# ----------------------------------------------------------------------
# URL factory
# ----------------------------------------------------------------------

#: shared fake buckets: two make_remote_backend("fake://name") calls in
#: one process see the same objects, so in-process recovery works.
_FAKE_BUCKETS: Dict[str, FakeObjectStore] = {}
_FAKE_LOCK = threading.Lock()


def make_remote_backend(url: str, *, chunk_bytes: int = 4 << 20,
                        max_retries: int = 4,
                        journal_root: Optional[str] = None,
                        fault_rate: float = 0.0,
                        seed: int = 0,
                        fmt: str = "frame") -> RemoteObjectBackend:
    """Build a RemoteObjectBackend from a URL.

    * ``fake://<bucket>`` — in-process store, shared per bucket name
      within the process. The fault configuration is applied on every
      call (last caller wins): ``fault_rate`` > 0 attaches a fresh
      statistical injector, 0 detaches any previous one — a cached
      bucket never silently keeps a stale fault schedule.
    * ``file:///path`` — directory-backed store; objects land under
      ``<path>/objects`` and the manifest journal under ``<path>``
      unless ``journal_root`` overrides it.

    Real S3/GCS schemes are not bundled (no SDK in the image): pass a
    custom :class:`ObjectStore` to :class:`RemoteObjectBackend` instead.
    """
    scheme, sep, rest = url.partition("://")
    if not sep:
        raise ValueError(f"remote url {url!r} needs a scheme://")
    if scheme == "fake":
        bucket = rest or "default"
        with _FAKE_LOCK:
            store = _FAKE_BUCKETS.get(bucket)
            if store is None:
                store = FakeObjectStore()
                _FAKE_BUCKETS[bucket] = store
            # reconfigure faults on every call, cached bucket or not
            store.faults = (FaultInjector(rate=fault_rate, seed=seed)
                            if fault_rate > 0.0 else None)
        return RemoteObjectBackend(store, chunk_bytes=chunk_bytes,
                                   max_retries=max_retries,
                                   journal_root=journal_root, fmt=fmt)
    if scheme == "file":
        root = rest
        if not root:
            raise ValueError("file:// remote url needs a path")
        store = FilesystemObjectStore(os.path.join(root, "objects"))
        return RemoteObjectBackend(
            store, chunk_bytes=chunk_bytes, max_retries=max_retries,
            journal_root=journal_root if journal_root is not None else root,
            fmt=fmt)
    raise ValueError(
        f"unsupported remote scheme {scheme!r}: this build bundles "
        f"fake:// and file:// (implement ObjectStore for real buckets)")
