"""Typed sub-leaf patch currency for the persistence pipeline.

PR 5's incremental-merging engine moved the unit of change from "the
whole model" to "the leaves that changed" — but a leaf is still the
container, not the change: one routed token dirties an entire
``(n_experts * expert_ff, d_model)`` MoE table, so leaf granularity
persists ~100x more bytes than actually moved (the regime Check-N-Run's
row-sparse differentials target). This module is the shared currency
that drops the unit one more level, to *row ranges*:

* :class:`Span` — one contiguous run of rows (``start`` + the row
  block's array). A span whose block equals the full leaf shape is the
  degenerate whole-leaf update, so leaf-granular callers are just the
  one-span case and every old ``Dict[str, np.ndarray]`` patch coerces
  losslessly (:meth:`PatchSet.coerce`).
* :class:`PatchSet` — ``frame leaf name -> ordered disjoint spans``
  plus each leaf's full shape (sharded backends need the full
  first-axis extent to re-split ranges with ``np.array_split``
  boundaries). This is the one type every
  ``StorageBackend.patch`` implementation accepts — the drifting
  ``Dict[str, np.ndarray]`` / ``Dict[str, Any]`` signatures unify here.
* :class:`RowUpdate` — the *serialized* form of a row-sparse leaf
  inside a patch blob's partial state dict (a registered NamedTuple, so
  frames and npz round-trip it). ``store.merge_updates`` overlays it
  onto a base leaf at recovery; ``store.fold_updates`` converts chains
  of them into a merged :class:`PatchSet`.
* interval helpers — dirty-mask -> span extraction with adjacent-run
  coalescing (:func:`mask_to_intervals`) and newest-wins merging of a
  patch chain's overlapping spans (:func:`merge_span_chain`), both
  pure-index math shared by the replica tracker and the fold.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, NamedTuple, Optional, Sequence, \
    Tuple

import numpy as np


class Span(NamedTuple):
    """One contiguous row range of a leaf: rows ``[start, start +
    len(data))`` along axis 0, ``data.shape[1:]`` matching the leaf's
    tail. A 0-d / scalar leaf is a single span with ``start == 0``."""

    start: int
    data: np.ndarray

    @property
    def rows(self) -> int:
        d = np.asarray(self.data)
        return int(d.shape[0]) if d.ndim else 1

    @property
    def stop(self) -> int:
        return self.start + self.rows


class RowUpdate(NamedTuple):
    """Row-sparse leaf update inside a patch blob's partial state dict:
    parallel lists of span starts and row blocks, plus the full leaf
    shape (recovery validates against the base; the sharded backend
    needs the full extent to re-split). Registered with the frame codec
    so patch blobs holding it serialize through every backend."""

    starts: np.ndarray          #: (n,) int64 span start rows
    rows: list                  #: n arrays, rows[i].shape = (len_i, *tail)
    shape: tuple                #: full leaf shape

    def spans(self) -> List[Span]:
        return [Span(int(s), np.asarray(r))
                for s, r in zip(np.asarray(self.starts).tolist(), self.rows)]

    def extents(self) -> List[List[int]]:
        return [[sp.start, sp.stop] for sp in self.spans()]

    @property
    def nbytes(self) -> int:
        return int(sum(np.asarray(r).nbytes for r in self.rows))


def row_update_from_spans(spans: Sequence[Span],
                          shape: Sequence[int]) -> RowUpdate:
    spans = sorted(spans, key=lambda sp: sp.start)
    return RowUpdate(
        starts=np.asarray([sp.start for sp in spans], np.int64),
        rows=[np.asarray(sp.data) for sp in spans],
        shape=tuple(int(x) for x in shape))


class PatchSet:
    """Ordered, validated ``frame leaf name -> disjoint row spans``.

    The shared type all five ``StorageBackend.patch`` implementations
    accept. Supports dict-style iteration/indexing (``for name in ps``,
    ``ps[name]``) so slicing code can treat it like the legacy updates
    dict, plus :meth:`subset` for the fold's bounded slices.
    :meth:`coerce` upgrades legacy whole-leaf dicts in place, so old
    callers and old patch chains keep working unchanged."""

    def __init__(self) -> None:
        self._spans: Dict[str, List[Span]] = {}
        self._shapes: Dict[str, tuple] = {}

    # -- construction --------------------------------------------------
    def add(self, name: str, start: int, data,
            shape: Optional[Sequence[int]] = None) -> "PatchSet":
        """Add one span. ``shape`` is the leaf's *full* shape; omitted
        only for whole-leaf spans (it is then the data's own shape).
        Spans of one leaf must be disjoint; inserts keep them sorted."""
        a = np.asarray(data)
        start = int(start)
        if shape is None:
            if name in self._shapes:
                shape = self._shapes[name]
            elif start != 0:
                raise ValueError(
                    f"span for {name!r} at row {start} needs the leaf's "
                    f"full shape (only whole-leaf spans may omit it)")
            else:
                shape = a.shape
        shape = tuple(int(x) for x in shape)
        if start < 0:
            raise ValueError(f"span for {name!r}: negative start {start}")
        if shape:
            if a.shape[1:] != shape[1:]:
                raise ValueError(
                    f"span for {name!r}: tail {a.shape[1:]} != leaf tail "
                    f"{shape[1:]}")
            rows = int(a.shape[0]) if a.ndim else 1
            if start + rows > shape[0]:
                raise ValueError(
                    f"span for {name!r}: rows [{start}, {start + rows}) "
                    f"exceed leaf extent {shape[0]}")
        else:
            if start != 0 or a.shape != ():
                raise ValueError(
                    f"span for {name!r}: a scalar leaf takes exactly one "
                    f"whole span")
        known = self._shapes.get(name)
        if known is not None and known != shape:
            raise ValueError(f"leaf {name!r}: conflicting full shapes "
                             f"{known} and {shape}")
        self._shapes[name] = shape
        spans = self._spans.setdefault(name, [])
        sp = Span(start, a)
        for other in spans:
            if sp.start < other.stop and other.start < sp.stop:
                raise ValueError(
                    f"leaf {name!r}: span [{sp.start}, {sp.stop}) overlaps "
                    f"[{other.start}, {other.stop})")
        spans.append(sp)
        spans.sort(key=lambda s: s.start)
        return self

    def add_spans(self, name: str, spans: Sequence[Span],
                  shape: Sequence[int]) -> "PatchSet":
        for sp in spans:
            self.add(name, sp.start, sp.data, shape)
        return self

    @classmethod
    def from_arrays(cls, updates: Dict[str, np.ndarray]) -> "PatchSet":
        """Whole-leaf compatibility path: every value becomes one span
        covering its leaf."""
        ps = cls()
        for name, arr in updates.items():
            ps.add(name, 0, np.asarray(arr))
        return ps

    @classmethod
    def coerce(cls, obj) -> "PatchSet":
        """Accept a PatchSet, a legacy ``{name: array}`` dict, or a
        ``{name: [Span, ...]}``/``{name: RowUpdate}`` dict (shapes
        inferred where derivable)."""
        if isinstance(obj, cls):
            return obj
        if not isinstance(obj, dict):
            raise TypeError(f"cannot coerce {type(obj).__name__} to "
                            f"PatchSet")
        ps = cls()
        for name, v in obj.items():
            if isinstance(v, RowUpdate):
                ps.add_spans(name, v.spans(), v.shape)
            elif isinstance(v, (list, tuple)) \
                    and all(isinstance(s, Span) for s in v) and v:
                # span lists without a declared shape: bound the extent
                # by the last span (enough for patch_frame, which
                # validates against the frame header anyway)
                stop = max(s.stop for s in v)
                tail = np.asarray(v[0].data).shape[1:]
                ps.add_spans(name, list(v), (stop,) + tuple(tail))
            else:
                ps.add(name, 0, np.asarray(v))
        return ps

    # -- mapping surface ----------------------------------------------
    def __iter__(self) -> Iterator[str]:
        return iter(sorted(self._spans))

    def __len__(self) -> int:
        return len(self._spans)

    def __contains__(self, name: str) -> bool:
        return name in self._spans

    def __getitem__(self, name: str) -> Tuple[Span, ...]:
        return tuple(self._spans[name])

    def __bool__(self) -> bool:
        return bool(self._spans)

    def names(self) -> List[str]:
        return sorted(self._spans)

    def shape_of(self, name: str) -> tuple:
        return self._shapes[name]

    def is_whole(self, name: str) -> bool:
        """True when the leaf's spans are one full-cover span."""
        spans = self._spans[name]
        shape = self._shapes[name]
        if len(spans) != 1:
            return False
        sp = spans[0]
        return sp.start == 0 and (not shape or sp.rows == shape[0])

    @property
    def nbytes(self) -> int:
        return int(sum(np.asarray(sp.data).nbytes
                       for spans in self._spans.values() for sp in spans))

    @property
    def span_count(self) -> int:
        return sum(len(s) for s in self._spans.values())

    def extents(self) -> Dict[str, List[List[int]]]:
        return {name: [[sp.start, sp.stop] for sp in self._spans[name]]
                for name in self}

    # -- derived sets --------------------------------------------------
    def subset(self, names: Sequence[str]) -> "PatchSet":
        """Share-nothing-to-validate view over a subset of leaves (span
        arrays are shared by reference — subsets feed bounded fold
        slices, not mutation)."""
        ps = PatchSet()
        for name in names:
            ps._spans[name] = list(self._spans[name])
            ps._shapes[name] = self._shapes[name]
        return ps

    def copy(self) -> "PatchSet":
        """Deep copy (span data owned): for tiers that must snapshot the
        patch before handing it to an async write-back."""
        ps = PatchSet()
        for name, spans in self._spans.items():
            ps._spans[name] = [Span(sp.start, np.array(np.asarray(sp.data)))
                               for sp in spans]
            ps._shapes[name] = self._shapes[name]
        return ps

    # -- wire form -----------------------------------------------------
    def to_tree(self) -> dict:
        """Serializable pytree (plain dicts/lists/arrays) for the peer
        wire protocol's range PATCH payloads — round-trips through
        ``frame_dumps``/``frame_loads`` and zero-copy transports."""
        return {"__patchset__": 1,
                "leaves": {name: {
                    "shape": [int(x) for x in self._shapes[name]],
                    "starts": np.asarray(
                        [sp.start for sp in self._spans[name]], np.int64),
                    "rows": [np.asarray(sp.data)
                             for sp in self._spans[name]]}
                    for name in self}}

    @classmethod
    def is_tree(cls, obj) -> bool:
        return isinstance(obj, dict) and "__patchset__" in obj

    @classmethod
    def from_tree(cls, tree: dict) -> "PatchSet":
        ps = cls()
        for name, rec in tree["leaves"].items():
            shape = tuple(int(x) for x in rec["shape"])
            for s, r in zip(np.asarray(rec["starts"]).tolist(),
                            rec["rows"]):
                ps.add(name, int(s), np.asarray(r), shape)
        return ps


# ----------------------------------------------------------------------
# interval math
# ----------------------------------------------------------------------

def mask_to_intervals(persist: np.ndarray,
                      bridgeable: Optional[np.ndarray] = None,
                      max_gap: int = 0) -> List[Tuple[int, int]]:
    """Extract ``[start, stop)`` intervals from a boolean row mask,
    coalescing adjacent runs. With ``max_gap`` > 0 two runs separated by
    at most that many rows merge *when every gap row is bridgeable*
    (clean rows: re-writing them is a byte-identical no-op; a
    dirty-but-deferred row must never be bridged over — it would be
    persisted and defeat its deferral)."""
    idx = np.flatnonzero(persist)
    if idx.size == 0:
        return []
    out: List[Tuple[int, int]] = []
    start = prev = int(idx[0])
    for i in idx[1:].tolist():
        gap = i - prev - 1
        if gap == 0 or (gap <= max_gap and (
                bridgeable is None or bool(bridgeable[prev + 1:i].all()))):
            prev = i
            continue
        out.append((start, prev + 1))
        start = prev = i
    out.append((start, prev + 1))
    return out


def _subtract(start: int, stop: int,
              covered: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
    """Parts of [start, stop) not covered by the sorted disjoint list."""
    out = []
    pos = start
    for s, e in covered:
        if e <= pos:
            continue
        if s >= stop:
            break
        if s > pos:
            out.append((pos, min(s, stop)))
        pos = max(pos, e)
        if pos >= stop:
            break
    if pos < stop:
        out.append((pos, stop))
    return out


def _union(covered: List[Tuple[int, int]],
           iv: Tuple[int, int]) -> List[Tuple[int, int]]:
    """Insert one interval into a sorted disjoint list, merging."""
    s, e = iv
    out: List[Tuple[int, int]] = []
    placed = False
    for cs, ce in covered:
        if ce < s or cs > e:
            if not placed and cs > e:
                out.append((s, e))
                placed = True
            out.append((cs, ce))
        else:
            s, e = min(s, cs), max(e, ce)
    if not placed:
        out.append((s, e))
    out.sort()
    return out


def merge_span_chain(chain: Sequence[Sequence[Span]]) -> List[Span]:
    """Merge a patch chain's span lists (oldest -> newest) into one
    disjoint span list with *newest-wins* semantics: walking newest
    first, each span contributes only the row ranges no newer patch
    already covered — the emitted blocks are zero-copy views into the
    source arrays, so folding thousands of tiny patches never
    materializes a full leaf."""
    covered: List[Tuple[int, int]] = []
    out: List[Span] = []
    for spans in reversed(list(chain)):
        for sp in spans:
            d = np.asarray(sp.data)
            if d.ndim == 0:
                if not _subtract(0, 1, covered):
                    continue
                out.append(Span(0, d))
                covered = _union(covered, (0, 1))
                continue
            for s, e in _subtract(sp.start, sp.stop, covered):
                out.append(Span(s, d[s - sp.start:e - sp.start]))
            covered = _union(covered, (sp.start, sp.stop))
    out.sort(key=lambda sp: sp.start)
    return out
