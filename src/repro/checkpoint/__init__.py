"""Checkpoint storage engine: chain store + pluggable backends.

Construction is declarative: a store is a typed list of tier specs
(:class:`StoreConfig` / :class:`TierSpec` in
:mod:`repro.checkpoint.config`) —

::

    store = StoreConfig("/tmp/ck", tiers=[
        TierSpec("peer", replicas=2, hub="cluster"),
        TierSpec("memory", capacity_mb=256),
        TierSpec("local"),
    ], retention_fulls=2).build()

The legacy ``make_store(root, backend="...")`` keyword factory remains
as a deprecated shim delegating to :meth:`StoreConfig.from_legacy`.
"""
from __future__ import annotations

import warnings
from typing import Optional

from repro.checkpoint.backends import (BACKENDS, LocalFSBackend,
                                       MemoryTierBackend, ShardedBackend,
                                       StorageBackend, make_backend,
                                       make_pspec_splitter)
from repro.checkpoint.config import StoreConfig, StoreConfigError, TierSpec
from repro.checkpoint.io import FORMATS, FrameCorruptionError
from repro.checkpoint.journal import (JournalSegment, JournalTap,
                                      ManifestJournal,
                                      SegmentedManifestJournal)
from repro.checkpoint.patchset import (PatchSet, RowUpdate, Span,
                                       mask_to_intervals, merge_span_chain,
                                       row_update_from_spans)
from repro.checkpoint.peer import (LoopbackTransport, PeerGroup, PeerHub,
                                   PeerInfo, PeerNode, PeerReplicaBackend,
                                   PeerServer, PeerUnreachableError,
                                   SocketTransport, Transport, get_hub,
                                   reset_hub)
from repro.checkpoint.remote import (ChecksumError, FakeObjectStore,
                                     FaultInjector, FilesystemObjectStore,
                                     ObjectStore, RemoteObjectBackend,
                                     RetryExhaustedError,
                                     TransientStoreError,
                                     make_remote_backend)
from repro.checkpoint.store import CheckpointStore, order_fulls

__all__ = ["BACKENDS", "FORMATS", "CheckpointStore", "ChecksumError",
           "FakeObjectStore", "FaultInjector", "FilesystemObjectStore",
           "FrameCorruptionError", "JournalSegment", "JournalTap",
           "LocalFSBackend", "LoopbackTransport", "ManifestJournal",
           "MemoryTierBackend", "ObjectStore", "PatchSet", "PeerGroup",
           "PeerHub", "PeerInfo", "PeerNode", "PeerReplicaBackend",
           "PeerServer", "PeerUnreachableError", "RemoteObjectBackend",
           "RetryExhaustedError", "RowUpdate", "SegmentedManifestJournal",
           "ShardedBackend", "SocketTransport", "Span", "StorageBackend",
           "StoreConfig", "StoreConfigError", "TierSpec",
           "TransientStoreError", "Transport", "get_hub", "make_backend",
           "make_pspec_splitter", "make_remote_backend", "make_store",
           "mask_to_intervals", "merge_span_chain", "order_fulls",
           "reset_hub", "row_update_from_spans"]


def make_store(root: Optional[str], *, backend: str = "local",
               shards: int = 4, capacity_mb: Optional[float] = None,
               retention_fulls: int = 0, compact_every: int = 256,
               remote_url: Optional[str] = None, chunk_mb: float = 4.0,
               max_retries: int = 4, remote_fault_rate: float = 0.0,
               fmt: str = "frame", eviction: str = "fifo",
               host_id: Optional[str] = None) -> CheckpointStore:
    """Deprecated shim: build a CheckpointStore from the legacy keyword
    surface. New code should declare the store with
    :class:`StoreConfig` and call :meth:`StoreConfig.build` — the tier
    list expresses what these keywords implied (and more, e.g. the
    peer replication tier)."""
    warnings.warn(
        "make_store() is deprecated; declare the store with "
        "repro.checkpoint.config.StoreConfig and call build()",
        DeprecationWarning, stacklevel=2)
    cfg = StoreConfig.from_legacy(
        root, backend=backend, shards=shards, capacity_mb=capacity_mb,
        retention_fulls=retention_fulls, compact_every=compact_every,
        remote_url=remote_url, chunk_mb=chunk_mb, max_retries=max_retries,
        remote_fault_rate=remote_fault_rate, fmt=fmt, eviction=eviction,
        host_id=host_id)
    return cfg.build()
