"""Checkpoint storage engine: chain store + pluggable backends.

``make_store`` is the one-stop factory used by the launcher, examples
and benchmarks to select a backend by name::

    store = make_store("/tmp/ck", backend="sharded", shards=8,
                       retention_fulls=2)
"""
from __future__ import annotations

from typing import Optional

from repro.checkpoint.backends import (BACKENDS, LocalFSBackend,
                                       MemoryTierBackend, ShardedBackend,
                                       StorageBackend, make_backend,
                                       make_pspec_splitter)
from repro.checkpoint.store import CheckpointStore

__all__ = ["BACKENDS", "CheckpointStore", "LocalFSBackend",
           "MemoryTierBackend", "ShardedBackend", "StorageBackend",
           "make_backend", "make_pspec_splitter", "make_store"]


def make_store(root: Optional[str], *, backend: str = "local",
               shards: int = 4, capacity_mb: Optional[float] = None,
               retention_fulls: int = 0,
               compact_every: int = 256) -> CheckpointStore:
    """Build a CheckpointStore over the named backend."""
    be = make_backend(backend, root, shards=shards, capacity_mb=capacity_mb)
    return CheckpointStore(root, backend=be, retention_fulls=retention_fulls,
                           compact_every=compact_every)
