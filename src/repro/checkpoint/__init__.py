"""Checkpoint storage engine: chain store + pluggable backends.

``make_store`` is the one-stop factory used by the launcher, examples
and benchmarks to select a backend by name::

    store = make_store("/tmp/ck", backend="sharded", shards=8,
                       retention_fulls=2)
    store = make_store("/tmp/ck", backend="remote",
                       remote_url="fake://bucket", chunk_mb=2.0)
"""
from __future__ import annotations

from typing import Optional

from repro.checkpoint.backends import (BACKENDS, LocalFSBackend,
                                       MemoryTierBackend, ShardedBackend,
                                       StorageBackend, make_backend,
                                       make_pspec_splitter)
from repro.checkpoint.io import FORMATS, FrameCorruptionError
from repro.checkpoint.journal import (JournalSegment, ManifestJournal,
                                      SegmentedManifestJournal)
from repro.checkpoint.remote import (ChecksumError, FakeObjectStore,
                                     FaultInjector, FilesystemObjectStore,
                                     ObjectStore, RemoteObjectBackend,
                                     RetryExhaustedError,
                                     TransientStoreError,
                                     make_remote_backend)
from repro.checkpoint.store import CheckpointStore

__all__ = ["BACKENDS", "FORMATS", "CheckpointStore", "ChecksumError",
           "FakeObjectStore", "FaultInjector", "FilesystemObjectStore",
           "FrameCorruptionError", "JournalSegment", "LocalFSBackend",
           "ManifestJournal", "MemoryTierBackend", "ObjectStore",
           "RemoteObjectBackend", "RetryExhaustedError",
           "SegmentedManifestJournal", "ShardedBackend", "StorageBackend",
           "TransientStoreError", "make_backend", "make_pspec_splitter",
           "make_remote_backend", "make_store"]


def make_store(root: Optional[str], *, backend: str = "local",
               shards: int = 4, capacity_mb: Optional[float] = None,
               retention_fulls: int = 0, compact_every: int = 256,
               remote_url: Optional[str] = None, chunk_mb: float = 4.0,
               max_retries: int = 4, remote_fault_rate: float = 0.0,
               fmt: str = "frame", eviction: str = "fifo",
               host_id: Optional[str] = None) -> CheckpointStore:
    """Build a CheckpointStore over the named backend. ``fmt`` picks the
    write serialization ("frame" streamed zero-copy / "npz" legacy);
    reads sniff, so existing npz chains stay recoverable either way.
    ``eviction`` selects the memory tier's victim policy (fifo / lru
    over size-class buckets); ``host_id`` switches the manifest journal
    to per-host segments for multi-controller jobs."""
    be = make_backend(backend, root, shards=shards, capacity_mb=capacity_mb,
                      remote_url=remote_url, chunk_mb=chunk_mb,
                      max_retries=max_retries,
                      remote_fault_rate=remote_fault_rate, fmt=fmt,
                      eviction=eviction)
    return CheckpointStore(root, backend=be, retention_fulls=retention_fulls,
                           compact_every=compact_every, host_id=host_id)
