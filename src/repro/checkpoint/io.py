"""Atomic pytree checkpoint storage on a filesystem.

A checkpoint is one ``.npz`` (uncompressed zip of raw .npy buffers — the
write cost is the tensor bytes, which is what the paper's model meters)
plus an embedded JSON structure descriptor. Writes go to a temp file and
``os.replace`` in, so readers never observe a torn checkpoint. Supports
arbitrary nesting of dict / list / tuple / NamedTuple / SparseGrad /
QuantGrad / jax arrays / numpy / python scalars.
"""
from __future__ import annotations

import io as _io
import json
import os
import tempfile
from typing import Any, Dict, List, Tuple

import jax
import numpy as np

from repro.compression.quant import QuantGrad
from repro.compression.sparse import SparseGrad

_NAMEDTUPLES: Dict[str, type] = {}


def register_namedtuple(cls) -> type:
    _NAMEDTUPLES[cls.__name__] = cls
    return cls


def _register_builtin():
    from repro.models import blocks, encdec, lm, linear_attn, xlstm
    from repro.optim import adam
    for cls in (adam.AdamState, linear_attn.LinState, blocks.MambaCache,
                xlstm.MLSTMCache, xlstm.SLSTMState, lm.DecodeCache,
                encdec.EncDecCache):
        register_namedtuple(cls)


_register_builtin()


def _pack(obj, arrays: List[np.ndarray]):
    """Recursively encode obj into JSON-able structure + array list."""
    if isinstance(obj, SparseGrad):
        return {"__t": "sparse", "shape": list(obj.shape), "block": obj.block,
                "values": _arr(obj.values, arrays),
                "indices": _arr(obj.indices, arrays)}
    if isinstance(obj, QuantGrad):
        return {"__t": "quant", "shape": list(obj.shape), "block": obj.block,
                "q": _arr(obj.q, arrays), "scale": _arr(obj.scale, arrays)}
    if isinstance(obj, dict):
        return {"__t": "dict",
                "items": {k: _pack(v, arrays) for k, v in obj.items()}}
    if hasattr(obj, "_fields"):  # NamedTuple
        return {"__t": "nt", "cls": type(obj).__name__,
                "items": {f: _pack(getattr(obj, f), arrays)
                          for f in obj._fields}}
    if isinstance(obj, (list, tuple)):
        return {"__t": "list" if isinstance(obj, list) else "tuple",
                "items": [_pack(v, arrays) for v in obj]}
    if isinstance(obj, (jax.Array, np.ndarray, np.generic)):
        return {"__t": "arr", "i": _arr(obj, arrays)}
    if isinstance(obj, (int, float, str, bool)) or obj is None:
        return {"__t": "py", "v": obj}
    raise TypeError(f"cannot checkpoint {type(obj)}")


def _arr(x, arrays: List[np.ndarray]) -> int:
    a = np.asarray(x)
    if a.dtype == np.dtype("bfloat16"):
        arrays.append(a.view(np.uint16))
        return -len(arrays)  # negative index marks bf16 view
    arrays.append(a)
    return len(arrays) - 1


def _unpack(node, arrays):
    t = node["__t"]
    if t == "sparse":
        return SparseGrad(_get(node["values"], arrays),
                          _get(node["indices"], arrays),
                          tuple(node["shape"]), node["block"])
    if t == "quant":
        return QuantGrad(_get(node["q"], arrays), _get(node["scale"], arrays),
                         tuple(node["shape"]), node["block"])
    if t == "dict":
        return {k: _unpack(v, arrays) for k, v in node["items"].items()}
    if t == "nt":
        cls = _NAMEDTUPLES[node["cls"]]
        return cls(**{k: _unpack(v, arrays) for k, v in node["items"].items()})
    if t == "list":
        return [_unpack(v, arrays) for v in node["items"]]
    if t == "tuple":
        return tuple(_unpack(v, arrays) for v in node["items"])
    if t == "arr":
        return _get(node["i"], arrays)
    if t == "py":
        return node["v"]
    raise TypeError(t)


def _get(i: int, arrays):
    import ml_dtypes
    if i < 0:
        return arrays[f"a{-i - 1}"].view(ml_dtypes.bfloat16)
    return arrays[f"a{i}"]


def pack(obj: Any) -> Tuple[dict, List[np.ndarray]]:
    """Encode obj into (JSON-able structure, flat host-array list).

    bf16 leaves are stored as uint16 views and referenced by negative
    index in the structure (see ``_arr``); everything else by its
    position in the list. The inverse is :func:`unpack`.
    """
    arrays: List[np.ndarray] = []
    struct = _pack(obj, arrays)
    return struct, arrays


def unpack(struct: dict, arrays) -> Any:
    """Inverse of :func:`pack`. ``arrays`` is any mapping with keys
    ``a0..aN`` (an open npz file works) or a plain list."""
    if isinstance(arrays, (list, tuple)):
        arrays = {f"a{i}": a for i, a in enumerate(arrays)}
    return _unpack(struct, arrays)


def atomic_write(path: str, write_fn) -> int:
    """Crash-safe file write: mkstemp in the target directory,
    ``write_fn(binary_file)``, flush+fsync, then ``os.replace`` — a
    reader never observes a torn file. The single implementation of the
    pattern; every backend's durable write goes through it. Returns
    bytes written."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return os.path.getsize(path)


def save_npz(path: str, payload: Dict[str, np.ndarray]) -> int:
    """Atomic + fsync'd raw npz write. Returns bytes written."""
    return atomic_write(path, lambda f: np.savez(f, **payload))


def load_npz(path: str) -> Dict[str, np.ndarray]:
    """Fully materialize an npz written by :func:`save_npz`."""
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def payload_of(obj: Any) -> Dict[str, np.ndarray]:
    """Encode obj as the canonical npz payload dict (``a0..aN`` +
    embedded ``__struct__``). Single source of truth for the on-wire /
    on-disk encoding — every backend writes exactly this."""
    struct, arrays = pack(obj)
    payload = {f"a{i}": a for i, a in enumerate(arrays)}
    payload["__struct__"] = np.frombuffer(
        json.dumps(struct).encode(), dtype=np.uint8)
    return payload


def dumps(obj: Any) -> bytes:
    """Serialize obj to npz bytes (the same encoding :func:`save` puts
    on disk) — for backends that ship byte blobs instead of files."""
    buf = _io.BytesIO()
    np.savez(buf, **payload_of(obj))
    return buf.getvalue()


def loads(data: bytes) -> Any:
    """Inverse of :func:`dumps`."""
    with np.load(_io.BytesIO(data)) as z:
        struct = json.loads(bytes(z["__struct__"]).decode())
        return _unpack(struct, z)


def save(path: str, obj: Any) -> int:
    """Atomic write. Returns bytes written."""
    return save_npz(path, payload_of(obj))


def load(path: str) -> Any:
    with np.load(path) as z:
        struct = json.loads(bytes(z["__struct__"]).decode())
        return _unpack(struct, z)
