"""Atomic pytree checkpoint serialization: streamed frames + legacy npz.

Two on-disk encodings share one pytree codec (:func:`pack` /
:func:`unpack`):

* **Frame** (the fast path) — a streamed zero-copy format::

      RFRAME01 | header_len u64le | JSON header | pad -> 64B | leaf buffers

  The JSON header carries the structure descriptor plus one record per
  leaf: byte ``offset`` (relative to the 64-byte-aligned data section),
  ``nbytes``, ``dtype``, ``shape`` and ``sha256``. Every leaf buffer is
  64-byte aligned. Writers stream leaf-by-leaf via ``memoryview`` —
  there is never an intermediate serialized blob — and readers map the
  file with ``np.memmap`` so recovery touches only the leaves it needs.

* **npz** (the seed format) — an uncompressed zip of raw ``.npy``
  buffers with an embedded JSON structure descriptor. Kept fully
  readable (and writable via ``fmt="npz"``) so old checkpoints and
  mixed-format chains keep recovering; :func:`load_any` /
  :func:`loads_any` sniff the magic bytes.

Writes go through :func:`atomic_write` (temp file + fsync + rename +
parent-directory fsync), so readers never observe a torn checkpoint and
a crash immediately after the rename cannot lose it. Supports arbitrary
nesting of dict / list / tuple / NamedTuple / SparseGrad / QuantGrad /
PackedDiff / QuantSpan / jax arrays / numpy / python scalars.
"""
from __future__ import annotations

import hashlib
import io as _io
import json
import os
import struct as _struct
import tempfile
import threading
from typing import Any, Dict, Iterator, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

from repro.checkpoint.patchset import PatchSet, RowUpdate
from repro.compression.packed import PackedDiff
from repro.compression.quant import QuantGrad
from repro.compression.quant_span import QuantSpan
from repro.compression.sparse import SparseGrad

FRAME_MAGIC = b"RFRAME01"
FRAME_ALIGN = 64
FORMATS = ("frame", "npz")

_NAMEDTUPLES: Dict[str, type] = {}


def register_namedtuple(cls) -> type:
    _NAMEDTUPLES[cls.__name__] = cls
    return cls


def _register_builtin():
    from repro.models import blocks, encdec, lm, linear_attn, xlstm
    from repro.optim import adam
    for cls in (adam.AdamState, linear_attn.LinState, blocks.MambaCache,
                xlstm.MLSTMCache, xlstm.SLSTMState, lm.DecodeCache,
                encdec.EncDecCache):
        register_namedtuple(cls)


_register_builtin()
# row-sparse leaf updates inside patch blobs serialize like any other
# NamedTuple leaf container
register_namedtuple(RowUpdate)


class FrameCorruptionError(ValueError):
    """A frame failed structural validation or a leaf sha256 check."""


class CopyMeter:
    """Process-wide counter of host-side copies of tensor bytes.

    Instrumented at the points the zero-copy work eliminates: the D2H
    snapshot (the one unavoidable copy), npz blob materialization
    (``dumps``) and the remote tier's chunk re-slicing of that blob.
    ``benchmarks/serialization.py`` reads it to report copies-per-
    checkpoint for the npz vs frame paths.

    On top of the flat host-copy counter (``bytes``/``events``,
    semantics unchanged), the meter tracks the two PCIe directions the
    checkpoint pipeline moves tensor bytes over:

    * **D2H** — snapshot transfers off the device. ``wait_s`` is the
      time a consumer actually blocked for the bytes and ``span_s`` the
      issue-to-landed window, so ``d2h_overlap_ratio`` reports how much
      of the transfer hid behind compute (1.0 = fully overlapped).
    * **H2D** — recovery-replay uploads back onto the device. These
      were invisible before: recovery stacked payloads with jnp and the
      implicit transfer never hit any counter, so benchmarks could not
      report replay bandwidth honestly.
    """

    #: stats() keys, synced against the instrument set by
    #: tests/test_observability.py (``d2h_overlap_ratio`` is derived)
    KEYS = ("bytes", "events", "h2d_bytes", "h2d_events", "d2h_bytes",
            "d2h_events", "d2h_wait_s", "d2h_span_s")

    def __init__(self):
        from repro.obs.metrics import InstrumentSet
        self._inst = InstrumentSet("copy_meter")
        self._bytes = self._inst.counter("bytes")
        self._events = self._inst.counter("events")
        self._h2d_bytes = self._inst.counter("h2d_bytes")
        self._h2d_events = self._inst.counter("h2d_events")
        self._d2h_bytes = self._inst.counter("d2h_bytes")
        self._d2h_events = self._inst.counter("d2h_events")
        # histograms: the JSONL dump gets p50/p95/p99 of per-transfer
        # wait/span; stats() keeps reading the sums under the old keys
        self._d2h_wait = self._inst.histogram("d2h_wait_s")
        self._d2h_span = self._inst.histogram("d2h_span_s")

    # legacy attribute surface (tests and benchmarks read these raw)
    @property
    def bytes(self) -> int:
        return int(self._bytes.value)

    @property
    def events(self) -> int:
        return int(self._events.value)

    @property
    def h2d_bytes(self) -> int:
        return int(self._h2d_bytes.value)

    @property
    def h2d_events(self) -> int:
        return int(self._h2d_events.value)

    @property
    def d2h_bytes(self) -> int:
        return int(self._d2h_bytes.value)

    @property
    def d2h_events(self) -> int:
        return int(self._d2h_events.value)

    @property
    def d2h_wait_s(self) -> float:
        return self._d2h_wait.sum

    @property
    def d2h_span_s(self) -> float:
        return self._d2h_span.sum

    def add(self, nbytes: int) -> None:
        self._bytes.add(int(nbytes))
        self._events.add(1)

    def add_h2d(self, nbytes: int) -> None:
        """Replay-path host-to-device upload of checkpoint payloads."""
        self._h2d_bytes.add(int(nbytes))
        self._h2d_events.add(1)

    def add_d2h(self, nbytes: int, *, wait_s: float = 0.0,
                span_s: float = 0.0) -> None:
        """Snapshot device-to-host transfer. ``wait_s``: time the
        consumer blocked; ``span_s``: issue-to-landed window."""
        self._d2h_bytes.add(int(nbytes))
        self._d2h_events.add(1)
        self._d2h_wait.observe(float(wait_s))
        self._d2h_span.observe(float(span_s))

    def d2h_overlap_ratio(self) -> Optional[float]:
        """Fraction of the D2H transfer window hidden behind compute
        (None until a metered transfer recorded its span)."""
        span = self._d2h_span.sum
        if span <= 0.0:
            return None
        return max(0.0, 1.0 - self._d2h_wait.sum / span)

    def instruments(self):
        """The backing :class:`~repro.obs.metrics.InstrumentSet`."""
        return self._inst

    def stats(self) -> Dict[str, Any]:
        out = {k: getattr(self, k) for k in self.KEYS}
        out["d2h_overlap_ratio"] = self.d2h_overlap_ratio()
        return out

    def reset(self) -> None:
        self._bytes.reset()
        self._events.reset()
        self._h2d_bytes.reset()
        self._h2d_events.reset()
        self._d2h_bytes.reset()
        self._d2h_events.reset()
        self._d2h_wait.reset()
        self._d2h_span.reset()


COPY_METER = CopyMeter()


# ----------------------------------------------------------------------
# pytree <-> (struct, arrays) codec
# ----------------------------------------------------------------------

def _pack(obj, arrays: List[np.ndarray]):
    """Recursively encode obj into JSON-able structure + array list."""
    if isinstance(obj, SparseGrad):
        return {"__t": "sparse", "shape": list(obj.shape), "block": obj.block,
                "values": _arr(obj.values, arrays),
                "indices": _arr(obj.indices, arrays)}
    if isinstance(obj, QuantGrad):
        return {"__t": "quant", "shape": list(obj.shape), "block": obj.block,
                "q": _arr(obj.q, arrays), "scale": _arr(obj.scale, arrays)}
    if isinstance(obj, PackedDiff):
        # block-local indices (< block <= 32768) narrow losslessly to
        # int16 on the wire — this is what makes the nbytes accounting
        # (1 + 2 bytes per selected element + scales) real on disk
        idx = np.asarray(obj.indices)
        if obj.block <= np.iinfo(np.int16).max + 1:
            idx = idx.astype(np.int16)
        return {"__t": "packed", "shape": list(obj.shape), "block": obj.block,
                "q": _arr(obj.q, arrays), "indices": _arr(idx, arrays),
                "scale": _arr(obj.scale, arrays)}
    if isinstance(obj, QuantSpan):
        # quantized row-span payload: wire bytes travel verbatim — no
        # backend ever re-encodes (and so never re-quantizes) them
        return {"__t": "qspan", "shape": list(obj.shape),
                "bits": int(obj.bits), "dtype": str(obj.dtype),
                "starts": [int(s) for s in obj.starts],
                "qs": [_arr(q, arrays) for q in obj.qs],
                "scales": [_arr(s, arrays) for s in obj.scales]}
    if isinstance(obj, dict):
        return {"__t": "dict",
                "items": {k: _pack(v, arrays) for k, v in obj.items()}}
    if hasattr(obj, "_fields"):  # NamedTuple
        return {"__t": "nt", "cls": type(obj).__name__,
                "items": {f: _pack(getattr(obj, f), arrays)
                          for f in obj._fields}}
    if isinstance(obj, (list, tuple)):
        return {"__t": "list" if isinstance(obj, list) else "tuple",
                "items": [_pack(v, arrays) for v in obj]}
    if isinstance(obj, (jax.Array, np.ndarray, np.generic)):
        return {"__t": "arr", "i": _arr(obj, arrays)}
    if isinstance(obj, (int, float, str, bool)) or obj is None:
        return {"__t": "py", "v": obj}
    raise TypeError(f"cannot checkpoint {type(obj)}")


def _arr(x, arrays: List[np.ndarray]) -> int:
    a = np.asarray(x)
    if a.dtype == np.dtype("bfloat16"):
        arrays.append(a.view(np.uint16))
        return -len(arrays)  # negative index marks bf16 view
    arrays.append(a)
    return len(arrays) - 1


def _unpack(node, arrays):
    t = node["__t"]
    if t == "sparse":
        return SparseGrad(_get(node["values"], arrays),
                          _get(node["indices"], arrays),
                          tuple(node["shape"]), node["block"])
    if t == "quant":
        return QuantGrad(_get(node["q"], arrays), _get(node["scale"], arrays),
                         tuple(node["shape"]), node["block"])
    if t == "packed":
        # widen wire int16 indices back to the kernels' int32
        return PackedDiff(_get(node["q"], arrays),
                          np.asarray(_get(node["indices"], arrays),
                                     np.int32),
                          _get(node["scale"], arrays),
                          tuple(node["shape"]), node["block"])
    if t == "qspan":
        return QuantSpan(starts=tuple(int(s) for s in node["starts"]),
                         qs=[np.asarray(_get(i, arrays))
                             for i in node["qs"]],
                         scales=[np.asarray(_get(i, arrays))
                                 for i in node["scales"]],
                         shape=tuple(node["shape"]), bits=int(node["bits"]),
                         dtype=node["dtype"])
    if t == "dict":
        return {k: _unpack(v, arrays) for k, v in node["items"].items()}
    if t == "nt":
        cls = _NAMEDTUPLES[node["cls"]]
        return cls(**{k: _unpack(v, arrays) for k, v in node["items"].items()})
    if t == "list":
        return [_unpack(v, arrays) for v in node["items"]]
    if t == "tuple":
        return tuple(_unpack(v, arrays) for v in node["items"])
    if t == "arr":
        return _get(node["i"], arrays)
    if t == "py":
        return node["v"]
    raise TypeError(t)


def _get(i: int, arrays):
    if i < 0:
        return arrays[f"a{-i - 1}"].view(ml_dtypes.bfloat16)
    return arrays[f"a{i}"]


def pack(obj: Any) -> Tuple[dict, List[np.ndarray]]:
    """Encode obj into (JSON-able structure, flat host-array list).

    bf16 leaves are stored as uint16 views and referenced by negative
    index in the structure (see ``_arr``); everything else by its
    position in the list. The inverse is :func:`unpack`.
    """
    arrays: List[np.ndarray] = []
    struct = _pack(obj, arrays)
    return struct, arrays


def unpack(struct: dict, arrays) -> Any:
    """Inverse of :func:`pack`. ``arrays`` is any mapping with keys
    ``a0..aN`` (an open npz file works) or a plain list."""
    if isinstance(arrays, (list, tuple)):
        arrays = {f"a{i}": a for i, a in enumerate(arrays)}
    return _unpack(struct, arrays)


# ----------------------------------------------------------------------
# atomic file writes
# ----------------------------------------------------------------------

def _fsync_dir(path: str) -> None:
    """fsync a directory so a just-renamed entry survives a crash.
    Platforms whose directory handles reject fsync are skipped."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def atomic_write(path: str, write_fn) -> int:
    """Crash-safe file write: mkstemp in the target directory,
    ``write_fn(binary_file)``, flush+fsync, ``os.replace``, then fsync
    the parent directory (the rename itself is only durable once the
    directory entry is) — a reader never observes a torn file and a
    crash immediately after cannot un-publish it. The single
    implementation of the pattern; every backend's durable write goes
    through it. Returns bytes written."""
    parent = os.path.dirname(path) or "."
    os.makedirs(parent, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    _fsync_dir(parent)
    return os.path.getsize(path)


# ----------------------------------------------------------------------
# legacy npz encoding
# ----------------------------------------------------------------------

def save_npz(path: str, payload: Dict[str, np.ndarray]) -> int:
    """Atomic + fsync'd raw npz write. Returns bytes written."""
    return atomic_write(path, lambda f: np.savez(f, **payload))


def load_npz(path: str) -> Dict[str, np.ndarray]:
    """Fully materialize an npz written by :func:`save_npz`."""
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def payload_of(obj: Any) -> Dict[str, np.ndarray]:
    """Encode obj as the canonical payload dict (``a0..aN`` +
    embedded ``__struct__``). Single source of truth for the npz
    encoding — every npz writer emits exactly this."""
    struct, arrays = pack(obj)
    payload = {f"a{i}": a for i, a in enumerate(arrays)}
    payload["__struct__"] = np.frombuffer(
        json.dumps(struct).encode(), dtype=np.uint8)
    return payload


def dumps(obj: Any) -> bytes:
    """Serialize obj to npz bytes — for byte-blob backends on the
    legacy path. Materializes the full blob in memory (the copy the
    frame path exists to avoid), so it reports to the copy meter."""
    buf = _io.BytesIO()
    np.savez(buf, **payload_of(obj))
    data = buf.getvalue()
    COPY_METER.add(len(data))
    return data


def loads(data: bytes) -> Any:
    """Inverse of :func:`dumps`."""
    with np.load(_io.BytesIO(data)) as z:
        struct = json.loads(bytes(z["__struct__"]).decode())
        return _unpack(struct, z)


def save(path: str, obj: Any) -> int:
    """Atomic npz write (legacy format). Returns bytes written."""
    return save_npz(path, payload_of(obj))


def load(path: str) -> Any:
    """Load a checkpoint file of either format (magic-sniffed)."""
    return load_any(path)


# ----------------------------------------------------------------------
# streamed frame format
# ----------------------------------------------------------------------

def _byte_view(a: np.ndarray) -> np.ndarray:
    """Flat uint8 view of a C-contiguous array (zero-copy)."""
    flat = a.reshape(-1) if a.ndim != 1 else a
    if flat.size == 0:
        return np.empty(0, np.uint8)
    return flat.view(np.uint8)


def frame_payload(obj: Any) -> Tuple[Dict[str, np.ndarray], dict]:
    struct, arrays = pack(obj)
    payload = {f"a{i}": a for i, a in enumerate(arrays)}
    return payload, {"struct": struct}


def _frame_plan(payload: Dict[str, np.ndarray],
                extra: Optional[dict]) -> Tuple[bytes, List[np.ndarray],
                                                List[int], int]:
    """Lay the frame out: returns (prefix_bytes, contiguous arrays,
    per-leaf pad-before sizes, total frame bytes). Leaf offsets in the
    header are relative to the 64-byte-aligned data section, so the
    header's own size never perturbs them."""
    names = list(payload)
    # NB: ascontiguousarray only when needed — it would promote 0-d
    # scalars to shape (1,), breaking bit-identical npz parity
    arrays = [a if a.flags.c_contiguous else np.ascontiguousarray(a)
              for a in (np.asarray(payload[n]) for n in names)]
    leaves, pads, rel = [], [], 0
    for name, a in zip(names, arrays):
        pad = (-rel) % FRAME_ALIGN
        rel += pad
        pads.append(pad)
        view = _byte_view(a)
        leaves.append({"name": name, "offset": rel, "nbytes": int(a.nbytes),
                       "dtype": a.dtype.str, "shape": list(a.shape),
                       "sha256": hashlib.sha256(view).hexdigest()})
        rel += a.nbytes
    header = {"version": 1, "leaves": leaves, "data_bytes": rel}
    if extra:
        header.update(extra)
    hjson = json.dumps(header).encode("utf-8")
    pre = len(FRAME_MAGIC) + 8 + len(hjson)
    hpad = (-pre) % FRAME_ALIGN
    prefix = (FRAME_MAGIC + _struct.pack("<Q", len(hjson)) + hjson
              + b"\0" * hpad)
    return prefix, arrays, pads, len(prefix) + rel


def frame_segments(payload: Dict[str, np.ndarray],
                   extra: Optional[dict] = None
                   ) -> Tuple[int, Iterator[Any]]:
    """(total_bytes, iterator of buffers) for a frame. Large leaf
    buffers are yielded as zero-copy uint8 views; only the header and
    the <=63-byte alignment pads are freshly allocated bytes."""
    prefix, arrays, pads, total = _frame_plan(payload, extra)

    def gen():
        yield prefix
        for pad, a in zip(pads, arrays):
            if pad:
                yield b"\0" * pad
            if a.nbytes:
                yield _byte_view(a)

    return total, gen()


def write_frame(f, payload: Dict[str, np.ndarray],
                extra: Optional[dict] = None) -> int:
    """Stream a frame into a binary file object, leaf by leaf — no
    intermediate serialized blob. Returns bytes written."""
    total, segs = frame_segments(payload, extra)
    for seg in segs:
        f.write(seg)
    return total


#: ceiling on the coalesce threshold: segments at or below it are packed
#: together into shared chunks (a bounded copy of small glue + small
#: leaves), segments above it stream as zero-copy view slices — copying
#: a header is noise, re-slicing a 100MB leaf is the copy we exist to
#: avoid
_COALESCE_MAX = 1 << 18


def frame_chunks(payload: Dict[str, np.ndarray], chunk_bytes: int,
                 extra: Optional[dict] = None) -> Iterator[Any]:
    """Yield the frame as a sequence of buffers each <= ``chunk_bytes``,
    for backends that upload chunk objects. Large leaf buffers are
    yielded as zero-copy views sliced at chunk boundaries; small
    segments (header, pads, sub-256KB leaves) are coalesced into shared
    chunks so a pytree of many small leaves does not explode the object
    count. Coalesced *tensor* bytes report to the copy meter — they are
    the only host copy the frame path ever makes, bounded by the
    coalesce threshold per leaf."""
    coalesce = min(_COALESCE_MAX, chunk_bytes)
    _, segs = frame_segments(payload, extra)
    pending = bytearray()
    for seg in segs:
        is_leaf = isinstance(seg, np.ndarray)
        n = seg.nbytes if is_leaf else len(seg)
        if n <= coalesce:
            if pending and len(pending) + n > chunk_bytes:
                yield bytes(pending)
                pending = bytearray()
            pending += bytes(seg)
            if is_leaf:
                COPY_METER.add(n)
            continue
        if pending:
            yield bytes(pending)
            pending = bytearray()
        view = seg if is_leaf else memoryview(seg)
        for o in range(0, n, chunk_bytes):
            yield view[o:o + chunk_bytes]
    if pending:
        yield bytes(pending)


def save_frame_payload(path: str, payload: Dict[str, np.ndarray],
                       extra: Optional[dict] = None) -> int:
    """Atomic streamed frame write of a named-array payload."""
    return atomic_write(path, lambda f: write_frame(f, payload, extra))


def save_frame(path: str, obj: Any) -> int:
    """Atomic streamed frame write of a pytree. Returns bytes written."""
    payload, extra = frame_payload(obj)
    return save_frame_payload(path, payload, extra)


def frame_dumps(obj: Any) -> bytes:
    """Frame bytes in memory (tests / byte-blob transports)."""
    payload, extra = frame_payload(obj)
    total, segs = frame_segments(payload, extra)
    out = bytearray(total)
    pos = 0
    for seg in segs:
        b = memoryview(seg).cast("B") if isinstance(seg, np.ndarray) \
            else memoryview(seg)
        out[pos:pos + len(b)] = b
        pos += len(b)
    return bytes(out)


#: test seam: callable(point: str) fired at named points inside
#: :func:`patch_frame` — "patch:mid_span" (after the first row-range
#: pwrite when more spans remain), "patch:mid_data" (after the first
#: leaf's spans are fully written, before the rest), "patch:pre_header"
#: (data fsync'd, header still old) and "patch:mid_header" (half the
#: header bytes rewritten). Raising from the hook simulates a kill at
#: exactly that point.
_PATCH_CRASH_HOOK = None


def set_patch_crash_hook(hook) -> None:
    global _PATCH_CRASH_HOOK
    _PATCH_CRASH_HOOK = hook


def patch_frame(path: str, updates) -> int:
    """In-place partial rewrite of a frame file: overwrite the patched
    row ranges at ``leaf_offset + row_start * row_stride`` (the 64-byte-
    aligned layout never moves, so a span lands exactly on the rows it
    replaces), then rewrite the header with the new sha256s. ``updates``
    is anything :meth:`PatchSet.coerce` accepts — a :class:`PatchSet`
    or the legacy ``{name: whole_array}`` dict. Write order is the
    crash-consistency contract:

    1. span buffers are pwritten and fsync'd *first*;
    2. each patched leaf's sha256 is recomputed over the patched region
       *plus* the retained spans (read back for partially-patched
       leaves);
    3. the header (same byte length — a sha256 hex digest is fixed
       width) is rewritten *last*.

    A crash at any point leaves a frame whose patched ranges may hold
    torn bytes or stale digests — which is why callers journal each
    patch as a durable blob *before* folding it in: recovery replays
    the patch chain over the base, overwriting exactly the ranges a
    partial patch could have torn. Returns bytes written."""
    patch = PatchSet.coerce(updates)
    hook = _PATCH_CRASH_HOOK
    magic_len = len(FRAME_MAGIC)
    with open(path, "r+b") as f:
        head = f.read(magic_len + 8)
        if len(head) < magic_len + 8 or head[:magic_len] != FRAME_MAGIC:
            raise FrameCorruptionError(
                f"{path}: not a frame (bad magic); only frame files can "
                f"be patched in place")
        (hlen,) = _struct.unpack("<Q", head[magic_len:magic_len + 8])
        try:
            header = json.loads(f.read(hlen).decode("utf-8"))
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise FrameCorruptionError(f"{path}: header parse failed") from e
        pre = magic_len + 8 + hlen
        data_start = pre + (-pre) % FRAME_ALIGN
        by_name = {leaf["name"]: leaf for leaf in header["leaves"]}
        written = 0
        total_spans = patch.span_count
        spans_done = 0
        fired_span = False
        fired_mid = False
        for name in patch:
            rec = by_name.get(name)
            if rec is None:
                raise ValueError(f"{path}: frame has no leaf {name!r}")
            rshape = tuple(rec["shape"])
            rows = rshape[0] if rshape else 1
            stride = int(rec["nbytes"]) // rows if rows else 0
            whole = patch.is_whole(name)
            if whole and list(patch.shape_of(name)) != list(rec["shape"]):
                raise ValueError(
                    f"{path}: leaf {name!r} layout mismatch "
                    f"({patch.shape_of(name)} != {tuple(rec['shape'])}); "
                    f"in-place patching never moves the frame layout")
            view = b""
            for sp in patch[name]:
                a = np.asarray(sp.data)
                span_rows = int(a.shape[0]) if a.ndim else 1
                if a.dtype.str != rec["dtype"] or (
                        (sp.start != 0 or list(a.shape) != rec["shape"])
                        and (not rshape or a.ndim == 0
                             or a.shape[1:] != rshape[1:]
                             or sp.start + span_rows > rows)):
                    raise ValueError(
                        f"{path}: leaf {name!r} layout mismatch "
                        f"(rows [{sp.start}, {sp.start + span_rows}) of "
                        f"{a.dtype.str}{a.shape} != "
                        f"{rec['dtype']}{rshape}); in-place "
                        f"patching never moves the frame layout")
                a = a if a.flags.c_contiguous else np.ascontiguousarray(a)
                view = _byte_view(a)
                f.seek(data_start + rec["offset"] + sp.start * stride)
                f.write(view)
                written += int(a.nbytes)
                spans_done += 1
                if hook is not None and not fired_span \
                        and spans_done < total_spans:
                    fired_span = True
                    f.flush()
                    os.fsync(f.fileno())
                    hook("patch:mid_span")
            if whole:
                rec["sha256"] = hashlib.sha256(view).hexdigest()
            else:
                # partially-patched leaf: digest covers patched + retained
                # bytes, so read the leaf's full extent back
                f.flush()
                f.seek(data_start + rec["offset"])
                raw = f.read(int(rec["nbytes"]))
                rec["sha256"] = hashlib.sha256(raw).hexdigest()
            if hook is not None and not fired_mid:
                fired_mid = True
                f.flush()
                os.fsync(f.fileno())
                hook("patch:mid_data")
        # data durable before the header points at it
        f.flush()
        os.fsync(f.fileno())
        hjson = json.dumps(header).encode("utf-8")
        if len(hjson) != hlen:
            # cannot happen for headers this module wrote (fixed-width
            # digests, round-trip-stable json) — refuse rather than
            # shift the data section
            raise ValueError(f"{path}: patched header length diverged "
                             f"({len(hjson)} != {hlen}); frame is not "
                             f"patchable in place")
        if hook is not None:
            hook("patch:pre_header")
        mid = hlen // 2
        f.seek(magic_len + 8)
        f.write(hjson[:mid])
        if hook is not None:
            f.flush()
            os.fsync(f.fileno())
            hook("patch:mid_header")
        f.write(hjson[mid:])
        f.flush()
        os.fsync(f.fileno())
    return written + hlen


def _parse_frame(buf: np.ndarray, *, verify: bool,
                 source: str) -> Tuple[dict, Dict[str, np.ndarray]]:
    """buf: flat uint8 array (np.memmap or np.frombuffer) of the whole
    frame. Returns (header, name -> zero-copy leaf view)."""
    magic_len = len(FRAME_MAGIC)
    if buf.nbytes < magic_len + 8 or bytes(buf[:magic_len]) != FRAME_MAGIC:
        raise FrameCorruptionError(f"{source}: not a frame (bad magic)")
    (hlen,) = _struct.unpack("<Q", bytes(buf[magic_len:magic_len + 8]))
    pre = magic_len + 8 + hlen
    if pre > buf.nbytes:
        raise FrameCorruptionError(f"{source}: truncated header")
    try:
        header = json.loads(bytes(buf[magic_len + 8:pre]).decode("utf-8"))
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise FrameCorruptionError(f"{source}: header parse failed") from e
    data_start = pre + (-pre) % FRAME_ALIGN
    if data_start + header.get("data_bytes", 0) > buf.nbytes:
        raise FrameCorruptionError(f"{source}: truncated data section")
    out: Dict[str, np.ndarray] = {}
    for leaf in header["leaves"]:
        off = data_start + leaf["offset"]
        raw = buf[off:off + leaf["nbytes"]]
        if verify:
            digest = hashlib.sha256(raw).hexdigest()
            if digest != leaf["sha256"]:
                raise FrameCorruptionError(
                    f"{source}: leaf {leaf['name']!r} sha256 mismatch "
                    f"({digest[:12]} != {leaf['sha256'][:12]})")
        out[leaf["name"]] = raw.view(np.dtype(leaf["dtype"])).reshape(
            tuple(leaf["shape"]))
    return header, out


def read_frame(path: str, *, mmap: bool = True,
               verify: bool = False) -> Tuple[dict, Dict[str, np.ndarray]]:
    """Read a frame file. With ``mmap`` (default) the leaves are lazy
    ``np.memmap``-backed views — a reader that replays only part of a
    chain never faults in the rest. ``verify`` recomputes each leaf's
    sha256 (full read) and raises :class:`FrameCorruptionError` on
    mismatch."""
    if mmap:
        buf = np.memmap(path, dtype=np.uint8, mode="r")
    else:
        with open(path, "rb") as f:
            buf = np.frombuffer(f.read(), dtype=np.uint8)
    return _parse_frame(buf, verify=verify, source=path)


def load_frame(path: str, *, mmap: bool = True, verify: bool = False) -> Any:
    """Load a pytree frame written by :func:`save_frame`."""
    header, leaves = read_frame(path, mmap=mmap, verify=verify)
    return unpack(header["struct"], leaves)


def frame_loads(data: bytes, *, verify: bool = False) -> Any:
    """Inverse of :func:`frame_dumps`."""
    buf = np.frombuffer(data, dtype=np.uint8)
    header, leaves = _parse_frame(buf, verify=verify, source="<bytes>")
    return unpack(header["struct"], leaves)


# ----------------------------------------------------------------------
# format sniffing
# ----------------------------------------------------------------------

def is_frame_bytes(data) -> bool:
    return bytes(data[:len(FRAME_MAGIC)]) == FRAME_MAGIC


def is_frame_file(path: str) -> bool:
    with open(path, "rb") as f:
        return f.read(len(FRAME_MAGIC)) == FRAME_MAGIC


def load_any(path: str, *, mmap: bool = True, verify: bool = False) -> Any:
    """Load a checkpoint of either format, sniffing the magic bytes."""
    if is_frame_file(path):
        return load_frame(path, mmap=mmap, verify=verify)
    with np.load(path) as z:
        struct = json.loads(bytes(z["__struct__"]).decode())
        return _unpack(struct, z)


def loads_any(data: bytes, *, verify: bool = False) -> Any:
    """Deserialize a checkpoint byte blob of either format."""
    if is_frame_bytes(data):
        return frame_loads(data, verify=verify)
    return loads(bytes(data))
