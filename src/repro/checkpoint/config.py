"""Declarative store configuration: a store is a typed list of tiers.

``make_store`` / ``make_backend`` grew one keyword per feature (shards,
capacity_mb, remote_url, chunk_mb, eviction, fault rates, ...) until a
three-tier topology was a flag soup. This module replaces the sprawl
with two dataclasses:

* :class:`TierSpec` — one storage tier (``peer`` / ``memory`` /
  ``local`` / ``sharded`` / ``remote``) with only the knobs that tier
  actually has; setting a knob on the wrong kind is a validation error
  that names the offending field.
* :class:`StoreConfig` — the whole store: a hot-to-cold tier list plus
  store-wide policy (format, retention, journal host id).

::

    cfg = StoreConfig(root="/tmp/ck", tiers=[
        TierSpec("peer", replicas=2, hub="cluster"),
        TierSpec("memory", capacity_mb=256, eviction="lru"),
        TierSpec("local"),
    ], retention_fulls=2)
    store = cfg.build()

``to_dict`` / ``from_dict`` round-trip losslessly (config files, CLI
JSON). The legacy factories remain as deprecated shims that delegate
to :meth:`StoreConfig.from_legacy` — old call sites keep working, new
code gets one construction path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

TIER_KINDS = ("peer", "memory", "local", "sharded", "remote")
#: tiers that can anchor a store (own the durable bytes + journal root)
BASE_KINDS = ("local", "sharded", "remote", "memory")


class StoreConfigError(ValueError):
    """Invalid configuration; the message names the offending field."""


#: which TierSpec fields each kind may set (beyond "kind" itself).
#: validation rejects a non-default value on any other field, so a
#: typo like TierSpec("local", capacity_mb=64) fails loudly instead of
#: silently ignoring the knob.
_TIER_FIELDS: Dict[str, Tuple[str, ...]] = {
    "peer": ("replicas", "window", "hub", "node_id", "domain",
             "fault_rate", "max_retries", "latency_s_per_mb",
             "simulate_peers"),
    "memory": ("capacity_mb", "eviction"),
    "local": (),
    "sharded": ("shards",),
    "remote": ("url", "chunk_mb", "max_retries", "fault_rate",
               "capacity_mb", "eviction"),
}


@dataclasses.dataclass
class TierSpec:
    """One tier of the placement hierarchy. Only the fields listed in
    ``_TIER_FIELDS[kind]`` may differ from their defaults."""

    kind: str
    # -- peer tier -----------------------------------------------------
    replicas: int = 2           #: K peer replicas per blob
    window: int = 8             #: bounded in-flight replication sends
    hub: Optional[str] = None   #: loopback hub name (in-process cluster)
    node_id: Optional[str] = None  #: this host's peer id (default: host)
    domain: str = "d0"          #: failure domain of this host
    latency_s_per_mb: float = 0.0  #: simulated link latency (loopback)
    simulate_peers: bool = False  #: auto-register K synthetic peers
    # -- memory tier ---------------------------------------------------
    capacity_mb: Optional[float] = None  #: RAM budget (remote: RAM cache)
    eviction: str = "fifo"      #: victim policy over size-class buckets
    # -- sharded tier --------------------------------------------------
    shards: int = 4
    # -- remote tier ---------------------------------------------------
    url: Optional[str] = None   #: fake://bucket or file:///path
    chunk_mb: float = 4.0
    max_retries: int = 4        #: also the peer tier's send retries
    fault_rate: float = 0.0     #: injected transient-fault probability

    def validate(self, where: str = "tier") -> None:
        if self.kind not in TIER_KINDS:
            raise StoreConfigError(
                f"{where}.kind: {self.kind!r} is not one of {TIER_KINDS}")
        allowed = set(_TIER_FIELDS[self.kind])
        defaults = _TIER_DEFAULTS
        for f in dataclasses.fields(self):
            if f.name == "kind" or f.name in allowed:
                continue
            if getattr(self, f.name) != defaults[f.name]:
                raise StoreConfigError(
                    f"{where}.{f.name}: not a knob of kind="
                    f"{self.kind!r} (valid for {self.kind!r}: "
                    f"{sorted(allowed) or 'none'})")
        if self.kind == "peer" and self.replicas < 0:
            raise StoreConfigError(f"{where}.replicas: must be >= 0")
        if self.kind == "peer" and self.window < 1:
            raise StoreConfigError(f"{where}.window: must be >= 1")
        if self.eviction not in ("fifo", "lru"):
            raise StoreConfigError(
                f"{where}.eviction: {self.eviction!r} is not 'fifo'/'lru'")
        if self.kind == "sharded" and self.shards < 1:
            raise StoreConfigError(f"{where}.shards: must be >= 1")
        if self.capacity_mb is not None and self.capacity_mb <= 0:
            raise StoreConfigError(f"{where}.capacity_mb: must be > 0")
        if not 0.0 <= self.fault_rate <= 1.0:
            raise StoreConfigError(f"{where}.fault_rate: must be in [0,1]")

    def to_dict(self) -> Dict[str, Any]:
        """Only ``kind`` plus fields that differ from the default —
        stable and minimal, so configs diff cleanly."""
        out: Dict[str, Any] = {"kind": self.kind}
        for f in dataclasses.fields(self):
            if f.name == "kind":
                continue
            v = getattr(self, f.name)
            if v != _TIER_DEFAULTS[f.name]:
                out[f.name] = v
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, Any], where: str = "tier") -> "TierSpec":
        d = dict(d)
        kind = d.pop("kind", None)
        if kind is None:
            raise StoreConfigError(f"{where}.kind: missing")
        known = {f.name for f in dataclasses.fields(cls)}
        for k in d:
            if k not in known:
                raise StoreConfigError(f"{where}.{k}: unknown field")
        spec = cls(kind=kind, **d)
        spec.validate(where)
        return spec


_TIER_DEFAULTS = {f.name: f.default for f in dataclasses.fields(TierSpec)}


def _default_tiers() -> List[TierSpec]:
    return [TierSpec("local")]


@dataclasses.dataclass
class StoreConfig:
    """The whole checkpoint store, declaratively: hot-to-cold tiers +
    store-wide policy. ``build()`` is the single construction path."""

    root: Optional[str] = None
    tiers: List[TierSpec] = dataclasses.field(default_factory=_default_tiers)
    fmt: str = "frame"                 #: write serialization (reads sniff)
    retention_fulls: int = 0           #: kept full chains (0 = no GC)
    compact_every: int = 256           #: journal appends per compaction
    host_id: Optional[str] = None      #: per-host journal segments

    # ------------------------------------------------------------------
    def validate(self) -> None:
        from repro.checkpoint.io import FORMATS
        if self.fmt not in FORMATS:
            raise StoreConfigError(
                f"fmt: {self.fmt!r} is not one of {FORMATS}")
        if self.retention_fulls < 0:
            raise StoreConfigError("retention_fulls: must be >= 0")
        if self.compact_every < 1:
            raise StoreConfigError("compact_every: must be >= 1")
        if not self.tiers:
            raise StoreConfigError("tiers: at least one tier is required")
        for i, t in enumerate(self.tiers):
            if not isinstance(t, TierSpec):
                raise StoreConfigError(f"tiers[{i}]: not a TierSpec")
            t.validate(f"tiers[{i}]")
        kinds = [t.kind for t in self.tiers]
        for k in kinds:
            if kinds.count(k) > 1:
                raise StoreConfigError(
                    f"tiers: duplicate kind {k!r} (one tier per kind)")
        base = kinds[-1]
        if base not in BASE_KINDS:
            raise StoreConfigError(
                f"tiers[{len(kinds) - 1}].kind: the last (coldest) tier "
                f"must be one of {BASE_KINDS}, got {base!r}")
        order = {k: i for i, k in enumerate(TIER_KINDS)}
        for i in range(len(kinds) - 1):
            if order[kinds[i]] >= order[kinds[i + 1]]:
                raise StoreConfigError(
                    f"tiers[{i + 1}].kind: tiers must run hot->cold "
                    f"({' > '.join(TIER_KINDS)}); {kinds[i + 1]!r} cannot "
                    f"sit below {kinds[i]!r}")
        needs_root = {"local", "sharded"} & set(kinds)
        if needs_root and self.root is None:
            raise StoreConfigError(
                f"root: required by tier kind(s) {sorted(needs_root)}")
        mem = next((t for t in self.tiers if t.kind == "memory"), None)
        if (mem is not None and mem.capacity_mb is not None
                and self.tiers[-1] is mem):
            raise StoreConfigError(
                "tiers: a capacity-bounded memory tier needs a lower "
                "tier to spill to (add a local/sharded/remote base)")

    # ------------------------------------------------------------------
    def build_backend(self):
        """Compose the backend stack cold-to-hot. Import-local to keep
        config importable without dragging in every backend."""
        from repro.checkpoint.backends import (LocalFSBackend,
                                               MemoryTierBackend,
                                               ShardedBackend)
        self.validate()
        backend = None
        for i in reversed(range(len(self.tiers))):
            spec = self.tiers[i]
            where = f"tiers[{i}]"
            if spec.kind == "local":
                backend = LocalFSBackend(self.root, fmt=self.fmt)
            elif spec.kind == "sharded":
                backend = ShardedBackend(self.root, num_shards=spec.shards,
                                         fmt=self.fmt)
            elif spec.kind == "remote":
                backend = self._build_remote(spec, where)
            elif spec.kind == "memory":
                cap = (int(spec.capacity_mb * 2**20)
                       if spec.capacity_mb else None)
                backend = MemoryTierBackend(backend, capacity_bytes=cap,
                                            eviction=spec.eviction)
            elif spec.kind == "peer":
                backend = self._build_peer(spec, backend, where)
        return backend

    def _build_remote(self, spec: TierSpec, where: str):
        from repro.checkpoint.backends import MemoryTierBackend
        from repro.checkpoint.remote import make_remote_backend
        url = spec.url
        if url is None:
            if self.root is None:
                raise StoreConfigError(
                    f"{where}.url: required when the store has no root "
                    f"(root becomes file://<root> by default)")
            url = f"file://{self.root}"
        lower = make_remote_backend(
            url, chunk_bytes=int(spec.chunk_mb * 2**20),
            max_retries=spec.max_retries, journal_root=self.root,
            fault_rate=spec.fault_rate, fmt=self.fmt)
        # the RAM tier over the remote store absorbs object-store
        # latency off the step loop (same layering make_backend did)
        cap = int(spec.capacity_mb * 2**20) if spec.capacity_mb else None
        return MemoryTierBackend(lower, capacity_bytes=cap,
                                 eviction=spec.eviction)

    def _build_peer(self, spec: TierSpec, lower, where: str):
        from repro.checkpoint.peer import (FaultInjector, LoopbackTransport,
                                           PeerGroup, PeerReplicaBackend,
                                           get_hub)
        if lower is None:
            raise StoreConfigError(
                f"{where}: the peer tier needs a lower tier to wrap")
        hub = get_hub(spec.hub or "default")
        node_id = spec.node_id or self.host_id or "host0"
        hub.ensure(node_id, spec.domain)
        if spec.simulate_peers:
            # single-process simulation: make sure K peers exist, each
            # in its own synthetic failure domain
            others = [p for p in hub.members() if p.node_id != node_id]
            for i in range(len(others), spec.replicas):
                hub.ensure(f"sim{i}", f"simdom{i}")
        faults = (FaultInjector(rate=spec.fault_rate)
                  if spec.fault_rate > 0.0 else None)
        # simulated in-process peers take replicas by reference: a real
        # peer's RAM costs this host no serialization/checksum CPU, so
        # the framed round-trip would charge phantom work to the step
        transport = LoopbackTransport(hub, faults=faults,
                                      latency_s_per_mb=spec.latency_s_per_mb,
                                      zero_copy=spec.simulate_peers)
        group = PeerGroup(node_id, spec.domain, hub=hub)
        return PeerReplicaBackend(lower, transport, group,
                                  replicas=spec.replicas,
                                  window=spec.window,
                                  max_retries=spec.max_retries,
                                  own_transport=True)

    def build(self):
        """Backend stack + chain store + journal: the one construction
        path ``train.py`` / ``serve.py`` / examples / benchmarks use."""
        from repro.checkpoint.store import CheckpointStore
        return CheckpointStore(self.root, backend=self.build_backend(),
                               retention_fulls=self.retention_fulls,
                               compact_every=self.compact_every,
                               host_id=self.host_id)

    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {"root": self.root,
                "tiers": [t.to_dict() for t in self.tiers],
                "fmt": self.fmt, "retention_fulls": self.retention_fulls,
                "compact_every": self.compact_every,
                "host_id": self.host_id}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "StoreConfig":
        d = dict(d)
        tiers_raw = d.pop("tiers", None)
        known = {f.name for f in dataclasses.fields(cls)}
        for k in d:
            if k not in known:
                raise StoreConfigError(f"{k}: unknown field")
        tiers = (_default_tiers() if tiers_raw is None else
                 [TierSpec.from_dict(t, f"tiers[{i}]")
                  for i, t in enumerate(tiers_raw)])
        cfg = cls(tiers=tiers, **d)
        cfg.validate()
        return cfg

    # ------------------------------------------------------------------
    @classmethod
    def from_legacy(cls, root: Optional[str], *, backend: str = "local",
                    shards: int = 4, capacity_mb: Optional[float] = None,
                    retention_fulls: int = 0, compact_every: int = 256,
                    remote_url: Optional[str] = None, chunk_mb: float = 4.0,
                    max_retries: int = 4, remote_fault_rate: float = 0.0,
                    fmt: str = "frame", eviction: str = "fifo",
                    host_id: Optional[str] = None,
                    peers: int = 0, peer_hub: Optional[str] = None,
                    peer_domain: str = "d0", peer_window: int = 8,
                    peer_fault_rate: float = 0.0,
                    simulate_peers: bool = False) -> "StoreConfig":
        """Map the old ``make_store`` keyword surface (plus the peer
        flags) onto a tier list — the one place the legacy backend
        names are interpreted."""
        if backend == "local":
            tiers = [TierSpec("local")]
        elif backend == "sharded":
            tiers = [TierSpec("sharded", shards=shards)]
        elif backend == "memory":
            mem = TierSpec("memory", capacity_mb=capacity_mb,
                           eviction=eviction)
            tiers = [mem, TierSpec("local")] if root is not None else [mem]
        elif backend == "remote":
            tiers = [TierSpec("remote", url=remote_url, chunk_mb=chunk_mb,
                              max_retries=max_retries,
                              fault_rate=remote_fault_rate,
                              capacity_mb=capacity_mb, eviction=eviction)]
        else:
            raise StoreConfigError(
                f"backend: unknown legacy backend {backend!r}")
        if peers > 0:
            tiers.insert(0, TierSpec(
                "peer", replicas=peers, hub=peer_hub, window=peer_window,
                domain=peer_domain, fault_rate=peer_fault_rate,
                node_id=host_id, simulate_peers=simulate_peers))
        cfg = cls(root=root, tiers=tiers, fmt=fmt,
                  retention_fulls=retention_fulls,
                  compact_every=compact_every, host_id=host_id)
        cfg.validate()
        return cfg
