"""Peer-memory replication tier (Checkmate-style).

The paper's differentials are already wire-format blobs, which is the
precondition Checkmate exploits for per-iteration checkpointing with
~zero overhead: instead of persisting every differential to storage,
replicate it into the *memory of K peer hosts* over the network. A
single-host failure then recovers the newest chain from a surviving
peer at network speed; the durable tiers (local NVMe / object store)
only matter for correlated failures. This module is the hot end of the
TierCheck-style placement hierarchy::

    peer memory  ->  CPU-RAM tier  ->  local NVMe / sharded  ->  remote

Pieces:

* wire protocol — framed messages (magic + fixed header + sha256
  trailer) carrying PUT / PATCH / DEL / GET / HAS / CATALOG and
  manifest-record traffic between hosts.
* :class:`PeerNode` — the receiving side: an in-memory replica map plus
  a per-source manifest-record log, with ``kill()`` / ``revive()`` to
  simulate host death in tests and benchmarks.
* :class:`Transport` — how requests reach a node.
  :class:`LoopbackTransport` routes through an in-process
  :class:`PeerHub` (still encoding/decoding the wire format, so the
  framing and checksums are exercised); :class:`SocketTransport` +
  :class:`PeerServer` speak the same protocol over real TCP sockets.
  Both accept a :class:`~repro.checkpoint.remote.FaultInjector` to
  drop or corrupt messages deterministically.
* :class:`PeerReplicaBackend` — a :class:`StorageBackend` that wraps a
  lower tier: every ``put``/``patch``/``delete`` lands locally first
  and is then replicated *asynchronously* to K failure-domain-diverse
  peers through a bounded in-flight window with per-send exp-backoff
  retries and ack tracking. ``get`` falls back to pulling from peers
  when the local blob is gone — which is exactly what recovery on a
  replacement host does. Because it is just a backend, the chain /
  manifest machinery in :class:`~repro.checkpoint.store.
  CheckpointStore` is reused unchanged; the store additionally
  forwards every manifest-journal append through
  ``on_journal_append`` so a replacement host can adopt the dead
  host's manifest from its peers (``CheckpointStore.
  adopt_peer_manifest``).

Replication is *best-effort* by design: a peer that stays unreachable
after bounded retries costs a counter bump, never a training stall —
durability is the lower tier's job, peers buy recovery speed.
"""
from __future__ import annotations

import abc
import dataclasses
import hashlib
import json
import socket
import struct
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import io as cio
from repro.checkpoint.backends import StorageBackend
from repro.checkpoint.patchset import PatchSet
from repro.checkpoint.remote import (ChecksumError, FaultInjector,
                                     RetryExhaustedError,
                                     TransientStoreError)

__all__ = ["LoopbackTransport", "PeerGroup", "PeerHub", "PeerInfo",
           "PeerNode", "PeerProtocolError", "PeerReplicaBackend",
           "PeerServer", "PeerUnreachableError", "SocketTransport",
           "Transport", "decode_message", "encode_message", "get_hub",
           "reset_hub"]


class PeerProtocolError(Exception):
    """Malformed or unexpected peer message (not retried)."""


class PeerUnreachableError(TransientStoreError):
    """The peer did not answer (dead host, refused connection, timeout).
    Subclasses TransientStoreError: retried with backoff like any other
    transient infrastructure fault."""


# ----------------------------------------------------------------------
# wire protocol
# ----------------------------------------------------------------------

MSG_MAGIC = b"RPEER01\n"
#: magic(8) + kind(4) + key_len(u32) + meta_len(u32) + payload_len(u64)
_HDR = struct.Struct(">8s4sIIQ")
_DIGEST_LEN = 32

# request kinds
PUT, PATCH, DEL, GET, HAS = b"PUT_", b"PTCH", b"DEL_", b"GET_", b"HAS_"
CATALOG, MREC, MGET = b"CTLG", b"MREC", b"MGET"
# response kinds
ACK, DATA, MISS, ERR = b"ACK_", b"DATA", b"MISS", b"ERR_"


def encode_message(kind: bytes, key: str, meta: Optional[dict],
                   payload: bytes = b"") -> bytes:
    """One framed message: fixed header, key, JSON meta, raw payload,
    then a sha256 trailer over everything before it — a flipped byte
    anywhere in flight surfaces as :class:`ChecksumError` on decode,
    never as silently corrupt replica bytes."""
    kb = key.encode("utf-8")
    mb = json.dumps(meta or {}).encode("utf-8")
    head = _HDR.pack(MSG_MAGIC, kind, len(kb), len(mb), len(payload))
    h = hashlib.sha256()
    for part in (head, kb, mb, payload):
        h.update(part)
    return b"".join((head, kb, mb, payload, h.digest()))


def decode_message(buf: bytes) -> Tuple[bytes, str, dict, bytes]:
    """Inverse of :func:`encode_message`. Raises
    :class:`PeerProtocolError` on framing damage and
    :class:`ChecksumError` on a digest mismatch (transient: the sender
    retries)."""
    if len(buf) < _HDR.size + _DIGEST_LEN:
        raise PeerProtocolError(f"short peer message ({len(buf)} bytes)")
    magic, kind, klen, mlen, plen = _HDR.unpack_from(buf)
    if magic != MSG_MAGIC:
        raise PeerProtocolError(f"bad peer magic {magic!r}")
    end = _HDR.size + klen + mlen + plen
    if len(buf) != end + _DIGEST_LEN:
        raise PeerProtocolError(
            f"peer message length mismatch ({len(buf)} != "
            f"{end + _DIGEST_LEN})")
    digest = hashlib.sha256(buf[:end]).digest()
    if digest != buf[end:]:
        raise ChecksumError("peer message sha256 mismatch")
    pos = _HDR.size
    key = buf[pos:pos + klen].decode("utf-8")
    pos += klen
    meta = json.loads(buf[pos:pos + mlen].decode("utf-8"))
    pos += mlen
    return kind, key, meta, buf[pos:pos + plen]


# ----------------------------------------------------------------------
# the receiving side
# ----------------------------------------------------------------------

def _blob_nbytes(meta: dict, blob: Any) -> int:
    """Replica size: wire length for framed blobs, the sender-declared
    (or pack-summed) array bytes for zero-copy object trees."""
    if isinstance(blob, (bytes, bytearray, memoryview)):
        return len(blob)
    n = meta.get("nbytes")
    if n:
        return int(n)
    _, arrays = cio.pack(blob)
    return int(sum(np.asarray(a).nbytes for a in arrays))


class PeerNode:
    """One host's replica memory: key -> (meta, frame bytes), plus a
    per-source manifest-record log so a replacement host can adopt a
    dead host's manifest. ``kill()`` simulates host death (requests
    raise :class:`PeerUnreachableError`); ``revive()`` brings the host
    back with its memory intact (a process pause, not a reboot — tests
    use kill-without-revive for real loss)."""

    def __init__(self, node_id: str, domain: str = "d0"):
        self.node_id = node_id
        self.domain = domain
        self.alive = True
        self._lock = threading.Lock()
        self._blobs: Dict[str, Tuple[dict, bytes]] = {}
        #: src host id -> {rseq: manifest record}
        self._records: Dict[str, Dict[int, dict]] = {}
        self.puts = 0
        self.gets = 0
        self.patches = 0
        self.deletes = 0

    # -- lifecycle -----------------------------------------------------
    def kill(self) -> None:
        self.alive = False

    def revive(self) -> None:
        self.alive = True

    # -- request dispatch ---------------------------------------------
    def handle(self, kind: bytes, key: str, meta: dict,
               payload: bytes) -> Tuple[bytes, str, dict, bytes]:
        if not self.alive:
            raise PeerUnreachableError(f"peer {self.node_id} is down")
        if kind == PUT:
            with self._lock:
                self._blobs[key] = (dict(meta), payload)
                self.puts += 1
            return ACK, key, {"node": self.node_id,
                              "nbytes": _blob_nbytes(meta, payload)}, b""
        if kind == PATCH:
            return self._patch(key, meta, payload)
        if kind == DEL:
            with self._lock:
                existed = self._blobs.pop(key, None) is not None
                self.deletes += 1
            return ACK, key, {"node": self.node_id, "existed": existed}, b""
        if kind == GET:
            with self._lock:
                hit = self._blobs.get(key)
                self.gets += 1
            if hit is None:
                return MISS, key, {"node": self.node_id}, b""
            blob = hit[1]
            if (not isinstance(blob, (bytes, bytearray, memoryview))
                    and not meta.get("zc")):
                # object-tree replica served to a framed client: the
                # frame encode happens here, on the serving peer. A
                # zero-copy client ("zc") takes the tree by reference.
                blob = cio.frame_dumps(blob)
            return DATA, key, dict(hit[0]), blob
        if kind == HAS:
            with self._lock:
                has = key in self._blobs
            return ACK, key, {"node": self.node_id, "has": has}, b""
        if kind == CATALOG:
            return DATA, "", {"node": self.node_id}, json.dumps(
                self.catalog()).encode("utf-8")
        if kind == MREC:
            recs = json.loads(payload.decode("utf-8"))
            src = meta.get("src", "?")
            with self._lock:
                log = self._records.setdefault(src, {})
                for rec in recs:
                    log[int(rec["rseq"])] = rec
            return ACK, key, {"node": self.node_id, "count": len(recs)}, b""
        if kind == MGET:
            return DATA, "", {"node": self.node_id}, json.dumps(
                self.records()).encode("utf-8")
        return ERR, key, {"error": f"unknown request kind {kind!r}"}, b""

    def _patch(self, key: str, meta: dict,
               payload: bytes) -> Tuple[bytes, str, dict, bytes]:
        """Apply an in-place partial update to a replica: the payload is
        a :class:`PatchSet` wire tree (or a legacy ``{leaf_name: array}``
        dict/frame) keyed by the base frame's payload names (``a0..aN``,
        pack order) — the same addressing the durable tiers' ``patch``
        uses, so peer replicas track range patches and the background
        fold and stay current."""
        updates = (payload if isinstance(payload, dict)
                   else cio.frame_loads(payload))
        ps = (PatchSet.from_tree(updates) if PatchSet.is_tree(updates)
              else PatchSet.coerce(updates))
        with self._lock:
            hit = self._blobs.get(key)
        if hit is None:
            return MISS, key, {"node": self.node_id}, b""
        old_meta, blob = hit
        as_bytes = isinstance(blob, (bytes, bytearray, memoryview))
        obj = cio.frame_loads(blob) if as_bytes else blob
        tree, arrays = cio.pack(obj)
        for name in ps:
            idx = int(name[1:])  # frame payload names are a<pack index>
            if idx >= len(arrays):
                return ERR, key, {"error": f"patch leaf {name} out of "
                                           f"range for {key}"}, b""
            base = np.asarray(arrays[idx])
            copied = False
            for sp in ps[name]:
                a = np.asarray(sp.data)
                if sp.start == 0 and a.shape == base.shape:
                    base = a     # whole-leaf span: replace by reference
                    continue
                if (base.ndim == 0 or a.ndim == 0 or a.dtype != base.dtype
                        or a.shape[1:] != base.shape[1:]
                        or sp.stop > base.shape[0]):
                    return ERR, key, {
                        "error": f"patch span rows [{sp.start}, {sp.stop}) "
                                 f"of leaf {name} do not fit {key}"}, b""
                if not copied:
                    # replica arrays may be read-only views into the
                    # stored blob — splice into a private copy
                    base = np.array(base)
                    copied = True
                base[sp.start:sp.stop] = a
            arrays[idx] = base
        new_obj = cio.unpack(tree, arrays)
        # a zero-copy replica stays an object tree; a framed one stays
        # bytes — the representation the replica arrived in is kept
        new_blob = cio.frame_dumps(new_obj) if as_bytes else new_obj
        new_meta = dict(old_meta)
        for k in ("state_step",):
            if k in meta:
                new_meta[k] = meta[k]
        with self._lock:
            # only commit if the replica wasn't deleted/replaced while
            # we were re-serializing outside the lock
            if self._blobs.get(key) is hit:
                self._blobs[key] = (new_meta, new_blob)
            self.patches += 1
        return ACK, key, {"node": self.node_id,
                          "nbytes": _blob_nbytes(new_meta, new_blob)}, b""

    # -- introspection -------------------------------------------------
    def catalog(self) -> Dict[str, dict]:
        with self._lock:
            return {k: dict(m) for k, (m, _) in self._blobs.items()}

    def records(self) -> Dict[str, List[dict]]:
        with self._lock:
            return {src: [log[s] for s in sorted(log)]
                    for src, log in self._records.items()}

    def replica_bytes(self) -> int:
        with self._lock:
            return sum(_blob_nbytes(m, b)
                       for m, b in self._blobs.values())

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {"node": self.node_id, "domain": self.domain,
                    "alive": self.alive, "replicas": len(self._blobs),
                    "replica_bytes": sum(_blob_nbytes(m, b) for m, b
                                         in self._blobs.values()),
                    "puts": self.puts, "gets": self.gets,
                    "patches": self.patches, "deletes": self.deletes}


# ----------------------------------------------------------------------
# membership
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PeerInfo:
    node_id: str
    domain: str = "d0"


class PeerHub:
    """In-process peer registry: the loopback analogue of a cluster
    membership service. Tests and single-process simulations register
    :class:`PeerNode` instances here; :class:`LoopbackTransport` routes
    requests through it."""

    def __init__(self, name: str = "default"):
        self.name = name
        self._lock = threading.Lock()
        self._nodes: Dict[str, PeerNode] = {}

    def ensure(self, node_id: str, domain: str = "d0") -> PeerNode:
        with self._lock:
            node = self._nodes.get(node_id)
            if node is None:
                node = PeerNode(node_id, domain)
                self._nodes[node_id] = node
            return node

    def add(self, node: PeerNode) -> PeerNode:
        with self._lock:
            self._nodes[node.node_id] = node
        return node

    def remove(self, node_id: str) -> None:
        with self._lock:
            self._nodes.pop(node_id, None)

    def node(self, node_id: str) -> PeerNode:
        with self._lock:
            node = self._nodes.get(node_id)
        if node is None:
            raise PeerUnreachableError(f"no peer {node_id!r} in hub "
                                       f"{self.name!r}")
        return node

    def members(self) -> List[PeerInfo]:
        with self._lock:
            return sorted((PeerInfo(n.node_id, n.domain)
                           for n in self._nodes.values()),
                          key=lambda p: p.node_id)


#: process-global named hubs (mirrors remote.py's fake:// bucket
#: registry) so declarative configs can share one simulated cluster
_HUBS: Dict[str, PeerHub] = {}
_HUBS_LOCK = threading.Lock()


def get_hub(name: str = "default") -> PeerHub:
    with _HUBS_LOCK:
        hub = _HUBS.get(name)
        if hub is None:
            hub = PeerHub(name)
            _HUBS[name] = hub
        return hub


def reset_hub(name: str = "default") -> None:
    """Drop a named hub (test isolation)."""
    with _HUBS_LOCK:
        _HUBS.pop(name, None)


class PeerGroup:
    """This host's view of the replication group: who the peers are and
    which K of them receive replicas. Membership is read live from the
    hub (or a static list), so peers joining after the store was built
    become eligible without a rebuild."""

    def __init__(self, self_id: str, self_domain: str = "d0", *,
                 hub: Optional[PeerHub] = None,
                 members: Optional[List[PeerInfo]] = None):
        if hub is None and members is None:
            raise ValueError("PeerGroup needs a hub or a members list")
        self.self_id = self_id
        self.self_domain = self_domain
        self._hub = hub
        self._members = list(members or [])

    def members(self) -> List[PeerInfo]:
        if self._hub is not None:
            return self._hub.members()
        return list(self._members)

    def peers(self) -> List[PeerInfo]:
        return [p for p in self.members() if p.node_id != self.self_id]

    def select(self, k: int) -> List[str]:
        """K replication targets, failure-domain-diverse: one peer per
        distinct domain first — domains other than our own before
        peers that share it (a rack-level failure taking us out must
        not take every replica with us) — then round-robin across
        domains to fill. Deterministic (sorted by node id) so every
        call and every test sees the same assignment."""
        if k <= 0:
            return []
        by_domain: Dict[str, List[PeerInfo]] = {}
        for p in self.peers():
            by_domain.setdefault(p.domain, []).append(p)
        for group in by_domain.values():
            group.sort(key=lambda p: p.node_id)
        # our own domain last: it fails with us
        domains = sorted(by_domain, key=lambda d: (d == self.self_domain, d))
        out: List[str] = []
        depth = 0
        while len(out) < k:
            progressed = False
            for d in domains:
                group = by_domain[d]
                if depth < len(group):
                    out.append(group[depth].node_id)
                    progressed = True
                    if len(out) >= k:
                        break
            if not progressed:  # fewer peers than k: best effort
                break
            depth += 1
        return out


# ----------------------------------------------------------------------
# transports
# ----------------------------------------------------------------------

class Transport(abc.ABC):
    """How a framed request reaches a peer and its response returns."""

    #: True when PUT/PATCH payloads may be passed as object trees by
    #: reference instead of frame bytes (in-process simulation only)
    zero_copy = False

    @abc.abstractmethod
    def request(self, peer_id: str, kind: bytes, key: str,
                meta: Optional[dict] = None, payload: bytes = b""
                ) -> Tuple[bytes, str, dict, bytes]:
        """Send one request, return the decoded response. Raises
        :class:`PeerUnreachableError` (dead/absent peer, transient) or
        :class:`ChecksumError` (corrupt frame in flight, transient)."""

    def close(self) -> None:
        pass


class LoopbackTransport(Transport):
    """In-process transport through a :class:`PeerHub`. By default
    requests and responses round-trip through :func:`encode_message` /
    :func:`decode_message`, so the framing, checksums, and fault
    injection behave exactly like the socket path — minus the kernel.

    ``zero_copy=True`` hands payloads to the peer node by reference
    instead: no wire encode, copies, or checksums on either side. That
    is the right model for *simulated* peers sharing this process — a
    real peer's RAM and NIC DMA cost the sending host's CPU nothing,
    and on a small machine the framed simulation would charge all of
    that phantom work to the training step. Fault injection (drops)
    still applies; checksum corruption needs the framed path."""

    def __init__(self, hub: PeerHub, *,
                 faults: Optional[FaultInjector] = None,
                 latency_s_per_mb: float = 0.0,
                 zero_copy: bool = False):
        self.hub = hub
        self.faults = faults
        self.latency_s_per_mb = latency_s_per_mb
        self.zero_copy = zero_copy
        self.requests = 0
        self.bytes_sent = 0

    def request(self, peer_id: str, kind: bytes, key: str,
                meta: Optional[dict] = None, payload: bytes = b""
                ) -> Tuple[bytes, str, dict, bytes]:
        node = self.hub.node(peer_id)
        self.requests += 1
        if self.zero_copy:
            meta = dict(meta or {}, zc=True)
            nbytes = (len(payload) if isinstance(payload, (bytes,
                      bytearray, memoryview)) else _blob_nbytes(meta,
                                                                payload))
            self.bytes_sent += nbytes
            if self.faults is not None:
                self.faults.on_put(f"{peer_id}/{key}")
            if self.latency_s_per_mb > 0.0:
                time.sleep(self.latency_s_per_mb * nbytes / 2**20)
            rk, rkey, rmeta, rp = node.handle(kind, key, meta, payload)
            if self.faults is not None and isinstance(rp, bytes):
                rp = self.faults.on_get(f"{peer_id}/{key}", rp)
            return rk, rkey, rmeta, rp
        wire = encode_message(kind, key, meta, payload)
        self.bytes_sent += len(wire)
        if self.faults is not None:
            self.faults.on_put(f"{peer_id}/{key}")
        if self.latency_s_per_mb > 0.0:
            time.sleep(self.latency_s_per_mb * len(wire) / 2**20)
        resp = encode_message(*node.handle(*decode_message(wire)))
        if self.faults is not None:
            resp = self.faults.on_get(f"{peer_id}/{key}", resp)
        return decode_message(resp)


def _recv_exact(conn: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = conn.recv(min(n - len(buf), 1 << 20))
        if not chunk:
            raise ConnectionError("peer closed connection mid-message")
        buf.extend(chunk)
    return bytes(buf)


_LEN = struct.Struct(">Q")


class PeerServer:
    """TCP server exposing one :class:`PeerNode`: length-prefixed
    framed messages, one response per request, connections held open
    until the client closes. A killed node refuses work by closing the
    connection, which the client sees as unreachable."""

    def __init__(self, node: PeerNode, host: str = "127.0.0.1",
                 port: int = 0):
        self.node = node
        self._sock = socket.create_server((host, port))
        self.address: Tuple[str, int] = self._sock.getsockname()[:2]
        self._stop = threading.Event()
        self._conns: List[socket.socket] = []
        self._lock = threading.Lock()
        self._thread = threading.Thread(target=self._accept_loop,
                                        name=f"peer-srv-{node.node_id}",
                                        daemon=True)
        self._thread.start()

    def _accept_loop(self) -> None:
        self._sock.settimeout(0.2)
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            with self._lock:
                self._conns.append(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                head = _recv_exact(conn, _LEN.size)
                wire = _recv_exact(conn, _LEN.unpack(head)[0])
                try:
                    resp = self.node.handle(*decode_message(wire))
                except PeerUnreachableError:
                    return  # node killed: drop the connection
                except (PeerProtocolError, ChecksumError) as e:
                    resp = (ERR, "", {"error": f"{type(e).__name__}: {e}"},
                            b"")
                out = encode_message(*resp)
                conn.sendall(_LEN.pack(len(out)) + out)
        except (ConnectionError, OSError):
            return
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def close(self) -> None:
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass
        with self._lock:
            conns, self._conns = self._conns, []
        for c in conns:
            try:
                c.close()
            except OSError:
                pass
        self._thread.join(timeout=2.0)


class SocketTransport(Transport):
    """Real-socket transport: one short-lived TCP connection per
    request (simple and stateless; replication traffic is a few
    messages per training step, not RPC-benchmark QPS). Any socket
    error — refused, reset, timeout — maps to
    :class:`PeerUnreachableError` so the caller's retry/backoff logic
    treats network and dead-host identically."""

    def __init__(self, addresses: Dict[str, Tuple[str, int]], *,
                 timeout_s: float = 5.0,
                 faults: Optional[FaultInjector] = None):
        self.addresses = dict(addresses)
        self.timeout_s = timeout_s
        self.faults = faults
        self.requests = 0
        self.bytes_sent = 0

    def request(self, peer_id: str, kind: bytes, key: str,
                meta: Optional[dict] = None, payload: bytes = b""
                ) -> Tuple[bytes, str, dict, bytes]:
        addr = self.addresses.get(peer_id)
        if addr is None:
            raise PeerUnreachableError(f"no address for peer {peer_id!r}")
        wire = encode_message(kind, key, meta, payload)
        self.requests += 1
        self.bytes_sent += len(wire)
        if self.faults is not None:
            self.faults.on_put(f"{peer_id}/{key}")
        try:
            with socket.create_connection(
                    tuple(addr), timeout=self.timeout_s) as conn:
                conn.sendall(_LEN.pack(len(wire)) + wire)
                head = _recv_exact(conn, _LEN.size)
                resp = _recv_exact(conn, _LEN.unpack(head)[0])
        except (ConnectionError, socket.timeout, OSError) as e:
            raise PeerUnreachableError(
                f"peer {peer_id} at {addr} unreachable: {e}") from e
        if self.faults is not None:
            resp = self.faults.on_get(f"{peer_id}/{key}", resp)
        return decode_message(resp)


# ----------------------------------------------------------------------
# the backend
# ----------------------------------------------------------------------

def _kind_of_key(key: str) -> str:
    for prefix, kind in (("full_", "fulls"), ("diff_", "diffs"),
                         ("batch_", "batches"), ("patch_", "patches")):
        if key.startswith(prefix):
            return kind
    return "other"


def _once(fn):
    """Thread-safe memoized thunk: K replication workers share one
    deferred wire encoding instead of serializing K times."""
    lock = threading.Lock()
    cell: list = []

    def call():
        with lock:
            if not cell:
                cell.append(fn())
            return cell[0]

    return call


class PeerReplicaBackend(StorageBackend):
    """Replicate every blob to K failure-domain-diverse peers' memory,
    asynchronously, on top of a lower (durable-ish) tier.

    Write path: ``put``/``patch``/``delete`` complete against ``lower``
    first — the caller's durability contract is the lower tier's,
    unchanged — then the wire-format bytes are handed to a bounded
    in-flight window (``window`` concurrent sends; acquiring a slot
    blocks, which is the backpressure that keeps a slow peer from
    ballooning memory). Each send retries with exponential backoff on
    transient faults (unreachable peer, checksum flip in flight);
    exhausted retries bump ``replication_failures`` and move on —
    peers buy recovery speed, the lower tier owns durability.

    Read path: ``lower`` first; on a miss the blob is pulled from the
    peers (replication targets first, then any group member) — the
    replacement-host recovery path.

    Ack tracking: per-key set of peers that acknowledged the PUT.
    ``unreplicated_keys()`` is the loss window a host failure at this
    instant would expose (benchmarked by exp15).
    """

    name = "peer"

    def __init__(self, lower: StorageBackend, transport: Transport,
                 group: PeerGroup, *, replicas: int = 2, window: int = 8,
                 max_retries: int = 3, backoff_s: float = 0.01,
                 backoff_max_s: float = 0.5,
                 own_transport: bool = False):
        if replicas < 0:
            raise ValueError("replicas must be >= 0")
        self.lower = lower
        self.transport = transport
        self.group = group
        self.replicas = replicas
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.backoff_max_s = backoff_max_s
        self.persist_root = lower.persist_root
        self.fmt = lower.fmt
        self.src = group.self_id
        self._own_transport = own_transport
        self._lock = threading.Lock()
        self._window = threading.BoundedSemaphore(max(1, window))
        self._pool = ThreadPoolExecutor(max_workers=max(1, window),
                                        thread_name_prefix="peer-rep")
        self._inflight: set = set()
        self._acks: Dict[str, set] = {}
        self._rseq = 0
        from repro.obs.metrics import InstrumentSet
        self._inst = InstrumentSet("peer")
        #: stats() counter keys, synced by tests/test_observability.py
        self.KEYS = ("replicated", "acks_total", "replication_failures",
                     "patch_misses", "peer_reads", "retries",
                     "record_sends")
        for k in self.KEYS:
            self._inst.counter(k)
        self.last_error: Optional[str] = None

    def __getattr__(self, name):
        # legacy attribute surface: self.replicated etc. read counters
        if name != "KEYS" and name in getattr(self, "KEYS", ()):
            return int(self._inst.get(name).value)
        raise AttributeError(name)

    def instruments(self):
        """The backing :class:`~repro.obs.metrics.InstrumentSet`."""
        return self._inst

    def _count(self, attr: str, n: int = 1):
        self._inst.counter(attr).add(n)

    # -- provenance ----------------------------------------------------
    @property
    def provenance(self) -> str:
        """Manifest-entry tier tag: the *lower* tier's provenance — a
        put acked here is exactly as durable as the tier below (peer
        replication adds availability, not durability)."""
        return getattr(self.lower, "provenance", self.lower.name)

    # -- replication machinery ----------------------------------------
    def _targets(self) -> List[str]:
        return self.group.select(self.replicas)

    def _send_with_retries(self, peer_id: str, kind: bytes, key: str,
                           meta: dict, payload: bytes
                           ) -> Tuple[bytes, dict, bytes]:
        delay = self.backoff_s
        last: Optional[Exception] = None
        for attempt in range(self.max_retries + 1):
            try:
                rk, _, rmeta, rp = self.transport.request(
                    peer_id, kind, key, meta, payload)
                if rk == ERR:
                    raise PeerProtocolError(rmeta.get("error", "peer error"))
                return rk, rmeta, rp
            except TransientStoreError as e:  # incl. unreachable/checksum
                last = e
                if attempt < self.max_retries:
                    with self._lock:
                        self._count("retries")
                    time.sleep(delay)
                    delay = min(delay * 2, self.backoff_max_s)
        raise RetryExhaustedError(
            f"peer {peer_id} {kind!r} {key!r} failed after "
            f"{self.max_retries + 1} attempts: {last}")

    def _note_response(self, peer_id: str, kind: bytes, key: str,
                       rk: bytes) -> None:
        with self._lock:
            if kind == PUT and rk == ACK:
                self._acks.setdefault(key, set()).add(peer_id)
                self._count("acks_total")
            elif kind == PATCH and rk == MISS:
                self._count("patch_misses")

    def _note_failure(self, e: Exception) -> None:
        with self._lock:
            self._count("replication_failures")
            self.last_error = repr(e)

    def _replicate_one(self, peer_id: str, kind: bytes, key: str,
                       meta: dict, payload) -> None:
        try:
            if callable(payload):     # deferred wire encoding (see put)
                payload = payload()
            rk, _, _ = self._send_with_retries(peer_id, kind, key, meta,
                                               payload)
        except Exception as e:  # noqa: BLE001 - best-effort by contract
            self._note_failure(e)
            return
        self._note_response(peer_id, kind, key, rk)

    def _send_inline(self, peers: List[str], kind: bytes, key: str,
                     meta: dict, payload) -> List[str]:
        """First-attempt sends on the caller thread (zero-copy
        transports only — the send is a dict insert, cheaper than a
        worker handoff). Peers that fail transiently are returned for
        the async worker, so retry backoff never blocks the step."""
        retry: List[str] = []
        for peer_id in peers:
            try:
                rk, _, rmeta, _ = self.transport.request(
                    peer_id, kind, key, meta, payload)
                if rk == ERR:
                    raise PeerProtocolError(
                        rmeta.get("error", "peer error"))
            except TransientStoreError:
                retry.append(peer_id)
                continue
            except Exception as e:  # noqa: BLE001 - best-effort
                self._note_failure(e)
                continue
            self._note_response(peer_id, kind, key, rk)
        return retry

    def _replicate_fanout(self, peers: List[str], kind: bytes, key: str,
                          meta: dict, payload) -> None:
        from repro.obs.trace import trace_span
        with trace_span("peer.fanout", "peer", key=key,
                        peers=len(peers)):
            if callable(payload):     # deferred wire encoding (see put)
                payload = payload()
            for peer_id in peers:
                self._replicate_one(peer_id, kind, key, meta, payload)

    def _replicate_async(self, kind: bytes, key: str, meta: dict,
                         payload,
                         targets: Optional[List[str]] = None) -> None:
        # one task fans a key out to all K peers: a single dispatch on
        # the step path, K sequential sends on the worker
        peers = self._targets() if targets is None else targets
        if peers and self.transport.zero_copy:
            peers = self._send_inline(peers, kind, key, meta, payload)
        if not peers:
            return
        self._window.acquire()  # bounded in-flight: backpressure
        try:
            fut: Future = self._pool.submit(
                self._replicate_fanout, peers, kind, key, meta, payload)
        except RuntimeError:     # pool shut down mid-close
            self._window.release()
            return
        with self._lock:
            self._inflight.add(fut)

        def _done(f: Future, _self=self) -> None:
            _self._window.release()
            with _self._lock:
                _self._inflight.discard(f)

        fut.add_done_callback(_done)

    # -- StorageBackend ------------------------------------------------
    def put(self, key: str, obj: Any) -> int:
        n = self.lower.put(key, obj)
        if self.replicas > 0:
            meta = {"src": self.src, "kind": _kind_of_key(key),
                    "nbytes": n}
            # a zero-copy transport takes the object by reference; the
            # framed path defers the wire encoding to the replication
            # worker, memoized across the K sends — either way put()
            # returns after the durable write without paying a
            # serialization on the step path. Safe because the store
            # hands the backend snapshot arrays that are never mutated
            # in place afterwards.
            self._replicate_async(PUT, key, meta,
                                  obj if self.transport.zero_copy
                                  else _once(lambda: cio.frame_dumps(obj)))
            with self._lock:
                self._count("replicated")
        return n

    def get(self, key: str) -> Any:
        try:
            return self.lower.get(key)
        except FileNotFoundError:
            pass
        targets = self._targets()
        candidates = targets + [p.node_id for p in self.group.peers()
                                if p.node_id not in targets]
        for peer_id in candidates:
            try:
                rk, _, rp = self._send_with_retries(peer_id, GET, key,
                                                    {"src": self.src}, b"")
            except (RetryExhaustedError, PeerProtocolError):
                continue
            if rk == DATA:
                with self._lock:
                    self._count("peer_reads")
                if not isinstance(rp, (bytes, bytearray, memoryview)):
                    return rp        # zero-copy object tree by reference
                return cio.loads_any(rp)
        raise FileNotFoundError(
            f"no blob {key!r} in the lower tier or on "
            f"{len(candidates)} peers")

    def patch(self, key: str, patch: PatchSet) -> int:
        ps = PatchSet.coerce(patch)
        n = self.lower.patch(key, ps)
        if self.replicas > 0:
            # range PATCH on the wire: the PatchSet's serializable tree
            # — a zero-copy transport takes the span arrays by
            # reference, the framed path encodes once across the K sends
            tree = ps.to_tree()
            payload = (tree if self.transport.zero_copy
                       else _once(lambda: cio.frame_dumps(tree)))
            self._replicate_async(PATCH, key, {"src": self.src}, payload)
        return n

    def delete(self, key: str) -> None:
        self.lower.delete(key)
        with self._lock:
            self._acks.pop(key, None)
        if self.replicas > 0:
            self._replicate_async(DEL, key, {"src": self.src}, b"")

    def exists(self, key: str) -> bool:
        if self.lower.exists(key):
            return True
        for peer_id in self._targets():
            try:
                rk, rmeta, _ = self._send_with_retries(
                    peer_id, HAS, key, {"src": self.src}, b"")
            except (RetryExhaustedError, PeerProtocolError):
                continue
            if rk == ACK and rmeta.get("has"):
                return True
        return False

    def keys(self) -> List[str]:
        out = set(self.lower.keys())
        out.update(self.peer_catalog())
        return sorted(out)

    def url(self, key: str) -> str:
        return self.lower.url(key)

    def protect(self, keys) -> None:
        self.lower.protect(keys)

    def verify(self, key: str) -> Optional[str]:
        return self.lower.verify(key)

    def sweep_orphans(self, min_age_s: float = 60.0) -> int:
        return self.lower.sweep_orphans(min_age_s)

    # -- manifest replication -----------------------------------------
    def on_journal_append(self, op: str, kind: str, *,
                          entry: Optional[dict] = None,
                          key: Optional[str] = None) -> None:
        """Called by the store's journal tap after every local manifest
        append: forward the record (tiny JSON, async, same window) to
        the replication targets so a surviving peer can reconstruct
        this host's manifest after it dies."""
        if self.replicas <= 0:
            return
        with self._lock:
            self._rseq += 1
            rec = {"rseq": self._rseq, "op": op, "kind": kind}
        if entry is not None:
            rec["entry"] = entry
        if key is not None:
            rec["key"] = key
        payload = json.dumps([rec]).encode("utf-8")
        self._replicate_async(MREC, "", {"src": self.src}, payload)
        with self._lock:
            self._count("record_sends")

    def peer_catalog(self) -> Dict[str, dict]:
        """Union of every reachable peer's replica map (key -> meta)."""
        out: Dict[str, dict] = {}
        for peer in self.group.peers():
            try:
                rk, _, rp = self._send_with_retries(
                    peer.node_id, CATALOG, "", {"src": self.src}, b"")
            except (RetryExhaustedError, PeerProtocolError):
                continue
            if rk != DATA:
                continue
            for k, m in json.loads(rp.decode("utf-8")).items():
                out.setdefault(k, m)
        return out

    def peer_manifest(self, src: Optional[str] = None
                      ) -> List[Tuple[str, int, dict]]:
        """Merged manifest records held by the peers, as ordered
        ``(src_host, rseq, record)`` tuples. Records are deduped by
        ``(src, rseq)`` across peers — two peers holding overlapping
        prefixes of the same host's journal merge to one stream. Pass
        ``src`` to restrict to one dead host's records."""
        merged: Dict[Tuple[str, int], dict] = {}
        for peer in self.group.peers():
            try:
                rk, _, rp = self._send_with_retries(
                    peer.node_id, MGET, "", {"src": self.src}, b"")
            except (RetryExhaustedError, PeerProtocolError):
                continue
            if rk != DATA:
                continue
            for rsrc, recs in json.loads(rp.decode("utf-8")).items():
                if src is not None and rsrc != src:
                    continue
                for rec in recs:
                    merged.setdefault((rsrc, int(rec["rseq"])), rec)
        return [(s, q, merged[(s, q)]) for s, q in sorted(merged)]

    def prune_replicas(self, keep_keys) -> int:
        """Delete this host's replicas on every peer for keys no longer
        in the live manifest (folded patches, GC'd chains). Best-effort
        and idempotent — the maintenance service calls it after fold /
        GC completions. Returns replicas removed."""
        keep = set(keep_keys)
        removed = 0
        for peer in self.group.peers():
            try:
                rk, _, rp = self._send_with_retries(
                    peer.node_id, CATALOG, "", {"src": self.src}, b"")
            except (RetryExhaustedError, PeerProtocolError):
                continue
            if rk != DATA:
                continue
            for key, meta in json.loads(rp.decode("utf-8")).items():
                if meta.get("src") != self.src or key in keep:
                    continue
                try:
                    ak, ameta, _ = self._send_with_retries(
                        peer.node_id, DEL, key, {"src": self.src}, b"")
                except (RetryExhaustedError, PeerProtocolError):
                    continue
                if ak == ACK and ameta.get("existed"):
                    removed += 1
        return removed

    # -- ack introspection --------------------------------------------
    def ack_count(self, key: str) -> int:
        with self._lock:
            return len(self._acks.get(key, ()))

    def unreplicated_keys(self, min_acks: int = 1) -> List[str]:
        """Keys whose PUT has fewer than ``min_acks`` peer acks right
        now — the loss window a host death at this instant would leave
        for peers to cover (the durable tier still has them)."""
        with self._lock:
            acked = dict(self._acks)
        live = set(self.lower.keys())
        return sorted(k for k in live
                      if len(acked.get(k, ())) < min_acks)

    # -- lifecycle -----------------------------------------------------
    def flush(self) -> None:
        """Wait for the lower tier's durability AND every in-flight
        replication send (success or counted failure)."""
        while True:
            with self._lock:
                pending = list(self._inflight)
            if not pending:
                break
            for fut in pending:
                try:
                    fut.result(timeout=30.0)
                except Exception:  # noqa: BLE001 - counted in _replicate_one
                    pass
        self.lower.flush()

    def close(self) -> None:
        self.flush()
        self._pool.shutdown(wait=True)
        self.lower.close()
        if self._own_transport:
            self.transport.close()

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            acked_keys = sum(1 for s in self._acks.values() if s)
            out = {"backend": self.name, "replicas": self.replicas,
                   "targets": self._targets(),
                   "replicated": self.replicated,
                   "acks_total": self.acks_total,
                   "acked_keys": acked_keys,
                   "replication_failures": self.replication_failures,
                   "patch_misses": self.patch_misses,
                   "peer_reads": self.peer_reads,
                   "retries": self.retries,
                   "record_sends": self.record_sends,
                   "last_error": self.last_error}
        out["lower"] = self.lower.stats()
        return out
