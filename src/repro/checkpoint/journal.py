"""Append-only manifest journal with periodic compaction.

The seed store rewrote the whole ``manifest.json`` on every record —
O(n) bytes per write, O(n²) over a training run, exactly the failure
mode per-iteration checkpointing provokes. This journal appends one
JSON line per mutation (O(1) bytes per write) and periodically folds
the log into an atomic snapshot.

On-disk layout::

    <root>/manifest.json   # snapshot {"fulls": [...], ..., "__seq__": n}
    <root>/manifest.log    # JSON lines appended after the snapshot

Records::

    {"seq": 7, "op": "add", "kind": "fulls",  "entry": {...}}
    {"seq": 8, "op": "del", "kind": "batches", "key": "batch_..."}
    {"seq": 9, "op": "replace", "kind": "fulls", "key": "full_...",
     "entry": {...}}   # atomic del+add (entry rewrites, e.g. the fold)

Recovery reads the snapshot, then replays log records with
``seq > snapshot.__seq__``. A torn tail (partial last line from a
crash mid-append) is detected by the JSON parse failing and the valid
prefix is kept — recovery always sees a consistent chain prefix.

Multi-controller jobs use :class:`SegmentedManifestJournal`: each host
appends to its *own* segment file (``manifest.<host>.log``) so per-host
shard writers never serialize on one journal writer, and every reader
reconstructs the same merged view deterministically (records are
totally ordered by ``(seq, host)``). The merge/compaction step folds
all segments into the shared snapshot, whose ``__segseq__`` map carries
one watermark per host.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Tuple

from repro.checkpoint import io as cio

EMPTY = {"fulls": [], "diffs": [], "batches": []}


def _read_snapshot(root: str) -> Tuple[Dict[str, List[dict]], int,
                                       Dict[str, int]]:
    """Read ``manifest.json`` into ``(manifest, legacy_seq, segment
    watermarks)``. The snapshot carries both watermark styles so a job
    can switch between the single journal and per-host segments in
    either direction without losing unfolded records."""
    manifest = _blank()
    seq = 0
    marks: Dict[str, int] = {}
    path = os.path.join(root, ManifestJournal.SNAPSHOT)
    if os.path.exists(path):
        with open(path, encoding="utf-8") as f:
            snap = json.load(f)
        seq = int(snap.pop("__seq__", 0))
        marks = {h: int(s) for h, s in snap.pop("__segseq__", {}).items()}
        # iterate the *snapshot's* kinds, not just the builtin three:
        # extra kinds (e.g. the scrubber's "quarantined" list) must
        # survive a compaction + reload round-trip
        for k, v in snap.items():
            manifest[k] = list(v)
    return manifest, seq, marks


def _fold_legacy_log(manifest: Dict[str, List[dict]], root: str,
                     floor: int) -> Tuple[int, int, int]:
    """Apply single-journal ``manifest.log`` records above ``floor``.
    Returns (top seq, valid bytes, total bytes)."""
    records, valid, total = read_segment(
        os.path.join(root, ManifestJournal.LOG))
    top = floor
    for rec in records:
        if rec.get("seq", 0) <= floor:
            continue  # already folded into the snapshot
        _apply(manifest, rec["op"], rec["kind"], rec.get("entry"),
               rec.get("key"))
        top = rec["seq"]
    return top, valid, total


def _fold_segments(manifest: Dict[str, List[dict]], root: str,
                   marks: Dict[str, int]
                   ) -> Tuple[Dict[str, int], Dict[str, Tuple[int, int]]]:
    """Apply every per-host segment's records above its watermark, in
    deterministic ``(seq, host)`` order. Returns (new watermarks,
    per-host byte spans)."""
    merged, marks, spans = merge_segment_records(root, marks)
    for rec in merged:
        _apply(manifest, rec["op"], rec["kind"], rec.get("entry"),
               rec.get("key"))
    return marks, spans


def _blank() -> Dict[str, List[dict]]:
    return {k: [] for k in EMPTY}


class MemoryJournal:
    """Journal interface for backends with no durable root (pure
    CPU-memory tier): the manifest lives only in this process."""

    def __init__(self):
        self.manifest = _blank()
        self.appends = 0

    def append(self, op: str, kind: str, *, entry: Optional[dict] = None,
               key: Optional[str] = None) -> int:
        _apply(self.manifest, op, kind, entry, key)
        self.appends += 1
        return 0  # no bytes hit storage

    def compact(self):
        pass

    def close(self):
        pass

    def stats(self):
        return {"appends": self.appends, "log_bytes": 0, "compactions": 0}


class ManifestJournal:
    SNAPSHOT = "manifest.json"
    LOG = "manifest.log"

    def __init__(self, root: str, compact_every: int = 256):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.compact_every = compact_every
        self.compactions = 0
        self.appends = 0
        self._seq = 0
        self._segseq: Dict[str, int] = {}
        self._since_compact = 0
        self.manifest = self._load()
        self._log = open(self._log_path(), "a", encoding="utf-8")

    # ------------------------------------------------------------------
    def _snap_path(self) -> str:
        return os.path.join(self.root, self.SNAPSHOT)

    def _log_path(self) -> str:
        return os.path.join(self.root, self.LOG)

    def _load(self) -> Dict[str, List[dict]]:
        manifest, self._seq, self._segseq = _read_snapshot(self.root)
        # fold journal segments left by a segmented-era run first (they
        # predate the switch back to the single journal): a mode switch
        # must never lose records above the per-host watermarks
        self._segseq, _ = _fold_segments(manifest, self.root, self._segseq)
        self._seq, valid_bytes, total = _fold_legacy_log(
            manifest, self.root, self._seq)
        if valid_bytes < total:
            # drop the torn fragment so the next append starts a
            # fresh line instead of merging into it (which would
            # poison every later record on the following reload)
            with open(self._log_path(), "r+b") as f:
                f.truncate(valid_bytes)
        return manifest

    # ------------------------------------------------------------------
    def append(self, op: str, kind: str, *, entry: Optional[dict] = None,
               key: Optional[str] = None) -> int:
        """Apply a mutation and append one JSON line. Returns the number
        of journal bytes written — O(entry), independent of history."""
        _apply(self.manifest, op, kind, entry, key)
        self._seq += 1
        rec = {"seq": self._seq, "op": op, "kind": kind}
        if entry is not None:
            rec["entry"] = entry
        if key is not None:
            rec["key"] = key
        line = json.dumps(rec) + "\n"
        self._log.write(line)
        self._log.flush()
        self.appends += 1
        self._since_compact += 1
        if self._since_compact >= self.compact_every:
            self.compact()
        return len(line)

    def compact(self):
        """Fold the log into an atomic snapshot and truncate it."""
        snap = dict(self.manifest)
        snap["__seq__"] = self._seq
        if self._segseq:
            # carry the segmented-era watermarks forward so those
            # segment records are never re-applied by a later reader
            snap["__segseq__"] = self._segseq
        # shared tmp+fsync+rename+dir-fsync implementation: the rename
        # must be durable before the log is truncated, or a crash could
        # lose both the snapshot and the folded records
        cio.atomic_write(self._snap_path(),
                         lambda f: f.write(json.dumps(snap).encode("utf-8")))
        # Snapshot is durable; a crash before the truncate just replays
        # records whose seq <= __seq__, which _load skips.
        self._log.close()
        self._log = open(self._log_path(), "w", encoding="utf-8")
        self._since_compact = 0
        self.compactions += 1

    def close(self):
        if not self._log.closed:
            self._log.close()

    def log_bytes(self) -> int:
        try:
            return os.path.getsize(self._log_path())
        except OSError:
            return 0

    def stats(self):
        return {"appends": self.appends, "log_bytes": self.log_bytes(),
                "compactions": self.compactions}


def read_segment(path: str) -> Tuple[List[dict], int, int]:
    """Read a journal log file, tolerating a torn tail (partial last
    line from a crash mid-append). Returns ``(records, valid_bytes,
    total_bytes)`` — the valid record prefix and how many bytes of the
    file it spans, so callers that own the file can truncate the torn
    fragment."""
    records: List[dict] = []
    valid = 0
    try:
        total = os.path.getsize(path)
    except OSError:
        return records, 0, 0
    with open(path, "rb") as f:
        for raw in f:
            if not raw.endswith(b"\n"):
                break  # newline missing: the append was torn
            try:
                records.append(json.loads(raw.decode("utf-8")))
            except (json.JSONDecodeError, UnicodeDecodeError):
                break  # torn tail: keep the valid prefix
            valid += len(raw)
    return records, valid, total


# ----------------------------------------------------------------------
# multi-controller journal segments
# ----------------------------------------------------------------------

class JournalSegment:
    """One host's append-only journal segment (``manifest.<host>.log``).

    The segment is single-writer: only its host appends, so there is no
    cross-host lock on the append path. Records carry ``(seq, host)``;
    the merged view orders them by that pair, which is deterministic no
    matter when each segment is read."""

    def __init__(self, root: str, host: str):
        if "/" in host or host.startswith("."):
            raise ValueError(f"invalid journal host id {host!r}")
        self.root = root
        self.host = host
        self.path = segment_path(root, host)
        os.makedirs(root, exist_ok=True)
        # lazily opened: a read-only recovery session must not litter
        # the root with empty segment files for its transient host id
        self._f = None

    def append_record(self, rec: dict) -> int:
        if self._f is None:
            self._f = open(self.path, "a", encoding="utf-8")
        line = json.dumps(rec) + "\n"
        self._f.write(line)
        self._f.flush()
        return len(line)

    def truncate(self):
        """Reset the segment after its records were folded into the
        snapshot. Only the owning host may call this (sole writer)."""
        if self._f is not None:
            self._f.close()
        self._f = open(self.path, "w", encoding="utf-8")

    def close(self):
        if self._f is not None and not self._f.closed:
            self._f.close()


def segment_path(root: str, host: str) -> str:
    return os.path.join(root, f"manifest.{host}.log")


def list_segment_hosts(root: str) -> List[str]:
    """Hosts with a segment file under root, sorted."""
    hosts = []
    try:
        names = os.listdir(root)
    except OSError:
        return hosts
    for f in names:
        if f.startswith("manifest.") and f.endswith(".log"):
            host = f[len("manifest."):-len(".log")]
            if host:  # skip the single-journal "manifest.log" itself
                hosts.append(host)
    return sorted(hosts)


def merge_segment_records(root: str, watermarks: Dict[str, int]
                          ) -> Tuple[List[dict], Dict[str, int],
                                     Dict[str, Tuple[int, int]]]:
    """Read every segment under root and return the deterministic merge:
    ``(records sorted by (seq, host), new per-host watermarks, per-host
    (valid_bytes, total_bytes))``. Records at or below a host's existing
    watermark are skipped (already folded into the snapshot), so the
    merge is idempotent — a crash between the snapshot write and the
    segment truncation just re-skips them on the next load."""
    merged: List[dict] = []
    marks = dict(watermarks)
    spans: Dict[str, Tuple[int, int]] = {}
    for host in list_segment_hosts(root):
        records, valid, total = read_segment(segment_path(root, host))
        spans[host] = (valid, total)
        floor = marks.get(host, 0)
        top = floor
        for rec in records:
            seq = int(rec.get("seq", 0))
            if seq <= floor:
                continue
            rec.setdefault("host", host)
            merged.append(rec)
            top = max(top, seq)
        marks[host] = top
    merged.sort(key=lambda r: (r.get("seq", 0), r.get("host", "")))
    return merged, marks, spans


class SegmentedManifestJournal:
    """Per-host manifest journal for multi-controller jobs.

    Appends go to this host's own :class:`JournalSegment` — no
    serialization on a shared writer. Loading builds the *merged* view:
    shared snapshot (``manifest.json`` with a ``__segseq__`` per-host
    watermark map) plus every segment's records above its watermark,
    applied in ``(seq, host)`` order — deterministic, so any reader
    (including single-host recovery after a multi-controller run)
    reconstructs bit-identical manifest state.

    ``compact()`` is the merge step: fold the legacy log and all
    segments into an atomic snapshot, then truncate this host's own
    segment only (sole writer — safe; another host's segment is *never*
    touched, its folded records are simply skipped by the watermark on
    every future load). Cross-host merges are serialized by a
    best-effort lock file with stale-lock breaking, so two hosts
    compacting concurrently cannot clobber each other's folds; on
    contention the merge is skipped and retried a window later. A crash
    between the snapshot write and the truncation loses nothing: folded
    records sit at or below their host's watermark.
    """

    SNAPSHOT = ManifestJournal.SNAPSHOT
    MERGE_LOCK = "manifest.merge.lock"

    def __init__(self, root: str, host: str = "h0",
                 compact_every: int = 256):
        self.root = root
        self.host = host
        os.makedirs(root, exist_ok=True)
        self.compact_every = compact_every
        self.compactions = 0
        self.merge_contentions = 0
        self.appends = 0
        self._since_compact = 0
        self._watermarks: Dict[str, int] = {}
        self._legacy_seq = 0
        #: test hook: called at named points inside compact() to inject
        #: crashes at merge boundaries (see tests/test_maintenance.py)
        self._crash_hook = None
        self.manifest = self._load()
        self._segment = JournalSegment(root, host)
        self._seq = self._watermarks.get(host, 0)

    # ------------------------------------------------------------------
    def _snap_path(self) -> str:
        return os.path.join(self.root, self.SNAPSHOT)

    def _load(self) -> Dict[str, List[dict]]:
        manifest, legacy_floor, self._watermarks = _read_snapshot(self.root)
        # fold single-journal records left by a pre-segmented run first
        # (they predate the switch): enabling --host-id on an existing
        # store must not lose records not yet folded into the snapshot
        self._legacy_seq, _, _ = _fold_legacy_log(manifest, self.root,
                                                  legacy_floor)
        self._watermarks, spans = _fold_segments(manifest, self.root,
                                                 self._watermarks)
        # truncate only our OWN torn tail — other hosts may be mid-append
        own = spans.get(self.host)
        if own is not None and own[0] < own[1]:
            with open(segment_path(self.root, self.host), "r+b") as f:
                f.truncate(own[0])
        return manifest

    # ------------------------------------------------------------------
    def _acquire_merge_lock(self, stale_s: float = 120.0) -> bool:
        """Best-effort cross-process merge mutex: O_CREAT|O_EXCL lock
        file, broken when older than ``stale_s`` (a merger that died
        mid-merge). Returns False on live contention — the caller skips
        this merge and retries a compaction window later."""
        path = os.path.join(self.root, self.MERGE_LOCK)
        for attempt in (0, 1):
            try:
                fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                os.close(fd)
                return True
            except FileExistsError:
                try:
                    stale = time.time() - os.path.getmtime(path) > stale_s
                except OSError:
                    continue  # lock vanished under us: retry once
                if stale and attempt == 0:
                    try:
                        os.unlink(path)
                    except OSError:
                        pass
                    continue
                return False
        return False

    def _release_merge_lock(self) -> None:
        try:
            os.unlink(os.path.join(self.root, self.MERGE_LOCK))
        except OSError:
            pass

    # ------------------------------------------------------------------
    def append(self, op: str, kind: str, *, entry: Optional[dict] = None,
               key: Optional[str] = None) -> int:
        _apply(self.manifest, op, kind, entry, key)
        self._seq += 1
        rec = {"seq": self._seq, "host": self.host, "op": op, "kind": kind}
        if entry is not None:
            rec["entry"] = entry
        if key is not None:
            rec["key"] = key
        n = self._segment.append_record(rec)
        self.appends += 1
        self._since_compact += 1
        if self._since_compact >= self.compact_every:
            self.compact()
        return n

    def compact(self) -> bool:
        """The deterministic merge step: fold the legacy log and every
        segment into the shared snapshot, then truncate our own
        segment. Serialized across hosts by the merge lock; returns
        False when another host holds it (skip now, retry a window
        later — our records stay safely in our segment)."""
        if not self._acquire_merge_lock():
            self.merge_contentions += 1
            self._since_compact = 0
            return False
        try:
            # re-read everything from disk inside the lock: the merge
            # must fold what is durable *now*, including records other
            # hosts appended since our last load
            manifest, legacy_floor, old_marks = _read_snapshot(self.root)
            legacy_top, _, _ = _fold_legacy_log(manifest, self.root,
                                                legacy_floor)
            marks, _ = _fold_segments(manifest, self.root, old_marks)
            if self._crash_hook is not None:
                self._crash_hook("merge:premerge")
            out = dict(manifest)
            out["__seq__"] = legacy_top
            out["__segseq__"] = marks
            cio.atomic_write(
                self._snap_path(),
                lambda f: f.write(json.dumps(out).encode("utf-8")))
            if self._crash_hook is not None:
                self._crash_hook("merge:snapshotted")
            # snapshot durable: our own segment's records are folded and
            # we are its sole writer, so truncating cannot lose anything.
            # Other hosts' segments are left alone — their folded
            # records sit at or below the watermark and are skipped on
            # every future load; each host truncates its own at its own
            # next merge.
            self._segment.truncate()
            self.manifest = manifest
            self._watermarks = marks
            self._legacy_seq = legacy_top
            self._seq = max(self._seq, marks.get(self.host, 0))
            self._since_compact = 0
            self.compactions += 1
            return True
        finally:
            self._release_merge_lock()

    def close(self):
        self._segment.close()

    def log_bytes(self) -> int:
        try:
            return os.path.getsize(self._segment.path)
        except OSError:
            return 0

    def stats(self):
        return {"appends": self.appends, "log_bytes": self.log_bytes(),
                "compactions": self.compactions,
                "merge_contentions": self.merge_contentions,
                "host": self.host,
                "watermarks": dict(self._watermarks)}


class JournalTap:
    """Transparent journal wrapper that forwards every append to
    ``tap(op, kind, entry=, key=)`` *after* it is applied and durable
    locally. The chain store installs one when its backend exposes
    ``on_journal_append`` (the peer tier), so manifest records are
    replicated to peers without the journal implementations knowing.
    The tap is best-effort: a tap failure never fails the local append.
    ``append_untapped`` bypasses the tap — used when *adopting* records
    that came from peers, which must not echo back out."""

    def __init__(self, inner, tap):
        self.inner = inner
        self.tap = tap

    def append(self, op: str, kind: str, *, entry: Optional[dict] = None,
               key: Optional[str] = None) -> int:
        n = self.inner.append(op, kind, entry=entry, key=key)
        try:
            self.tap(op, kind, entry=entry, key=key)
        except Exception:  # noqa: BLE001 - replication is best-effort
            pass
        return n

    def append_untapped(self, op: str, kind: str, *,
                        entry: Optional[dict] = None,
                        key: Optional[str] = None) -> int:
        return self.inner.append(op, kind, entry=entry, key=key)

    def __getattr__(self, name):
        return getattr(self.inner, name)


def _entry_key(e: dict) -> Optional[str]:
    key = e.get("key")
    if key is None and "path" in e:  # pre-journal entries carried paths only
        key = os.path.basename(e["path"])
        for suffix in (".npz", ".ckpt"):
            if key.endswith(suffix):
                key = key[:-len(suffix)]
    return key


def _apply(manifest: Dict[str, List[dict]], op: str, kind: str,
           entry: Optional[dict], key: Optional[str]):
    if kind not in manifest:
        manifest[kind] = []
    if op == "add":
        manifest[kind].append(entry)
    elif op == "del":
        manifest[kind] = [e for e in manifest[kind] if _entry_key(e) != key]
    elif op == "replace":
        # atomic del-by-key + add in ONE journal record: an entry
        # rewrite (e.g. the fold advancing a full's state_step) must
        # never have a crash window in which the key exists in neither
        # form — a torn tail drops the whole record, leaving the old
        # entry intact
        manifest[kind] = ([e for e in manifest[kind]
                           if _entry_key(e) != key] + [entry])
    else:
        raise ValueError(f"unknown journal op {op!r}")
