"""Append-only manifest journal with periodic compaction.

The seed store rewrote the whole ``manifest.json`` on every record —
O(n) bytes per write, O(n²) over a training run, exactly the failure
mode per-iteration checkpointing provokes. This journal appends one
JSON line per mutation (O(1) bytes per write) and periodically folds
the log into an atomic snapshot.

On-disk layout::

    <root>/manifest.json   # snapshot {"fulls": [...], ..., "__seq__": n}
    <root>/manifest.log    # JSON lines appended after the snapshot

Records::

    {"seq": 7, "op": "add", "kind": "fulls",  "entry": {...}}
    {"seq": 8, "op": "del", "kind": "batches", "key": "batch_..."}

Recovery reads the snapshot, then replays log records with
``seq > snapshot.__seq__``. A torn tail (partial last line from a
crash mid-append) is detected by the JSON parse failing and the valid
prefix is kept — recovery always sees a consistent chain prefix.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.checkpoint import io as cio

EMPTY = {"fulls": [], "diffs": [], "batches": []}


def _blank() -> Dict[str, List[dict]]:
    return {k: [] for k in EMPTY}


class MemoryJournal:
    """Journal interface for backends with no durable root (pure
    CPU-memory tier): the manifest lives only in this process."""

    def __init__(self):
        self.manifest = _blank()
        self.appends = 0

    def append(self, op: str, kind: str, *, entry: Optional[dict] = None,
               key: Optional[str] = None) -> int:
        _apply(self.manifest, op, kind, entry, key)
        self.appends += 1
        return 0  # no bytes hit storage

    def compact(self):
        pass

    def close(self):
        pass

    def stats(self):
        return {"appends": self.appends, "log_bytes": 0, "compactions": 0}


class ManifestJournal:
    SNAPSHOT = "manifest.json"
    LOG = "manifest.log"

    def __init__(self, root: str, compact_every: int = 256):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.compact_every = compact_every
        self.compactions = 0
        self.appends = 0
        self._seq = 0
        self._since_compact = 0
        self.manifest = self._load()
        self._log = open(self._log_path(), "a", encoding="utf-8")

    # ------------------------------------------------------------------
    def _snap_path(self) -> str:
        return os.path.join(self.root, self.SNAPSHOT)

    def _log_path(self) -> str:
        return os.path.join(self.root, self.LOG)

    def _load(self) -> Dict[str, List[dict]]:
        manifest = _blank()
        if os.path.exists(self._snap_path()):
            with open(self._snap_path(), encoding="utf-8") as f:
                snap = json.load(f)
            self._seq = int(snap.pop("__seq__", 0))
            for k in manifest:
                manifest[k] = list(snap.get(k, []))
        if os.path.exists(self._log_path()):
            valid_bytes = 0
            with open(self._log_path(), "rb") as f:
                for raw in f:
                    if not raw.endswith(b"\n"):
                        break  # newline missing: the append was torn
                    try:
                        rec = json.loads(raw.decode("utf-8"))
                    except (json.JSONDecodeError, UnicodeDecodeError):
                        break  # torn tail: keep the valid prefix
                    valid_bytes += len(raw)
                    if rec.get("seq", 0) <= self._seq:
                        continue  # already folded into the snapshot
                    _apply(manifest, rec["op"], rec["kind"],
                           rec.get("entry"), rec.get("key"))
                    self._seq = rec["seq"]
            if valid_bytes < os.path.getsize(self._log_path()):
                # drop the torn fragment so the next append starts a
                # fresh line instead of merging into it (which would
                # poison every later record on the following reload)
                with open(self._log_path(), "r+b") as f:
                    f.truncate(valid_bytes)
        return manifest

    # ------------------------------------------------------------------
    def append(self, op: str, kind: str, *, entry: Optional[dict] = None,
               key: Optional[str] = None) -> int:
        """Apply a mutation and append one JSON line. Returns the number
        of journal bytes written — O(entry), independent of history."""
        _apply(self.manifest, op, kind, entry, key)
        self._seq += 1
        rec = {"seq": self._seq, "op": op, "kind": kind}
        if entry is not None:
            rec["entry"] = entry
        if key is not None:
            rec["key"] = key
        line = json.dumps(rec) + "\n"
        self._log.write(line)
        self._log.flush()
        self.appends += 1
        self._since_compact += 1
        if self._since_compact >= self.compact_every:
            self.compact()
        return len(line)

    def compact(self):
        """Fold the log into an atomic snapshot and truncate it."""
        snap = dict(self.manifest)
        snap["__seq__"] = self._seq
        # shared tmp+fsync+rename+dir-fsync implementation: the rename
        # must be durable before the log is truncated, or a crash could
        # lose both the snapshot and the folded records
        cio.atomic_write(self._snap_path(),
                         lambda f: f.write(json.dumps(snap).encode("utf-8")))
        # Snapshot is durable; a crash before the truncate just replays
        # records whose seq <= __seq__, which _load skips.
        self._log.close()
        self._log = open(self._log_path(), "w", encoding="utf-8")
        self._since_compact = 0
        self.compactions += 1

    def close(self):
        if not self._log.closed:
            self._log.close()

    def log_bytes(self) -> int:
        try:
            return os.path.getsize(self._log_path())
        except OSError:
            return 0

    def stats(self):
        return {"appends": self.appends, "log_bytes": self.log_bytes(),
                "compactions": self.compactions}


def _entry_key(e: dict) -> Optional[str]:
    key = e.get("key")
    if key is None and "path" in e:  # pre-journal entries carried paths only
        key = os.path.basename(e["path"])
        for suffix in (".npz", ".ckpt"):
            if key.endswith(suffix):
                key = key[:-len(suffix)]
    return key


def _apply(manifest: Dict[str, List[dict]], op: str, kind: str,
           entry: Optional[dict], key: Optional[str]):
    if kind not in manifest:
        manifest[kind] = []
    if op == "add":
        manifest[kind].append(entry)
    elif op == "del":
        manifest[kind] = [e for e in manifest[kind] if _entry_key(e) != key]
    else:
        raise ValueError(f"unknown journal op {op!r}")
