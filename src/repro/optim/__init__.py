"""Native Adam with the affine moment recurrences recovery exploits."""
