"""Native Adam optimizer (pytree-level), plus the differential-replay form.

Implements exactly Eq. (4) of the paper: ``M_{t+1} <- M_t + Adam(G_t)``
where the model state M = (params, opt). The *same* ``adam_update``
function serves (a) the training step and (b) checkpoint recovery replay
(Algorithm 1, recovery process) — which is what makes Finding 1
(compressed gradient == differential checkpoint) an exact identity in this
system, not an approximation.

Moments are stored in f32 regardless of the param dtype (mixed-precision
policy); the update is computed in f32 and cast back.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    mu: Any       # first moment (f32 pytree)
    nu: Any       # second moment (f32 pytree)
    count: jax.Array


def adam_init(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(zeros, jax.tree.map(jnp.copy, zeros),
                     jnp.zeros((), jnp.int32))


def adam_update(params, grads, state: AdamState, *, lr=1e-3, b1=0.9,
                b2=0.999, eps=1e-8, weight_decay=0.0,
                grad_clip=0.0) -> Tuple[Any, AdamState]:
    count = state.count + 1
    if grad_clip:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu2 = b1 * mu + (1 - b1) * g
        nu2 = b2 * nu + (1 - b2) * g * g
        step = lr * (mu2 / c1) / (jnp.sqrt(nu2 / c2) + eps)
        if weight_decay:
            step = step + lr * weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype), mu2, nu2

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    params2 = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    mu2 = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    nu2 = jax.tree.map(lambda t: t[2], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return params2, AdamState(mu2, nu2, count)
