"""Config registry: ``get_config(arch_id)`` + the assigned input shapes."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, ShapeConfig, INPUT_SHAPES  # noqa: F401

# arch-id -> module name
_REGISTRY = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "pixtral-12b": "pixtral_12b",
    "qwen2-1.5b": "qwen2_1_5b",
    "stablelm-1.6b": "stablelm_1_6b",
    "xlstm-350m": "xlstm_350m",
    "granite-3-8b": "granite_3_8b",
    "llama3-405b": "llama3_405b",
    "hymba-1.5b": "hymba_1_5b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "gpt2-l": "gpt2_l",
}

ASSIGNED_ARCHS = tuple(k for k in _REGISTRY if k != "gpt2-l")


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch_id]}")
    return mod.CONFIG


def get_shape(shape_id: str) -> ShapeConfig:
    if shape_id not in INPUT_SHAPES:
        raise KeyError(f"unknown shape {shape_id!r}; known: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[shape_id]
