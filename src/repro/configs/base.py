"""Architecture + input-shape configuration system.

Every assigned architecture is a frozen ``ArchConfig`` built by a
``src/repro/configs/<id>.py`` module (one per arch, citing its source).
``ShapeConfig`` describes the four assigned input shapes. Both are plain
dataclasses so they can be constructed / overridden from the CLI
(``--arch``, ``--shape``) and reduced for CPU smoke tests via
``reduced()``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    top_k: int = 0
    n_shared: int = 0             # always-on shared experts (DeepSeekMoE)
    expert_ff: int = 0            # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16           # mamba N / mLSTM matrix-memory per-head dim
    conv_width: int = 4
    expand: int = 2               # d_inner = expand * d_model
    chunk: int = 128              # chunkwise-scan chunk length


@dataclass(frozen=True)
class ArchConfig:
    name: str = "unnamed"
    arch_type: str = "dense"      # dense | moe | ssm | hybrid | audio | vlm
    citation: str = ""

    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 32000
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # attention variants
    sliding_window: int = 0                 # 0 = full attention
    # per-layer window pattern used by hybrid archs ("hymba keeps a few
    # global layers"); empty = uniform.
    global_attn_layers: Tuple[int, ...] = ()

    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)

    # encoder-decoder (audio backbone): n_layers is the decoder depth.
    n_encoder_layers: int = 0
    # vlm: dimensionality of the (stubbed) vision/audio frontend embeddings.
    frontend_dim: int = 0
    n_patches: int = 0            # patches (vlm) / frames divisor (audio)

    # precision / memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    grad_accum: int = 1           # microbatches inside train_step
    # dtype of the accumulated-gradient buffer; bf16 halves the FSDP
    # reduce-scatter traffic and the accumulator footprint (§Perf B-1)
    grad_accum_dtype: str = "float32"
    loss_chunk: int = 512         # seq chunk for the chunked softmax-xent

    # xlstm: every `slstm_every`-th block is an sLSTM block (rest mLSTM)
    slstm_every: int = 2

    # per-arch logical->mesh rule overrides, as ((logical, axes), ...)
    # where axes is a mesh-axis name, a tuple of names, or None. Applied
    # to TRAINING steps only — serving keeps the default (TP/seq-sharded
    # cache) layout, which is the right trade-off for small-batch decode.
    sharding_overrides: Tuple = ()

    def rules(self, kind: str = "train") -> dict:
        if kind != "train":
            return {}
        return {k: v for k, v in self.sharding_overrides}

    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """CPU-smoke-test variant: <=2 layers, d_model<=256, <=4 experts."""
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4)
        kv = min(self.n_kv_heads, heads)
        moe = self.moe
        if moe.n_experts:
            # drop-free capacity (C >= T needs cf >= E/K) so that decode
            # (per-token capacity) matches prefill bit-for-bit in tests
            k = min(2, moe.top_k)
            moe = dataclasses.replace(
                moe, n_experts=4, top_k=k,
                n_shared=min(1, moe.n_shared), expert_ff=max(64, d // 2),
                capacity_factor=4.0 / k + 0.5)
        return self.replace(
            n_layers=2, d_model=d, n_heads=heads, n_kv_heads=kv,
            d_ff=min(self.d_ff, 4 * d) if self.d_ff else 0,
            vocab=min(self.vocab, 512), head_dim=0, moe=moe,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            frontend_dim=min(self.frontend_dim, 128) if self.frontend_dim else 0,
            n_patches=min(self.n_patches, 16) if self.n_patches else 0,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
            global_attn_layers=tuple(i for i in self.global_attn_layers if i < 2),
            ssm=dataclasses.replace(self.ssm, chunk=16),
            param_dtype="float32", compute_dtype="float32",
            grad_accum=1, loss_chunk=64,
        )

    # ---- simple parameter counting (used by roofline MODEL_FLOPS) ----
    def param_count(self) -> int:
        d, hd = self.d_model, self.hd()
        qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
        o = self.n_heads * hd * d
        attn = qkv + o
        if self.arch_type == "moe":
            ff = 3 * d * self.moe.expert_ff * (self.moe.n_experts + self.moe.n_shared)
            ff += d * self.moe.n_experts  # router
        elif self.arch_type == "ssm":
            di = self.ssm.expand * d
            ff = 2 * d * di + di * d  # up/gate + down per block (approx)
            attn = 0
        else:
            ff = 3 * d * self.d_ff
        if self.arch_type == "hybrid":
            di = self.ssm.expand * d
            attn += 2 * d * di + di * d + di * self.ssm.state_dim * 2
        per_layer = attn + ff + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        n = self.n_layers * per_layer + emb + d
        if self.n_encoder_layers:
            n += self.n_encoder_layers * (attn + 3 * d * self.d_ff + 2 * d)
            n += self.n_layers * (attn + 2 * d)  # cross-attention
        if self.frontend_dim:
            n += self.frontend_dim * d
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if self.arch_type != "moe":
            return self.param_count()
        d = self.d_model
        m = self.moe
        dense_like = self.param_count() - self.n_layers * 3 * d * m.expert_ff * m.n_experts
        return dense_like + self.n_layers * 3 * d * m.expert_ff * m.top_k


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
