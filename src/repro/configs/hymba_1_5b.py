"""Hymba 1.5B. [arXiv:2411.13676]

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Parallel attention + mamba heads in every block; sliding-window attention
everywhere except the first / middle / last layers (full attention).
Meta tokens from the paper are omitted (noted in DESIGN.md).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    citation="arXiv:2411.13676",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    sliding_window=1024,
    global_attn_layers=(0, 15, 31),
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2, chunk=128),
)
