"""Granite 3 8B. [hf:ibm-granite/granite-3.0-2b-base family, 8B variant]

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b",
    arch_type="dense",
    citation="hf:ibm-granite/granite-3.0-2b-base",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab=49155,
    rope_theta=1e4,
    param_dtype="bfloat16",
    grad_accum=2,
)
