"""SeamlessM4T-medium transformer backbone. [arXiv:2308.11596]

Encoder-decoder, 12L encoder + 12L decoder, d_model=1024 16H (MHA)
d_ff=4096 vocab=256206. The mel-spectrogram/conformer frontend is a STUB:
``input_specs`` supplies precomputed frame embeddings (B, S/4, d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    citation="arXiv:2308.11596",
    n_layers=12,
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    frontend_dim=1024,
    rope_theta=1e4,
)
