"""xLSTM 350M. [arXiv:2405.04517]

24 blocks d_model=1024 4H d_ff=0 (projections live inside the blocks)
vocab=50304. Alternating sLSTM + mLSTM blocks (every 2nd block sLSTM).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    arch_type="ssm",
    citation="arXiv:2405.04517",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    ssm=SSMConfig(state_dim=0, conv_width=4, expand=2, chunk=128),
    slstm_every=2,
)
