"""DeepSeekMoE 16B. [arXiv:2401.06066]

28L d_model=2048 16H (MHA kv=16) per-expert d_ff=1408 vocab=102400,
MoE: 2 shared + 64 routed experts, top-6 (fine-grained experts).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    citation="arXiv:2401.06066",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, expert_ff=1408,
                  capacity_factor=1.25),
    rope_theta=1e4,
    param_dtype="bfloat16",
    grad_accum=2,
)
