"""GPT2-L (762M) — the paper's own largest evaluation model. [Radford'19]

36L d_model=1280 20H d_ff=5120 vocab=50257. Used by the benchmark suite to
mirror the paper's GPT2-L experiments (at reduced scale on CPU).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gpt2-l",
    arch_type="dense",
    citation="Radford et al. 2019 (paper's Table II)",
    n_layers=36,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=50257,
    rope_theta=1e4,
)
