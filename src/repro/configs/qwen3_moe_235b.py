"""Qwen3-MoE 235B-A22B backbone. [hf:Qwen/Qwen3-30B-A3B scaled per assignment]

94L d_model=4096 64H (GQA kv=4) per-expert d_ff=1536 vocab=151936,
MoE 128 experts top-8.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    citation="hf:Qwen/Qwen3-30B-A3B",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab=151936,
    head_dim=128,  # qwen3 uses 128 head_dim (64 heads x 128 > d_model)
    moe=MoEConfig(n_experts=128, top_k=8, n_shared=0, expert_ff=1536,
                  capacity_factor=1.25),
    rope_theta=1e6,
    param_dtype="bfloat16",
    grad_accum=4,
    grad_accum_dtype="bfloat16",
)
