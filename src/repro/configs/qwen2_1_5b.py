"""Qwen2 1.5B. [arXiv:2407.10671]

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936, QKV bias.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    arch_type="dense",
    citation="arXiv:2407.10671",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    param_dtype="bfloat16",
    # §Perf C-1: 12 heads don't divide a 16-way model axis, so tensor
    # parallelism degenerates (attention replicated 16x). A 1.5B model
    # fits per-chip: run pure 256-way data parallel with FSDP over the
    # whole mesh instead.
    sharding_overrides=(
        ("batch", ("pod", "data", "model")),
        ("fsdp", ("pod", "data", "model")),
        ("heads", None), ("mlp", None), ("vocab", None),
    ),
)
