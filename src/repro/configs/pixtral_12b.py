"""Pixtral 12B language backbone. [hf:mistralai/Pixtral-12B-2409]

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072. The Pixtral-ViT
vision tower is a STUB: ``input_specs`` supplies precomputed patch
embeddings (B, n_patches, 1024) that the trainable projector maps into
the decoder's embedding stream.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    arch_type="vlm",
    citation="hf:mistralai/Pixtral-12B-2409",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    frontend_dim=1024,
    n_patches=1024,
    rope_theta=1e6,
    param_dtype="bfloat16",
    grad_accum=2,
)
