"""StableLM 2 1.6B. [hf:stabilityai/stablelm-2-1_6b]

24L d_model=2048 32H (MHA kv=32) d_ff=5632 vocab=100352.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    citation="hf:stabilityai/stablelm-2-1_6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    rope_theta=1e4,
)
