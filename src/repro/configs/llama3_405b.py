"""Llama-3.1 405B. [arXiv:2407.21783]

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    arch_type="dense",
    citation="arXiv:2407.21783",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab=128256,
    rope_theta=5e5,
    param_dtype="bfloat16",
    # §Perf B-1: 8 microbatches (half the per-step FSDP weight-gather
    # rounds; activation stash stays within HBM thanks to the
    # sequence-parallel residual) + bf16 gradient accumulation (halves
    # reduce-scatter traffic and the accumulator footprint).
    grad_accum=4,
    grad_accum_dtype="bfloat16",
    loss_chunk=256,
)
