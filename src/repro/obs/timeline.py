"""Per-step stall attribution: where did each step's wall time go?

The paper's claim is a *time* claim — checkpointing hidden behind
compute — so the first-class question is "what fraction of a step was
stall?". :class:`StepTimeline` charges each step's wall time to the
categories

* ``compute`` — the residual: wall minus every attributed stall
* ``snapshot_stall`` — blocked waiting for a snapshot arena permit or
  a synchronous D2H copy on the step path
* ``flush_stall`` — blocked in ``flush()`` draining the persist queue
  (failure injection, shutdown, barrier-style persists)
* ``queue_backpressure`` — blocked in ``ReusingQueue.put`` because the
  consumer fell behind
* ``recovery`` — restoring state after a failure

The driver owns step boundaries (``begin``/``commit``); strategies
charge stalls from wherever they block (``charge`` is thread-safe —
the persist consumer never charges, only the step thread blocks, but
the API doesn't assume it). Work that happens *outside* a step window
(a flush after the loop, recovery between steps) is recorded with
:meth:`event` so attribution still sums to observed wall.

The tuner consumes :meth:`stall_fraction` — stalls over wall across a
recent window — a cleaner signal than raw wall-clock, which conflates
checkpoint cost with compute jitter.
"""
from __future__ import annotations

import json
import threading
from collections import deque
from typing import Any, Dict, List, Optional

__all__ = ["StepTimeline", "TIMELINE", "STALL_CATEGORIES"]

STALL_CATEGORIES = ("snapshot_stall", "flush_stall", "queue_backpressure",
                    "recovery")
CATEGORIES = ("compute",) + STALL_CATEGORIES


class StepTimeline:
    """Bounded per-step ledger of wall-time attribution."""

    def __init__(self, maxlen: int = 4096):
        self._lock = threading.Lock()
        self._records: deque = deque(maxlen=maxlen)
        self._open_step: Optional[int] = None
        self._charges: Dict[str, float] = {}
        self.steps_total = 0

    # -- step window --------------------------------------------------
    def begin(self, step: int) -> None:
        with self._lock:
            self._open_step = step
            self._charges = {}

    def charge(self, category: str, seconds: float) -> None:
        """Attribute ``seconds`` of the open step to ``category``.
        Charges landing outside a step window (consumer-thread stalls
        after commit) are dropped — they are not step-path time."""
        if seconds <= 0.0:
            return
        with self._lock:
            if self._open_step is None:
                return
            self._charges[category] = (
                self._charges.get(category, 0.0) + seconds)

    def commit(self, step: int, wall: float) -> Dict[str, float]:
        """Close the step window: compute = wall − attributed stalls
        (clamped at 0 — a stall measured longer than the wall, e.g.
        clock skew across charge sites, never goes negative)."""
        with self._lock:
            charges = self._charges
            self._open_step = None
            self._charges = {}
            stalls = sum(charges.values())
            rec = {"step": step, "wall": wall,
                   "compute": max(0.0, wall - stalls)}
            for cat in STALL_CATEGORIES:
                if cat in charges:
                    rec[cat] = charges[cat]
            self._records.append(rec)
            self.steps_total += 1
            return rec

    def event(self, category: str, seconds: float,
              step: Optional[int] = None) -> None:
        """Record out-of-step work (post-loop flush, recovery) as its
        own zero-compute record so totals still match observed wall."""
        if seconds <= 0.0:
            return
        with self._lock:
            if self._open_step is not None:
                # inside a step window: charge it there instead
                self._charges[category] = (
                    self._charges.get(category, 0.0) + seconds)
                return
            self._records.append({"step": step, "wall": seconds,
                                  "compute": 0.0, category: seconds,
                                  "out_of_step": True})

    # -- consumption --------------------------------------------------
    def records(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._records)

    def stall_fraction(self, window: int = 32) -> float:
        """Stalled seconds over wall seconds across the last ``window``
        step records (out-of-step events excluded: the tuner wants the
        steady-state step-path signal, not one-off recovery cost)."""
        with self._lock:
            recs = [r for r in self._records
                    if not r.get("out_of_step")][-window:]
        wall = sum(r["wall"] for r in recs)
        if wall <= 0.0:
            return 0.0
        stall = sum(sum(r.get(c, 0.0) for c in STALL_CATEGORIES)
                    for r in recs)
        return min(1.0, stall / wall)

    def totals(self) -> Dict[str, float]:
        out = {c: 0.0 for c in CATEGORIES}
        out["wall"] = 0.0
        for r in self.records():
            out["wall"] += r["wall"]
            for c in CATEGORIES:
                out[c] += r.get(c, 0.0)
        return out

    def stats(self) -> Dict[str, Any]:
        t = self.totals()
        return {"steps": self.steps_total,
                "stall_fraction": self.stall_fraction(),
                **{k: round(v, 6) for k, v in t.items()}}

    def write_jsonl(self, path: str, extra: Optional[List[dict]] = None,
                    mode: str = "w") -> int:
        """Dump step records (+ optional tagged extras, e.g. the final
        metrics registry collection) as JSON Lines."""
        n = 0
        with open(path, mode, encoding="utf-8") as f:
            for rec in self.records():
                f.write(json.dumps({"kind": "step", **rec}) + "\n")
                n += 1
            for rec in (extra or []):
                f.write(json.dumps(rec) + "\n")
                n += 1
        return n

    def clear(self) -> None:
        with self._lock:
            self._records.clear()
            self._open_step = None
            self._charges = {}
            self.steps_total = 0


#: process-global timeline — strategies charge it, the driver frames it
TIMELINE = StepTimeline()
