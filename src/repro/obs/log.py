"""Structured logging for the launchers.

``launch/train.py``/``launch/serve.py`` used bare ``print()``; this
wraps stdlib :mod:`logging` with a formatter that keeps the default
human-readable output byte-stable (message only, no timestamp prefix,
so examples and docs keep matching) while gaining ``--log-level``
filtering and ``key=value`` structured fields::

    log = get_logger("train")
    log.info("step %(step)5d loss=%(loss).4f", step=10, loss=1.2)
    log.info("recovered at step %d", 42)          # printf style works too

Fields passed as keywords format through ``%(name)s`` placeholders in
the message; at DEBUG the raw field dict is appended for grepping.
"""
from __future__ import annotations

import logging
import sys
from typing import Any

__all__ = ["configure", "get_logger"]

_ROOT = "repro"
_configured = False


class _KVLogger(logging.LoggerAdapter):
    """Adapter accepting structured fields as keyword arguments."""

    def log_kv(self, level: int, msg: str, *args, **fields) -> None:
        if not self.isEnabledFor(level):
            return
        if fields:
            try:
                msg = msg % fields
            except (KeyError, TypeError, ValueError):
                msg = f"{msg} {fields}"
        self.logger.log(level, msg, *args)

    def info(self, msg, *args, **fields):
        self.log_kv(logging.INFO, msg, *args, **fields)

    def debug(self, msg, *args, **fields):
        self.log_kv(logging.DEBUG, msg, *args, **fields)

    def warning(self, msg, *args, **fields):
        self.log_kv(logging.WARNING, msg, *args, **fields)

    def error(self, msg, *args, **fields):
        self.log_kv(logging.ERROR, msg, *args, **fields)


def configure(level: str = "info", stream=None) -> None:
    """Idempotent root setup: message-only format to stdout (matching
    the old ``print()`` output), level from ``--log-level``."""
    global _configured
    root = logging.getLogger(_ROOT)
    root.setLevel(getattr(logging, level.upper(), logging.INFO))
    if not _configured:
        handler = logging.StreamHandler(stream or sys.stdout)
        handler.setFormatter(logging.Formatter("%(message)s"))
        root.addHandler(handler)
        root.propagate = False
        _configured = True


def get_logger(name: str = "") -> _KVLogger:
    configure()
    full = f"{_ROOT}.{name}" if name else _ROOT
    return _KVLogger(logging.getLogger(full), {})


def set_level(level: str) -> None:
    logging.getLogger(_ROOT).setLevel(
        getattr(logging, level.upper(), logging.INFO))


def kv(**fields: Any) -> str:
    """Render fields as a stable ``k=v`` suffix for step lines."""
    return " ".join(f"{k}={v}" for k, v in fields.items())
