"""Span tracer: bounded ring buffer + Chrome ``trace_event`` export.

The checkpoint pipeline spreads one logical step across four threads
(main step loop, persist worker, maintenance worker, peer-replication
worker); a flat log can't show why a step stalled. This tracer records
``(name, category, tid, t_start, t_end, attrs)`` spans into a
``deque(maxlen=...)`` ring (appends are GIL-atomic; the bound makes a
week-long run safe by construction) and exports the Chrome
``trace_event`` JSON that chrome://tracing and Perfetto render as a
per-thread flame chart of the full lifecycle: step compute →
dirty-snapshot D2H → compress → persist-queue wait → backend write
(per tier) → peer fanout → fold/GC slices → replay H2D.

Cost discipline: tracing is **disabled by default** and the disabled
path is one attribute load + truthiness test returning a module-level
no-op singleton — no object allocation, no clock read. Callers
therefore sprinkle ``with trace_span(...)`` freely on the step path.
"""
from __future__ import annotations

import functools
import json
import os
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

__all__ = ["SpanTracer", "TRACER", "trace_span", "traced"]


class _Span:
    """An open span; ``__exit__`` stamps the end time and commits the
    event tuple to the ring."""

    __slots__ = ("_tracer", "name", "cat", "attrs", "t0")

    def __init__(self, tracer: "SpanTracer", name: str, cat: str,
                 attrs: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.attrs = attrs
        self.t0 = 0.0

    def __enter__(self) -> "_Span":
        self.t0 = time.perf_counter()
        return self

    def set(self, **attrs) -> None:
        """Attach attributes discovered mid-span (byte counts...)."""
        if self.attrs is None:
            self.attrs = attrs
        else:
            self.attrs.update(attrs)

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter()
        t = self._tracer
        th = threading.current_thread()
        t._events.append((self.name, self.cat, th.ident, th.name,
                          self.t0, t1, self.attrs))
        t.events_total += 1


class _NoopSpan:
    """Shared do-nothing span for the disabled path: zero allocation,
    zero clock reads."""

    __slots__ = ()
    t0 = 0.0

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass

    def set(self, **attrs) -> None:
        pass


_NOOP = _NoopSpan()


class SpanTracer:
    """Ring-buffered span recorder (see module docstring)."""

    DEFAULT_BUFFER = 65536

    def __init__(self, buffer: int = DEFAULT_BUFFER, enabled: bool = False):
        self.enabled = enabled
        self.events_total = 0
        self._events: deque = deque(maxlen=buffer)

    # -- control ------------------------------------------------------
    def enable(self, buffer: Optional[int] = None) -> None:
        if buffer is not None and buffer != self._events.maxlen:
            self._events = deque(self._events, maxlen=max(1, buffer))
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        self._events.clear()
        self.events_total = 0

    # -- recording ----------------------------------------------------
    def span(self, name: str, cat: str = "pipeline", **attrs):
        if not self.enabled:
            return _NOOP
        return _Span(self, name, cat, attrs or None)

    # -- introspection ------------------------------------------------
    def __len__(self) -> int:
        return len(self._events)

    @property
    def dropped(self) -> int:
        return self.events_total - len(self._events)

    def events(self) -> List[tuple]:
        return list(self._events)

    def stats(self) -> Dict[str, Any]:
        return {"enabled": self.enabled, "buffered": len(self._events),
                "capacity": self._events.maxlen,
                "events_total": self.events_total,
                "dropped": self.dropped}

    # -- export -------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        """Chrome ``trace_event`` JSON object: one ``"X"`` (complete)
        event per span, µs timestamps, plus ``"M"`` metadata events
        naming each thread so Perfetto labels the tracks."""
        pid = os.getpid()
        events: List[Dict[str, Any]] = []
        threads: Dict[int, str] = {}
        for (name, cat, tid, tname, t0, t1, attrs) in list(self._events):
            threads.setdefault(tid, tname)
            ev: Dict[str, Any] = {
                "name": name, "cat": cat, "ph": "X",
                "ts": round(t0 * 1e6, 3),
                "dur": round((t1 - t0) * 1e6, 3),
                "pid": pid, "tid": tid,
            }
            if attrs:
                ev["args"] = attrs
            events.append(ev)
        for tid, tname in threads.items():
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": tid, "args": {"name": tname}})
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def export_chrome(self, path: str) -> int:
        doc = self.to_chrome()
        with open(path, "w", encoding="utf-8") as f:
            json.dump(doc, f)
        return len(doc["traceEvents"])


#: process-global tracer; ``launch/train.py --trace-out`` enables it
TRACER = SpanTracer()


def trace_span(name: str, cat: str = "pipeline", **attrs):
    """``with trace_span("persist.batch", "persist", n=4):`` — records
    a span on the global tracer; a shared no-op when disabled."""
    if not TRACER.enabled:
        return _NOOP
    return _Span(TRACER, name, cat, attrs or None)


def traced(name: Optional[str] = None, cat: str = "pipeline"):
    """Decorator form: ``@traced("maint.gc", "maintenance")``."""

    def deco(fn):
        span_name = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not TRACER.enabled:
                return fn(*args, **kwargs)
            with _Span(TRACER, span_name, cat, None):
                return fn(*args, **kwargs)

        return wrapper

    return deco
