"""Unified checkpoint-pipeline observability.

Three layers over one currency:

* :mod:`repro.obs.metrics` — typed Counter/Gauge/Histogram instruments
  + the process-global :data:`~repro.obs.metrics.REGISTRY`; components
  register per-instance :class:`~repro.obs.metrics.InstrumentSet`\\ s
  and their legacy ``stats()`` dicts become thin views.
* :mod:`repro.obs.trace` — bounded ring-buffer span tracer
  (:data:`~repro.obs.trace.TRACER`, ``with trace_span(...)``,
  ``@traced``) with a Chrome ``trace_event`` exporter for
  chrome://tracing / Perfetto.
* :mod:`repro.obs.timeline` — :class:`~repro.obs.timeline.StepTimeline`
  charging each step's wall to {compute, snapshot-stall, flush-stall,
  queue-backpressure, recovery}; feeds the online (f, b) tuner a
  stall-fraction signal.

``launch/train.py --trace-out/--metrics-out/--trace-buffer`` emit the
artifacts; ``repro.analysis.trace_report`` renders them.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, InstrumentSet,
                               MetricsRegistry, REGISTRY)
from repro.obs.timeline import STALL_CATEGORIES, StepTimeline, TIMELINE
from repro.obs.trace import SpanTracer, TRACER, trace_span, traced

__all__ = ["Counter", "Gauge", "Histogram", "InstrumentSet",
           "MetricsRegistry", "REGISTRY", "SpanTracer", "TRACER",
           "trace_span", "traced", "StepTimeline", "TIMELINE",
           "STALL_CATEGORIES"]
