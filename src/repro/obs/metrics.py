"""Typed metrics registry: Counter / Gauge / Histogram instruments.

One currency for the telemetry every pipeline component used to
hand-roll as an ad-hoc ``stats()`` dict. Components own *per-instance*
instruments (grouped in an :class:`InstrumentSet`) so each store /
queue / meter instance keeps an independent view — the existing
``stats()`` methods become thin reads over the set — while every
instrument also registers into the process-global
:class:`MetricsRegistry`, which aggregates across live instances for
the ``--metrics-out`` dump.

Design constraints, in order:

* **Step-path cost.** ``Counter.add`` is one lock-protected float add;
  ``Histogram.observe`` is a ``bisect`` + two adds. No string
  formatting, no allocation on the hot path.
* **Backward compatibility.** Components exposed raw attributes
  (``store.bytes_written``, ``COPY_METER.bytes``) that tests and
  benchmarks read directly; those become properties over instruments,
  so the registry absorbs the counters without breaking a single
  caller.
* **No leaks.** The global registry holds weak references: a closed
  store's instruments vanish from ``collect()`` when the store is
  collected, and tests that build hundreds of stores don't accumulate.
"""
from __future__ import annotations

import bisect
import math
import threading
import weakref
from typing import Any, Dict, List, Optional, Sequence

__all__ = ["Counter", "Gauge", "Histogram", "InstrumentSet",
           "MetricsRegistry", "REGISTRY", "default_buckets"]


class Counter:
    """Monotonic accumulator. ``add`` accepts negative deltas only via
    ``reset()`` — components that used ``-=`` bookkeeping (the memory
    tier's byte gauge) want a :class:`Gauge` instead."""

    __slots__ = ("name", "_value", "_lock", "__weakref__")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def add(self, delta: int | float = 1) -> None:
        with self._lock:
            self._value += delta

    def reset(self) -> None:
        with self._lock:
            self._value = 0

    @property
    def value(self):
        return self._value

    def snapshot(self) -> Dict[str, Any]:
        return {"name": self.name, "type": "counter", "value": self._value}


class Gauge:
    """Last-written value; ``add`` supports signed deltas (byte
    occupancy, queue depth)."""

    __slots__ = ("name", "_value", "_lock", "__weakref__")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def set(self, value) -> None:
        self._value = value

    def add(self, delta) -> None:
        with self._lock:
            self._value += delta

    def reset(self) -> None:
        self._value = 0

    @property
    def value(self):
        return self._value

    def snapshot(self) -> Dict[str, Any]:
        return {"name": self.name, "type": "gauge", "value": self._value}


def default_buckets(lo: float = 1e-5, hi: float = 100.0,
                    per_decade: int = 4) -> List[float]:
    """Log-spaced bucket upper bounds covering [lo, hi] — the default
    spans 10µs..100s, wide enough for both a dict-insert put and a
    multi-second recovery replay at ~18% relative error."""
    n = int(round(math.log10(hi / lo) * per_decade))
    return [lo * (hi / lo) ** (i / n) for i in range(n + 1)]


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles.

    ``observe`` bisects into precomputed upper bounds; ``percentile``
    walks the cumulative counts and linearly interpolates inside the
    winning bucket (exact min/max tighten the first/last bucket), the
    standard Prometheus-style estimate — cheap, bounded memory, good
    enough for p50/p95/p99 tables."""

    __slots__ = ("name", "bounds", "_counts", "_count", "_sum",
                 "_min", "_max", "_lock", "__weakref__")

    def __init__(self, name: str, buckets: Optional[Sequence[float]] = None):
        self.name = name
        self.bounds = list(buckets) if buckets else default_buckets()
        self._counts = [0] * (len(self.bounds) + 1)  # +1 overflow
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        i = bisect.bisect_left(self.bounds, value)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += value
            if value < self._min:
                self._min = value
            if value > self._max:
                self._max = value

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * (len(self.bounds) + 1)
            self._count = 0
            self._sum = 0.0
            self._min = math.inf
            self._max = -math.inf

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def value(self) -> float:
        """Sum — lets callers treat a histogram as its total (the
        CopyMeter's ``d2h_wait_s`` style accumulators)."""
        return self._sum

    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def percentile(self, p: float) -> float:
        if not self._count:
            return 0.0
        target = self._count * min(max(p, 0.0), 100.0) / 100.0
        cum = 0
        for i, c in enumerate(self._counts):
            if not c:
                continue
            lo = self.bounds[i - 1] if i > 0 else 0.0
            hi = self.bounds[i] if i < len(self.bounds) else self._max
            # exact extremes tighten the edge buckets
            lo = max(lo, self._min) if cum == 0 else lo
            hi = min(hi, self._max)
            if cum + c >= target:
                frac = (target - cum) / c
                return lo + (hi - lo) * frac
            cum += c
        return self._max

    def snapshot(self) -> Dict[str, Any]:
        return {"name": self.name, "type": "histogram",
                "count": self._count, "sum": self._sum,
                "min": (None if self._count == 0 else self._min),
                "max": (None if self._count == 0 else self._max),
                "mean": self.mean(),
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


class MetricsRegistry:
    """Process-global instrument directory. Holds weakrefs — a
    component's instruments disappear when the component does —
    and aggregates same-named instruments across live instances on
    :meth:`collect` (multiple stores in one process sum their
    ``bytes_written``)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: List[weakref.ref] = []

    def register(self, instrument):
        with self._lock:
            self._instruments.append(weakref.ref(instrument))
        return instrument

    def counter(self, name: str) -> Counter:
        return self.register(Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self.register(Gauge(name))

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        return self.register(Histogram(name, buckets))

    def live(self) -> List[Any]:
        with self._lock:
            alive, out = [], []
            for ref in self._instruments:
                inst = ref()
                if inst is not None:
                    alive.append(ref)
                    out.append(inst)
            self._instruments = alive
        return out

    def collect(self) -> List[Dict[str, Any]]:
        """Aggregated snapshots, one entry per instrument *name*.
        Counters/gauges sum across instances; histograms merge counts
        and report the merged percentiles via a union snapshot."""
        by_name: Dict[str, List[Any]] = {}
        for inst in self.live():
            by_name.setdefault(inst.name, []).append(inst)
        out: List[Dict[str, Any]] = []
        for name in sorted(by_name):
            insts = by_name[name]
            if len(insts) == 1:
                out.append(insts[0].snapshot())
                continue
            first = insts[0].snapshot()
            if first["type"] == "histogram":
                merged = Histogram(name, insts[0].bounds)
                for h in insts:
                    with h._lock:
                        for i, c in enumerate(h._counts):
                            if i < len(merged._counts):
                                merged._counts[i] += c
                        merged._count += h._count
                        merged._sum += h._sum
                        merged._min = min(merged._min, h._min)
                        merged._max = max(merged._max, h._max)
                out.append(merged.snapshot())
            else:
                first["value"] = sum(i.value for i in insts)
                out.append(first)
        return out


#: the process-global default registry
REGISTRY = MetricsRegistry()


class InstrumentSet:
    """A component's bundle of instruments under one name prefix.

    ``counter/gauge/histogram`` create-and-memoize by short key;
    ``view()`` returns a ``stats()``-compatible ``{key: value}`` dict
    (histograms expand to ``key`` = sum plus ``key_p50``-style keys
    only when asked). The sync test walks ``keys()`` against each
    component's ``stats()`` output to catch orphaned dict keys."""

    def __init__(self, prefix: str, registry: MetricsRegistry = REGISTRY):
        self.prefix = prefix
        self._registry = registry
        self._by_key: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def _make(self, key: str, factory):
        with self._lock:
            inst = self._by_key.get(key)
            if inst is None:
                inst = factory(f"{self.prefix}.{key}")
                self._registry.register(inst)
                self._by_key[key] = inst
            return inst

    def counter(self, key: str) -> Counter:
        return self._make(key, Counter)

    def gauge(self, key: str) -> Gauge:
        return self._make(key, Gauge)

    def histogram(self, key: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        return self._make(key, lambda n: Histogram(n, buckets))

    def keys(self) -> List[str]:
        with self._lock:
            return sorted(self._by_key)

    def get(self, key: str):
        return self._by_key.get(key)

    def view(self) -> Dict[str, Any]:
        with self._lock:
            items = list(self._by_key.items())
        out: Dict[str, Any] = {}
        for key, inst in items:
            if isinstance(inst, Histogram):
                out[key] = inst.sum
            else:
                out[key] = inst.value
        return out
