"""HLO-text statistics: collective bytes per category.

Parses the post-SPMD (per-device) HLO of a compiled executable and sums
the *result* sizes of every collective op. Shapes in partitioned HLO are
per-device, so the totals are per-chip traffic, matching the other
roofline terms.

Caveat handled by the roofline module: collectives inside a while/scan
body appear once in the text — segment-composed accounting multiplies by
trip counts (see repro.analysis.segments).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\(?[^=]*?\)?)\s*(" + "|".join(COLLECTIVES) + r")(-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-category per-device bytes (+ 'total', 'count')."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":   # async pair: count only the -start
            continue
        result_sig, op = m.group(1), m.group(2)
        b = sum(_shape_bytes(dt, dims)
                for dt, dims in _SHAPE_RE.findall(result_sig))
        out[op] += b
        out["count"] += 1
    out["total"] = sum(v for k, v in out.items()
                       if k in COLLECTIVES)
    return dict(out)


def op_histogram(hlo_text: str, top: int = 15):
    """Most frequent HLO opcodes (debugging aid for perf iteration)."""
    ops = re.findall(r"=\s*\(?[\w\[\],{}: ]*?\)?\s*([a-z][\w-]*)\(",
                     hlo_text)
    hist = defaultdict(int)
    for o in ops:
        hist[o] += 1
    return sorted(hist.items(), key=lambda kv: -kv[1])[:top]
