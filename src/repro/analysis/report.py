"""Generate the EXPERIMENTS.md dry-run + roofline tables from results/."""
from __future__ import annotations

import json
import sys


def gib(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(path: str) -> str:
    rows = json.load(open(path))
    out = ["| arch | shape | mesh | step | lower s | compile s | "
           "args GiB/dev | temp GiB/dev | peak GiB/dev | collectives "
           "(bytes/dev) |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"FAILED: {r.get('error', '?')} | | | | | | |")
            continue
        m, c = r["memory"], r["collectives"]
        colls = " ".join(f"{k}:{v / 2**20:.0f}M" for k, v in c.items()
                         if k not in ("total", "count") and v)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['step_kind']} | {r['lower_s']} | {r['compile_s']} | "
            f"{gib(m['argument_bytes'])} | {gib(m['temp_bytes'])} | "
            f"{gib(m['peak_bytes_est'])} | {colls} |")
    return "\n".join(out)


def roofline_table(path: str) -> str:
    rows = json.load(open(path))
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL_FLOPS/chip | useful ratio | one-line lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    LEVERS = {
        ("compute",): "larger per-chip tiles / drop masked-tile waste",
        ("memory",): "fewer activation passes (fusion), lower-precision "
                     "intermediates, remat policy",
        ("collective",): "fewer FSDP gather rounds (accum), sharding that "
                         "keeps tokens resident",
    }
    for r in rows:
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | ERROR {r['error']} "
                       "| | | | | | |")
            continue
        lever = LEVERS[(r["dominant"],)]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['model_flops_per_chip']:.3g} | "
            f"{r['useful_flops_ratio']:.2f} | {lever} |")
    return "\n".join(out)


if __name__ == "__main__":
    kind, path = sys.argv[1], sys.argv[2]
    print(dryrun_table(path) if kind == "dryrun" else roofline_table(path))
