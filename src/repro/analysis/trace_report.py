"""Render emitted observability artifacts into human-readable reports.

Consumes the two files a ``launch/train.py`` run emits:

* ``--trace-out trace.json`` — Chrome ``trace_event`` JSON; prints the
  top-N slowest spans and a per-category time rollup.
* ``--metrics-out metrics.jsonl`` — JSON Lines of per-step timeline
  records (``{"kind": "step", ...}``) plus the final metrics registry
  dump (``{"kind": "metric", ...}``); prints the stall-attribution
  table and the attributed fraction of checkpointed-step wall.

``--compare baseline.jsonl`` additionally reports step-path overhead
(median step wall vs. the baseline run's) — CI's <5% tracing-overhead
guard drives this.

Run::

    PYTHONPATH=src python -m repro.analysis.trace_report \
        --trace /tmp/trace.json --metrics /tmp/metrics.jsonl [--top 15]
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List, Optional, Tuple

CATEGORIES = ("compute", "snapshot_stall", "flush_stall",
              "queue_backpressure", "recovery")


# ---------------------------------------------------------------------
# loaders (each validates the schema it claims to read)
# ---------------------------------------------------------------------
def load_chrome_trace(path: str) -> List[Dict[str, Any]]:
    """Load + validate a Chrome ``trace_event`` JSON object format
    file. Raises ``ValueError`` on schema violations so tests (and CI)
    catch a malformed exporter, not a silently empty report."""
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError(f"{path}: not Chrome trace_event JSON "
                         "(missing 'traceEvents')")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError(f"{path}: 'traceEvents' is not a list")
    for i, ev in enumerate(events):
        for field in ("name", "ph", "pid", "tid"):
            if field not in ev:
                raise ValueError(
                    f"{path}: event {i} missing required field "
                    f"{field!r}: {ev}")
        if ev["ph"] == "X" and ("ts" not in ev or "dur" not in ev):
            raise ValueError(
                f"{path}: complete event {i} missing ts/dur: {ev}")
    return events


def load_metrics_jsonl(path: str) -> Tuple[List[dict], List[dict]]:
    """Split a ``--metrics-out`` JSONL into (step records, metric
    snapshots)."""
    steps: List[dict] = []
    metrics: List[dict] = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.get("kind")
            if kind == "step":
                steps.append(rec)
            elif kind == "metric":
                metrics.append(rec)
    return steps, metrics


# ---------------------------------------------------------------------
# analyses
# ---------------------------------------------------------------------
def slowest_spans(events: List[dict], top: int = 15) -> List[dict]:
    spans = [ev for ev in events if ev.get("ph") == "X"]
    return sorted(spans, key=lambda ev: ev.get("dur", 0.0),
                  reverse=True)[:top]


def category_rollup(events: List[dict]) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        cat = ev.get("cat", "?")
        agg = out.setdefault(cat, {"count": 0, "total_ms": 0.0})
        agg["count"] += 1
        agg["total_ms"] += ev.get("dur", 0.0) / 1e3
    return out


def attribution(steps: List[dict]) -> Dict[str, float]:
    """Total seconds charged per category plus the attributed fraction:
    sum(categories)/sum(wall). The timeline computes compute as the
    wall residual, so the fraction is 1.0 up to float noise — the
    report asserts the *pipeline* kept it ≥95%, catching any future
    charge-accounting regression."""
    totals = {c: 0.0 for c in CATEGORIES}
    wall = 0.0
    for rec in steps:
        wall += rec.get("wall", 0.0)
        for c in CATEGORIES:
            totals[c] += rec.get(c, 0.0)
    attributed = sum(totals.values())
    totals["wall"] = wall
    totals["attributed_fraction"] = (attributed / wall) if wall else 0.0
    return totals


def median_step_wall(steps: List[dict]) -> float:
    """Median wall of in-loop step records — the step-path cost metric
    for overhead comparison (median, not mean: robust to the one-off
    flush/recovery outliers and compile-warmup first steps)."""
    walls = sorted(r["wall"] for r in steps
                   if not r.get("out_of_step") and "wall" in r)
    if not walls:
        return 0.0
    n = len(walls)
    return (walls[n // 2] if n % 2 else
            (walls[n // 2 - 1] + walls[n // 2]) / 2.0)


def overhead_pct(steps: List[dict], baseline_steps: List[dict]) -> float:
    base = median_step_wall(baseline_steps)
    cur = median_step_wall(steps)
    if base <= 0.0:
        return 0.0
    return (cur - base) / base * 100.0


# ---------------------------------------------------------------------
# report
# ---------------------------------------------------------------------
def print_stall_table(steps: List[dict], out=print) -> Dict[str, float]:
    tot = attribution(steps)
    wall = tot["wall"] or 1e-12
    out(f"stall attribution over {len(steps)} records "
        f"({tot['wall']:.3f}s wall):")
    out(f"  {'category':<20} {'seconds':>10} {'share':>8}")
    for c in CATEGORIES:
        out(f"  {c:<20} {tot[c]:>10.4f} {tot[c] / wall:>7.1%}")
    out(f"  attributed fraction: {tot['attributed_fraction']:.1%}")
    return tot


def print_span_table(events: List[dict], top: int, out=print) -> None:
    roll = category_rollup(events)
    if roll:
        out("span categories:")
        out(f"  {'category':<16} {'spans':>8} {'total_ms':>12}")
        for cat in sorted(roll, key=lambda c: -roll[c]["total_ms"]):
            agg = roll[cat]
            out(f"  {cat:<16} {agg['count']:>8d} {agg['total_ms']:>12.2f}")
    out(f"top {top} slowest spans:")
    out(f"  {'name':<28} {'cat':<14} {'ms':>10}  args")
    for ev in slowest_spans(events, top):
        args = ev.get("args") or {}
        out(f"  {ev['name']:<28} {ev.get('cat', '?'):<14} "
            f"{ev.get('dur', 0) / 1e3:>10.2f}  {args if args else ''}")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", default=None,
                    help="Chrome trace_event JSON from --trace-out")
    ap.add_argument("--metrics", default=None,
                    help="JSONL from --metrics-out")
    ap.add_argument("--compare", default=None, metavar="BASELINE_JSONL",
                    help="baseline --metrics-out to compute step-path "
                         "overhead %% against")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--assert-attribution", type=float, default=None,
                    metavar="FRAC", help="exit 1 unless attributed "
                    "fraction >= FRAC (CI guard)")
    ap.add_argument("--assert-overhead", type=float, default=None,
                    metavar="PCT", help="exit 1 unless --compare "
                    "overhead < PCT (CI guard)")
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics:
        ap.error("need --trace and/or --metrics")

    rc = 0
    if args.trace:
        events = load_chrome_trace(args.trace)
        print(f"{args.trace}: {len(events)} events")
        print_span_table(events, args.top)
    if args.metrics:
        steps, metrics = load_metrics_jsonl(args.metrics)
        in_loop = [r for r in steps if not r.get("out_of_step")]
        tot = print_stall_table(steps)
        print(f"median step wall: {median_step_wall(steps) * 1e3:.2f}ms "
              f"({len(in_loop)} in-loop steps)")
        if metrics:
            print(f"{len(metrics)} metric snapshots "
                  f"(pass --top to span table for details)")
        if args.assert_attribution is not None:
            frac = tot["attributed_fraction"]
            if frac < args.assert_attribution:
                print(f"FAIL: attributed fraction {frac:.3f} < "
                      f"{args.assert_attribution}")
                rc = 1
            else:
                print(f"OK: attributed fraction {frac:.3f} >= "
                      f"{args.assert_attribution}")
        if args.compare:
            base_steps, _ = load_metrics_jsonl(args.compare)
            pct = overhead_pct(steps, base_steps)
            print(f"step-path overhead vs {args.compare}: {pct:+.2f}% "
                  f"(median {median_step_wall(steps) * 1e3:.2f}ms vs "
                  f"{median_step_wall(base_steps) * 1e3:.2f}ms)")
            if args.assert_overhead is not None:
                if pct >= args.assert_overhead:
                    print(f"FAIL: overhead {pct:.2f}% >= "
                          f"{args.assert_overhead}%")
                    rc = 1
                else:
                    print(f"OK: overhead {pct:.2f}% < "
                          f"{args.assert_overhead}%")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
