"""Roofline analysis: compute / memory / collective terms per
(architecture x input shape) on the production mesh.

    compute_term    = FLOPs_per_chip / 197e12        [s]
    memory_term     = HBM_bytes_per_chip / 819e9     [s]
    collective_term = collective_bytes_per_chip / 50e9 [s]

FLOPs/bytes come from segment-composed ``cost_analysis`` of the compiled
dry-run pieces (scan trip counts folded in — see segments.py); collective
bytes from the partitioned HLO text. MODEL_FLOPS is the analytic
6·N_active·T (train) / 2·N_active·T (inference) divided across chips —
its ratio to compiled FLOPs exposes remat/masking/dispatch waste.
"""
from __future__ import annotations

import os

if __name__ == "__main__":  # standalone: fake the 512 hosts before jax init
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=512")

import json
from typing import Dict, Optional

from repro.analysis.segments import compose
from repro.configs import INPUT_SHAPES, get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS,
                               make_production_mesh)
from repro.models.registry import build_model


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs per step (global, all chips)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # decode: one token


def roofline(arch: str, shape_id: str, *, multi_pod: bool = False,
             rules: Optional[dict] = None) -> Dict:
    cfg = get_config(arch)
    model = build_model(cfg)
    shape = INPUT_SHAPES[shape_id]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    if rules is None:
        rules = cfg.rules(shape.kind)
    with shd.use_mesh(mesh, rules):
        comp = compose(model, shape)
    t = comp["total"]
    terms = {
        "compute_s": t["flops"] / PEAK_FLOPS,
        "memory_s": t["bytes"] / HBM_BW,
        "collective_s": t["coll_bytes"] / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape) / chips
    rec = {
        "arch": arch, "shape": shape_id,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "flops_per_chip": t["flops"],
        "bytes_per_chip": t["bytes"],
        "coll_bytes_per_chip": t["coll_bytes"],
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops_per_chip": mf,
        "useful_flops_ratio": round(mf / t["flops"], 4) if t["flops"] else 0,
        "segments": comp["segments"],
    }
    return rec


def measured_copy_bandwidth(nbytes: int = 1 << 26, iters: int = 5) -> float:
    """Measured memory-copy bandwidth of this host in bytes/s (2x the
    copied size: one read + one write stream). The replay roofline's
    denominator on CPU backends, where the training state lives in host
    RAM; on an accelerator backend use :data:`HBM_BW` instead."""
    import time as _time

    import numpy as np
    src = np.ones(nbytes, np.uint8)
    dst = np.empty_like(src)
    ts = []
    for _ in range(iters):
        t0 = _time.perf_counter()
        np.copyto(dst, src)
        ts.append(_time.perf_counter() - t0)
    return 2.0 * nbytes / float(np.median(ts))


def replay_roofline(state_bytes: int, payload_bytes: int, n_diffs: int,
                    bandwidth: Optional[float] = None) -> Dict:
    """Memory-bandwidth lower bound for replaying ``n_diffs``
    differentials through a stateful optimizer: each step must read and
    write the full optimizer state (params + both f32 moments) once and
    read its compressed payload — nothing less recovers Adam exactly.
    ``payload_bytes`` is per differential."""
    bw = bandwidth if bandwidth else (
        HBM_BW if os.environ.get("REPRO_ACCEL") else
        measured_copy_bandwidth())
    traffic = n_diffs * (2 * state_bytes + payload_bytes)
    return {"traffic_bytes": int(traffic), "bandwidth": float(bw),
            "min_seconds": traffic / bw}


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from repro.configs import ASSIGNED_ARCHS
    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)

    results = []
    if args.out and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}
    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    for arch in archs:
        for shape_id in shapes:
            if (arch, shape_id, mesh_name) in done:
                continue
            try:
                rec = roofline(arch, shape_id, multi_pod=args.multi_pod)
            except Exception as e:  # noqa: BLE001
                import traceback
                rec = {"arch": arch, "shape": shape_id, "mesh": mesh_name,
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc(limit=4)}
            if "error" in rec:
                print(f"[FAIL] {arch:24s} {shape_id:12s} {rec['error']}",
                      flush=True)
            else:
                print(f"[OK ] {arch:24s} {shape_id:12s} "
                      f"comp={rec['compute_s'] * 1e3:8.2f}ms "
                      f"mem={rec['memory_s'] * 1e3:8.2f}ms "
                      f"coll={rec['collective_s'] * 1e3:8.2f}ms "
                      f"dom={rec['dominant']:10s} "
                      f"useful={rec['useful_flops_ratio']:.2f}", flush=True)
            results.append(rec)
            if args.out:
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                json.dump(results, open(args.out, "w"), indent=1)


if __name__ == "__main__":
    main()
