"""Roofline / HLO cost analysis of the sharded training step."""
