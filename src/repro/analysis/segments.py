"""Segment-composed cost accounting for the roofline analysis.

XLA's ``cost_analysis`` counts a while-loop (scan) body exactly once, so
a full train step with layers/microbatches scanned massively undercounts
FLOPs. The composer therefore lowers each *segment* of the step
separately — one layer fwd+bwd, the embed/loss head, the optimizer, the
compression pass — with the production shardings and all inner scans
unrolled, then multiplies per-segment costs by their static trip counts:

    total = Σ_seg count(seg) × cost(lower(seg))

Validated against a fully-unrolled small-arch lowering in
tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_stats import collective_bytes
from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.distributed.step_builder import (batch_shardings, compress_sharded,
                                            effective_accum, param_shardings)
from repro.models import encdec, lm, ops
from repro.models.param import ParamSpec, is_spec
from repro.optim.adam import AdamState, adam_init, adam_update


@dataclasses.dataclass
class Segment:
    name: str
    count: int                  # static trip count in the real step
    fn: Callable                # positional fn to jit+lower
    args: tuple                 # ShapeDtypeStructs (sharded)


def _sds(shape, dtype, logical):
    ctx = shd.current()
    spec = shd.safe_spec(shape, ctx.spec(logical), ctx.mesh)
    from jax.sharding import NamedSharding
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(ctx.mesh, spec))


def _layer_params_abs(layer_specs_tree, pdtype):
    """Single-layer abstract params: strip the leading 'layers' dim."""
    ctx = shd.current()
    from jax.sharding import NamedSharding

    def one(s: ParamSpec):
        shape, logical = s.shape[1:], s.logical[1:]
        dt = jnp.dtype(s.dtype) if s.dtype else pdtype
        spec = shd.safe_spec(shape, ctx.spec(logical), ctx.mesh)
        return jax.ShapeDtypeStruct(shape, dt,
                                    sharding=NamedSharding(ctx.mesh, spec))

    return jax.tree.map(one, layer_specs_tree, is_leaf=is_spec)


def _grad_of(block_fn):
    """fwd+bwd of a rematerialized block, as in the real scan body."""
    blk = jax.checkpoint(block_fn)

    def f(lp, h, *rest):
        def loss(lp, h):
            out = blk(lp, h, *rest)
            out0 = out[0] if isinstance(out, tuple) else out
            extra = (out[1].astype(jnp.float32)
                     if isinstance(out, tuple) and out[1] is not None
                     and getattr(out[1], "ndim", 1) == 0 else 0.0)
            return jnp.sum(out0.astype(jnp.float32)) * 1e-6 + extra
        return jax.value_and_grad(loss, argnums=(0, 1))(lp, h)

    return f


# --------------------------------------------------------------------------
# per-shape segment builders
# --------------------------------------------------------------------------

def train_segments(model, shape: ShapeConfig) -> List[Segment]:
    cfg: ArchConfig = model.cfg
    ctx = shd.current()
    dp = 1
    for a in ("pod", "data"):
        if a in ctx.mesh.axis_names:
            dp *= ctx.mesh.devices.shape[ctx.mesh.axis_names.index(a)]
    accum = effective_accum(cfg.grad_accum, shape.global_batch, dp)
    Bm = shape.global_batch // accum
    S = shape.seq_len
    cdt = cfg.cdtype()
    h = _sds((Bm, S, cfg.d_model), cdt, ("batch", "residual_seq", None))
    positions = jnp.arange(S)
    segs: List[Segment] = []

    if cfg.arch_type == "audio":
        Ss = encdec.src_len(cfg, S)
        he = _sds((Bm, Ss, cfg.d_model), cdt, ("batch", "residual_seq", None))
        mem = _sds((Bm, Ss, cfg.d_model), cdt, ("batch", None, None))
        enc_lp = _layer_params_abs(model.specs["enc_layers"], cfg.pdtype())
        dec_lp = _layer_params_abs(model.specs["dec_layers"], cfg.pdtype())
        pe = jnp.arange(Ss)
        segs.append(Segment(
            "enc_layer", cfg.n_encoder_layers * accum,
            _grad_of(lambda lp, x: encdec.enc_block(lp, x, cfg, pe)),
            (enc_lp, he)))
        segs.append(Segment(
            "dec_layer", cfg.n_layers * accum,
            _grad_of(lambda lp, x, m: encdec.dec_block(lp, x, m, cfg,
                                                       positions)),
            (dec_lp, h, mem)))
    elif cfg.arch_type == "ssm":
        pair_lp = _layer_params_abs(model.specs["layers"], cfg.pdtype())

        def pair(lp, x):
            from repro.models import xlstm as _x
            x = _x.mlstm_apply(lp["mlstm"], x, cfg)
            return _x.slstm_apply(lp["slstm"], x, cfg)

        # xLSTM block cost is linear in S (fixed mLSTM chunk width, one
        # sLSTM step per token): lower at S'=256 with the sequential scan
        # unrolled and scale the count by S/S'.
        Sp = min(S, 256)
        hp = _sds((Bm, Sp, cfg.d_model), cdt,
                  ("batch", "residual_seq", None))
        segs.append(Segment("xlstm_pair",
                            (cfg.n_layers // 2) * accum * (S // Sp),
                            _grad_of(pair), (pair_lp, hp)))
    else:
        lp = _layer_params_abs(model.specs["layers"], cfg.pdtype())
        wins = lm.layer_windows(cfg)
        uniq, counts = np.unique(wins, return_counts=True)
        for w, c in zip(uniq.tolist(), counts.tolist()):
            segs.append(Segment(
                f"layer_w{w}", int(c) * accum,
                _grad_of(lambda lpp, x, _w=w: lm._std_block(
                    lpp, x, cfg, positions, _w)),
                (lp, h)))

    # embed (gather fwd + scatter-add bwd)
    V = cfg.vocab
    emb = _sds((V, cfg.d_model), cfg.pdtype(), ("vocab", "embed"))
    toks = _sds((Bm, S), jnp.int32, ("batch", None))

    def embed_seg(emb, toks):
        def loss(emb):
            return jnp.sum(emb.astype(cdt)[toks].astype(jnp.float32)) * 1e-6
        return jax.value_and_grad(loss)(emb)

    segs.append(Segment("embed", accum, embed_seg, (emb, toks)))

    # loss head: single-chunk xent fwd+bwd (S folded into one chunk)
    wlm = _sds((cfg.d_model, V), cfg.pdtype(), ("embed", "vocab"))
    tgt = _sds((Bm, S), jnp.int32, ("batch", None))

    def head_seg(h, wlm, tgt):
        def loss(h, wlm):
            tot, cnt = ops.chunked_softmax_xent(h, wlm, tgt,
                                                chunk=cfg.loss_chunk)
            return tot / jnp.maximum(cnt, 1.0)
        return jax.value_and_grad(loss, argnums=(0, 1))(h, wlm)

    segs.append(Segment("loss_head", accum, head_seg, (h, wlm, tgt)))

    # optimizer (full tree, once per step)
    psh = param_shardings(model)
    abs_p = jax.tree.map(
        lambda sds, s: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=s),
        model.abstract_params(), psh)
    abs_g = jax.tree.map(
        lambda sds, s: jax.ShapeDtypeStruct(sds.shape, jnp.float32,
                                            sharding=s),
        model.abstract_params(), psh)
    abs_opt = AdamState(abs_g, jax.tree.map(lambda x: x, abs_g),
                        jax.ShapeDtypeStruct((), jnp.int32))

    def opt_seg(p, g, o):
        return adam_update(p, g, o, lr=1e-3)

    segs.append(Segment("optimizer", 1, opt_seg, (abs_p, abs_g, abs_opt)))

    # LowDiff shard-local compression (once per step)
    pspecs = jax.tree.map(lambda s: s.spec, psh)
    mesh = ctx.mesh

    def comp_seg(g):
        return compress_sharded(g, pspecs, mesh, 0.01)

    segs.append(Segment("compress", 1, comp_seg, (abs_g,)))
    return segs


def prefill_segments(model, shape: ShapeConfig) -> List[Segment]:
    cfg: ArchConfig = model.cfg
    B, S = shape.global_batch, shape.seq_len
    cdt = cfg.cdtype()
    h = _sds((B, S, cfg.d_model), cdt, ("batch", "residual_seq", None))
    positions = jnp.arange(S)
    segs: List[Segment] = []
    if cfg.arch_type == "audio":
        Ss = encdec.src_len(cfg, S)
        he = _sds((B, Ss, cfg.d_model), cdt, ("batch", "residual_seq", None))
        mem = _sds((B, Ss, cfg.d_model), cdt, ("batch", None, None))
        enc_lp = _layer_params_abs(model.specs["enc_layers"], cfg.pdtype())
        dec_lp = _layer_params_abs(model.specs["dec_layers"], cfg.pdtype())
        pe = jnp.arange(Ss)
        segs.append(Segment("enc_layer", cfg.n_encoder_layers,
                            lambda lp, x: encdec.enc_block(lp, x, cfg, pe),
                            (enc_lp, he)))
        segs.append(Segment("dec_layer", cfg.n_layers,
                            lambda lp, x, m: encdec.dec_block(
                                lp, x, m, cfg, positions),
                            (dec_lp, h, mem)))
    elif cfg.arch_type == "ssm":
        pair_lp = _layer_params_abs(model.specs["layers"], cfg.pdtype())

        def pair(lp, x):
            from repro.models import xlstm as _x
            x = _x.mlstm_apply(lp["mlstm"], x, cfg)
            return _x.slstm_apply(lp["slstm"], x, cfg)

        Sp = min(S, 256)
        hp = _sds((B, Sp, cfg.d_model), cdt, ("batch", "residual_seq", None))
        segs.append(Segment("xlstm_pair", (cfg.n_layers // 2) * (S // Sp),
                            pair, (pair_lp, hp)))
    else:
        lp = _layer_params_abs(model.specs["layers"], cfg.pdtype())
        wins = lm.layer_windows(cfg)
        uniq, counts = np.unique(wins, return_counts=True)
        for w, c in zip(uniq.tolist(), counts.tolist()):
            segs.append(Segment(
                f"layer_w{w}", int(c),
                lambda lpp, x, _w=w: lm._std_block(lpp, x, cfg,
                                                   positions, _w)[0],
                (lp, h)))
    # final-position lm head
    V = cfg.vocab
    wlm = _sds((cfg.d_model, V), cfg.pdtype(), ("embed", "vocab"))
    hl = _sds((B, cfg.d_model), cdt, ("batch", None))
    segs.append(Segment(
        "lm_head", 1,
        lambda x, w: jnp.einsum("bd,dv->bv", x, w.astype(x.dtype),
                                preferred_element_type=jnp.float32),
        (hl, wlm)))
    return segs


def decode_segments(model, shape: ShapeConfig) -> List[Segment]:
    cfg: ArchConfig = model.cfg
    B = shape.global_batch
    seq_len = shape.seq_len
    cdt = cfg.cdtype()
    h = _sds((B, 1, cfg.d_model), cdt, ("batch", None, None))
    pos = jnp.asarray(seq_len - 1, jnp.int32)
    segs: List[Segment] = []
    cache_abs = model.init_cache(B, seq_len, abstract=True)
    cache_sh = shd.safe_sharding_tree(cache_abs, model.cache_logical())

    def strip(t_abs, t_sh):
        # single-layer slice of a stacked (L, ...) cache leaf
        return jax.tree.map(
            lambda sds, s: jax.ShapeDtypeStruct(
                sds.shape[1:], sds.dtype,
                sharding=type(s)(s.mesh,
                                 type(s.spec)(*tuple(s.spec)[1:]))),
            t_abs, t_sh)

    Lc = lm.cache_len(cfg, seq_len)
    ring = Lc < seq_len
    if cfg.arch_type == "ssm":
        pair_lp = _layer_params_abs(model.specs["layers"], cfg.pdtype())
        mc = strip(cache_abs.mlstm, cache_sh.mlstm)
        sc = strip(cache_abs.slstm, cache_sh.slstm)
        segs.append(Segment(
            "xlstm_pair_decode", cfg.n_layers // 2,
            lambda lp, x, m, s: lm.ssm_decode_block(lp, x, cfg, m, s),
            (pair_lp, h, mc, sc)))
    elif cfg.arch_type == "audio":
        dec_lp = _layer_params_abs(model.specs["dec_layers"], cfg.pdtype())
        ck = strip(cache_abs.k, cache_sh.k)
        cv = strip(cache_abs.v, cache_sh.v)
        xk = strip(cache_abs.cross_k, cache_sh.cross_k)
        xv = strip(cache_abs.cross_v, cache_sh.cross_v)
        segs.append(Segment(
            "dec_layer_decode", cfg.n_layers,
            lambda lp, x, a, b, c, d: encdec.dec_decode_block(
                lp, x, cfg, a, b, c, d, pos, ring),
            (dec_lp, h, ck, cv, xk, xv)))
    else:
        lp = _layer_params_abs(model.specs["layers"], cfg.pdtype())
        ck = strip(cache_abs.k, cache_sh.k)
        cv = strip(cache_abs.v, cache_sh.v)
        wins = lm.layer_windows(cfg)
        uniq, counts = np.unique(wins, return_counts=True)
        if cfg.arch_type == "hybrid":
            mam = strip(cache_abs.mamba, cache_sh.mamba)
            for w, c in zip(uniq.tolist(), counts.tolist()):
                segs.append(Segment(
                    f"layer_decode_w{w}", int(c),
                    lambda lpp, x, a, b, m, _w=w: lm.decode_block(
                        lpp, x, cfg, a, b, pos, window=_w, ring=ring, mam=m),
                    (lp, h, ck, cv, mam)))
        else:
            for w, c in zip(uniq.tolist(), counts.tolist()):
                segs.append(Segment(
                    f"layer_decode_w{w}", int(c),
                    lambda lpp, x, a, b, _w=w: lm.decode_block(
                        lpp, x, cfg, a, b, pos, window=_w, ring=ring)[:3],
                    (lp, h, ck, cv)))
    V = cfg.vocab
    wlm = _sds((cfg.d_model, V), cfg.pdtype(), ("embed", "vocab"))
    hl = _sds((B, cfg.d_model), cdt, ("batch", None))
    segs.append(Segment(
        "lm_head", 1,
        lambda x, w: jnp.einsum("bd,dv->bv", x, w.astype(x.dtype),
                                preferred_element_type=jnp.float32),
        (hl, wlm)))
    return segs


def segments_for(model, shape: ShapeConfig) -> List[Segment]:
    if shape.kind == "train":
        return train_segments(model, shape)
    if shape.kind == "prefill":
        return prefill_segments(model, shape)
    return decode_segments(model, shape)


# --------------------------------------------------------------------------
# lowering + accounting
# --------------------------------------------------------------------------

def normalize_cost_analysis(ca) -> Dict[str, float]:
    """jax >= 0.5 returns one dict; jax <= 0.4.x one dict per device."""
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca


def measure_segment(seg: Segment) -> Dict[str, float]:
    ops.set_analysis_unroll(True)
    try:
        compiled = jax.jit(seg.fn).lower(*seg.args).compile()
    finally:
        ops.set_analysis_unroll(False)
    ca = normalize_cost_analysis(compiled.cost_analysis())
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll_bytes": float(coll.get("total", 0)),
            "coll_count": int(coll.get("count", 0))}


def compose(model, shape: ShapeConfig) -> Dict:
    """Per-device composed cost over all segments."""
    segs = segments_for(model, shape)
    total = {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0}
    detail = []
    for seg in segs:
        m = measure_segment(seg)
        for k in total:
            total[k] += m[k] * seg.count
        detail.append({"segment": seg.name, "count": seg.count, **m})
    return {"total": total, "segments": detail}
