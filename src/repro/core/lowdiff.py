"""LowDiff: frequent differential checkpointing by compressed-gradient reuse.

Orchestrates the paper's architecture (Fig. 5): the jitted training step
emits the synchronized compressed gradient G̃_t; it is handed zero-copy to
the Reusing Queue; a background checkpointing thread drains the queue,
offloads to host memory (step ① of §V-B), batches b differentials
(step ②) and persists each batch in a single I/O (step ③). The model
state is checkpointed in full every `full_interval` steps,
asynchronously. (f, b) come from the Eq. (10) optimum unless overridden,
and the online tuner keeps re-solving Eq. (10) from observed merge
times after every batch write (§VII's optimal-configuration module) —
auto dimensions track the solution, pinned ones only record it.

Recovery (Algorithm 1 / §VII): load the latest full checkpoint, replay
the differential chain through Adam — serially or with the exact
log-depth parallel replay.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional

import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.core import recovery as rec
from repro.core.config_opt import OnlineTuner, SystemParams, practical_config
from repro.core.reusing_queue import (CheckpointingError, ReusingQueue,
                                      wait_drained)
from repro.core.snapshot import SnapshotArena, host_copy  # noqa: F401
from repro.core.steps import make_train_step
from repro.obs.timeline import TIMELINE
from repro.obs.trace import trace_span


def _payload_nbytes(payloads) -> int:
    """Host bytes of a batch of compressed differentials (what the
    batched write actually moves — the tuner history's bytes input)."""
    import jax
    return int(sum(getattr(leaf, "nbytes", 0) or 0
                   for p in payloads for leaf in jax.tree.leaves(p)))


class LowDiff:
    """Checkpointing strategy object. One per training job."""

    name = "lowdiff"

    def __init__(self, model, store: CheckpointStore, *, rho: float = 0.01,
                 lr: float = 1e-3, full_interval: Optional[int] = None,
                 batch_size: Optional[int] = None,
                 sys_params: Optional[SystemParams] = None,
                 batch_mode: str = "concat", queue_size: int = 4,
                 parallel_recovery: bool = True,
                 error_feedback: bool = True, compressor: str = "topk",
                 flush_timeout: float = 120.0,
                 replay_window: Optional[int] = None,
                 replay_device: bool = False,
                 snapshot_shards: int = 4):
        self.model, self.store = model, store
        self.rho, self.lr = rho, lr
        if compressor == "quant8":
            error_feedback = False
        self.batch_mode = batch_mode
        self.parallel_recovery = parallel_recovery
        #: bound on differentials per parallel-replay scan window (peak
        #: replay memory is O(window * model), not O(chain * model))
        self.replay_window = replay_window
        #: device-resident recovery: replay the chain as a jitted scan
        #: over the *compressed* payloads (fused decompress-and-apply
        #: kernels) instead of host-decoding each differential
        self.replay_device = replay_device
        #: >0: full-state snapshots issue per-shard D2H transfers that
        #: overlap the still-running step; 0: legacy whole-tree batch
        self.snapshot_shards = snapshot_shards
        self.flush_timeout = flush_timeout
        self.tuner = OnlineTuner(sys_params or SystemParams())
        fi, bs = practical_config(self.tuner.p)
        # an explicit (f, b) pins the config; None means "start at the
        # Eq. (10) optimum and let the online tuner keep re-solving it"
        self._auto_full_interval = full_interval is None
        self._auto_batch_size = batch_size is None
        self.full_interval = full_interval or fi
        self.batch_size = batch_size or bs
        self.queue = ReusingQueue(maxsize=queue_size)
        # double-buffered D2H snapshot permits: the full-state snapshot
        # overlaps the next training step; a persist tier more than two
        # snapshots behind backpressures instead of hoarding host copies
        self._arena = SnapshotArena(slots=2)
        self.step_fn = make_train_step(model, mode="lowdiff", rho=rho, lr=lr,
                                       error_feedback=error_feedback,
                                       compressor=compressor)
        self._buffer: List[Any] = []  # [(step, host payload)]
        # consumer thread appends, flush() (caller thread) swaps — the
        # buffer is a cross-thread structure and must be locked
        self._buffer_lock = threading.Lock()
        self._persist_pool = ThreadPoolExecutor(max_workers=2,
                                                thread_name_prefix="persist")
        self._pending: List[Future] = []
        self._consumer: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._step_counter: Optional[int] = None
        self._processed = 0          # differentials fully handled
        # bounded: one entry per batch flush would leak memory over a
        # multi-million-step per-iteration-checkpointing run
        self._tuning_history: "deque[Dict[str, Any]]" = deque(maxlen=256)
        self.tuning_resolves = 0
        self.tuning_applied = 0
        self.ckpt_time = 0.0         # time spent inside the training loop
        self.full_saves = 0

    # ------------------------------------------------------------------
    # checkpointing process (background thread)
    # ------------------------------------------------------------------
    def _start_consumer(self):
        if self.queue.error is not None:
            # never restart over a poisoned queue: the failed batch is
            # lost, and persisting later ones would durably write a
            # chain with a hole that recovery cannot detect
            raise CheckpointingError(
                "checkpointing consumer previously failed; differentials "
                "were lost") from self.queue.error
        if self._consumer is None or not self._consumer.is_alive():
            self._stop.clear()
            self._consumer = threading.Thread(
                target=self.queue.drain, args=(self._handle, self._stop),
                daemon=True, name="lowdiff-ckpt")
            self._consumer.start()

    def _handle(self, step: int, cg):
        """Step ①: offload to CPU memory (frees the device buffer)."""
        with trace_span("ckpt.offload", "persist", step=step):
            host_cg = host_copy(cg)
        del cg
        with self._buffer_lock:
            self._buffer.append((step, host_cg))
            full = len(self._buffer) >= self.batch_size
        # Step ②/③: batch then persist in one I/O
        if full:
            self._flush_batch()
        self._processed += 1

    def _flush_batch(self):
        with self._buffer_lock:
            if not self._buffer:
                return
            buf, self._buffer = self._buffer, []
        t0 = time.perf_counter()
        with trace_span("persist.batch", "persist", n=len(buf),
                        first=buf[0][0], last=buf[-1][0]):
            self.store.save_batch(buf[0][0], buf[-1][0],
                                  [p for _, p in buf], mode=self.batch_mode)
        merge_t = (time.perf_counter() - t0) / max(len(buf), 1)
        self.tuner.observe_merge_time(merge_t)
        batch_bytes = _payload_nbytes([p for _, p in buf])
        self._apply_tuning(merge_time_s=merge_t, batch_bytes=batch_bytes)

    def _apply_tuning(self, **inputs):
        """Close the paper's §VII adaptation loop: re-solve Eq. (10)
        with the tuner's updated constants after each batch write and
        apply the new (f, b) to the dimensions the caller left on auto.
        Explicitly pinned dimensions are still recorded, so stats()
        shows what the tuner *would* choose.

        Each history entry carries the *inputs* the decision saw
        (observed stall fraction, merge time, batch bytes) so
        ``stats()["tuning"]`` is auditable — a (f, b) move can be
        traced back to the measurement that caused it. Entries ride
        the same bounded deque as before."""
        stall = TIMELINE.stall_fraction()
        self.tuner.observe_stall_fraction(stall)
        interval, b = self.tuner.current()
        applied = False
        if self._auto_full_interval and interval != self.full_interval:
            self.full_interval = interval
            applied = True
        if self._auto_batch_size and b != self.batch_size:
            self.batch_size = b
            applied = True
        if applied:
            self.tuning_applied += 1
        self.tuning_resolves += 1
        self._tuning_history.append(
            {"step": self._step_counter, "full_interval": interval,
             "batch_size": b, "applied": applied,
             "stall_fraction": round(self.tuner.stall_fraction, 6),
             **{k: (round(v, 6) if isinstance(v, float) else v)
                for k, v in inputs.items()}})

    # ------------------------------------------------------------------
    # training process hooks
    # ------------------------------------------------------------------
    def train_step(self, state, batch):
        if self._step_counter is None:
            self._step_counter = int(state["step"])   # one-time sync
        state, metrics, cg = self.step_fn(state, batch)
        t0 = time.perf_counter()
        self._step_counter += 1
        step = self._step_counter   # host-side: never forces the device
        self._start_consumer()
        blocked = self.queue.put(step, cg)    # zero-copy hand-off
        TIMELINE.charge("queue_backpressure", blocked)
        if step % self.full_interval == 0:
            # async snapshot: only enqueue the D2H transfers here — the
            # wait for the bytes (and the write) happens on the persist
            # thread, overlapped with the next training step; sharded
            # mode additionally releases each shard's buffers as its
            # bytes land instead of pinning the whole model copy
            if self.snapshot_shards > 0:
                pending = self._arena.snapshot_sharded_async(
                    state, shards=self.snapshot_shards)
            else:
                pending = self._arena.snapshot_async(state)
            self._pending.append(
                self._persist_pool.submit(self._persist_full, step, pending))
            self.full_saves += 1
        self.ckpt_time += time.perf_counter() - t0
        return state, metrics

    def _persist_full(self, step: int, pending):
        try:
            with trace_span("persist.full", "persist", step=step):
                self.store.save_full(step, pending.result())
        finally:
            pending.release()

    def flush(self, timeout: Optional[float] = None):
        """Block until every queued differential/full write is durable
        (including the storage backend's own async tiers) and every
        pending maintenance slice has drained.

        Never hangs: a handler exception on the consumer thread is
        re-raised here as :class:`~repro.core.reusing_queue.
        CheckpointingError`, the wait is bounded by ``timeout`` (default
        ``flush_timeout``), and the store-level flush — including the
        maintenance drain — shares the same deadline budget."""
        t = timeout if timeout is not None else self.flush_timeout
        deadline = time.monotonic() + t
        t0 = time.perf_counter()
        with trace_span("ckpt.flush", "persist"):
            wait_drained(self.queue, lambda: self._processed,
                         self._consumer, t)
            self._flush_batch()
            for f in self._pending:
                f.result()
            self._pending.clear()
            self.store.flush(timeout=max(0.0, deadline - time.monotonic()))
        TIMELINE.event("flush_stall", time.perf_counter() - t0,
                       step=self._step_counter)

    def close(self):
        try:
            self.flush()
        finally:
            self._stop.set()
            self.queue.close()
            if self._consumer is not None:
                self._consumer.join(timeout=5)
            self._persist_pool.shutdown(wait=True)
            self.store.close()

    # ------------------------------------------------------------------
    # recovery process
    # ------------------------------------------------------------------
    def recover(self):
        """Returns (state, replayed_steps). Raises if no checkpoint.
        Works against any storage backend — the chain loader delegates
        shard re-assembly / tier lookup to the store's backend."""
        t_rec = time.perf_counter()
        with trace_span("recovery.load_chain", "recovery"):
            state, diffs = rec.load_latest_chain(self.store)
        # LowDiff writes one differential per iteration: cut the chain
        # at the first step gap (a write-back hole) rather than replay
        # across it into silently wrong state
        diffs = rec.contiguous_prefix(int(state["step"]), diffs)
        with trace_span("recovery.replay", "recovery", n=len(diffs),
                        mode=("device" if self.replay_device else
                              "parallel" if self.parallel_recovery
                              else "serial")):
            if self.replay_device:
                params, opt, applied = rec.replay_device(
                    state["params"], state["opt"], diffs, lr=self.lr,
                    window=self.replay_window)
            elif self.parallel_recovery:
                params, opt, applied = rec.replay_parallel(
                    state["params"], state["opt"], diffs, lr=self.lr,
                    window=self.replay_window)
            else:
                params, opt = rec.replay_serial(state["params"],
                                                state["opt"],
                                                diffs, lr=self.lr)
                applied = len(diffs)
        TIMELINE.event("recovery", time.perf_counter() - t_rec,
                       step=self._step_counter)
        state["params"], state["opt"] = params, opt
        if applied:
            # a payload that failed to decode cut the chain early; the
            # state is consistent as of the last *applied* differential
            state["step"] = np.asarray(diffs[applied - 1][0], np.int32)
        # NOTE: the error-feedback state stored in the full checkpoint is
        # stale by `len(diffs)` steps; exact-resume tests therefore compare
        # params/opt. (The paper has the same property: EF lives only in
        # the training process.)
        return state, applied

    def stats(self) -> Dict[str, Any]:
        from repro.checkpoint.io import COPY_METER
        return {"queue": self.queue.stats(), "store": self.store.stats(),
                "snapshot_arena": self._arena.stats(),
                "copy_meter": COPY_METER.stats(),
                "replay_device": self.replay_device,
                "snapshot_shards": self.snapshot_shards,
                "full_interval": self.full_interval,
                "batch_size": self.batch_size,
                "tuning": {"auto": {"full_interval": self._auto_full_interval,
                                    "batch_size": self._auto_batch_size},
                           "applied": self.tuning_applied,
                           "resolves": self.tuning_resolves,
                           "history": list(self._tuning_history),
                           "params": dataclasses.asdict(self.tuner.p)},
                "train_loop_ckpt_time": self.ckpt_time,
                "full_saves": self.full_saves,
                "timeline": TIMELINE.stats()}
