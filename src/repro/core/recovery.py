"""Recovery: differential replay (Algorithm 1) — serial and parallel.

Serial replay applies each differential through Adam in sequence:
``M_{j+1} = M_j + Adam(G_j)`` — n optimizer merges for n differentials.

Parallel recovery (paper §VII, Fig. 10) merges in log(n) depth. The
paper's pairwise merge is exact only for *state-delta* differentials
(Naïve DC); LowDiff differentials are gradients that pass through a
*stateful* optimizer. TPU/JAX adaptation: Adam's moment recurrences are
affine, so we parallelize them *exactly* with an associative scan
(log-depth, MXU-free elementwise work) — all intermediate (mu_j, nu_j)
drop out of one ``lax.associative_scan``, every step's param delta is then
computed in parallel, and a single sum produces M_n. This is the paper's
log(n) recovery without its approximation.

Device-resident replay (``replay_device``) goes one step further: the
compressed payloads themselves are staged to the device — a fraction of
the dense bytes over the interconnect — and a single jitted
``lax.scan`` decodes and applies each differential with the fused
decompress-and-apply kernels (``kernels.replay``); no dense gradient
stack ever exists on host or in HBM, and window N+1's payloads upload
while window N scans (double-buffered H2D staging).
"""
from __future__ import annotations

import functools
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compression.sparse import SparseGrad
from repro.optim.adam import AdamState


def load_latest_chain(store):
    """Load the newest full checkpoint and the ordered differentials
    after it from whatever storage backend the store wraps (the backend
    re-assembles sharded leaves, hits the memory tier, or fetches and
    checksum-verifies remote chunks transparently).

    A full checkpoint that cannot be read back — missing blob, a
    corrupt frame (leaf sha256 mismatch), or a remote tier whose
    bounded re-fetches never produced checksum-clean chunks — does not
    abort recovery: the loader falls back to the next older full and
    replays the longer differential chain from there. Entries the
    maintenance scrubber quarantined were already removed from the
    manifest's chain kinds, so they are skipped proactively without
    touching storage at all.

    The fallback order is *source-aware* (``order_fulls``): fulls are
    preferred by the state they actually represent (``state_step``),
    then by nominal step, then by the durability of the tier that
    recorded them (durable > memory > peer). On a replacement host the
    peer-adopted entries are typically the ONLY entries — peer-first
    recovery at network speed — while on a host whose durable storage
    survived, a stale peer-served replica can never shadow a newer
    durable full. Returns (state, [(step, payload), ...]); raises
    FileNotFoundError when no full checkpoint is loadable."""
    from repro.checkpoint.io import FrameCorruptionError
    from repro.checkpoint.remote import RetryExhaustedError
    from repro.checkpoint.store import order_fulls
    fulls = order_fulls(store.manifest["fulls"])
    if not fulls:
        raise FileNotFoundError("no full checkpoint")
    last_err = None
    for entry in fulls:
        try:
            state = store.load_full(entry)
        except (FileNotFoundError, RetryExhaustedError,
                FrameCorruptionError) as e:
            last_err = e
            continue
        return state, store.diffs_after(entry["step"])
    raise FileNotFoundError(
        f"none of {len(fulls)} full checkpoints is loadable "
        f"(last error: {last_err})")


def contiguous_prefix(start: int, diffs: List[Tuple[int, Any]],
                      stride: int = 1) -> List[Tuple[int, Any]]:
    """Longest prefix of ``diffs`` whose steps advance by ``stride``
    from ``start``. Replaying *past* a hole — a differential whose
    async write-back never landed before the crash, leaving a
    mid-chain gap that ``_prune_missing`` (which assumes missing blobs
    are a FIFO suffix) cannot repair — would silently corrupt the
    recovered state, so callers that know their differential cadence
    cut the chain at the first gap and recover to the last provably
    consistent step instead. LowDiff emits one differential per
    iteration, hence stride 1; strategies with a sparser cadence pass
    their own stride."""
    out = []
    expect = start + stride
    for s, p in diffs:
        if s != expect:
            break
        out.append((s, p))
        expect = s + stride
    return out


def _is_compressed(x):
    from repro.compression.packed import PackedDiff
    from repro.compression.quant import QuantGrad
    return isinstance(x, (SparseGrad, QuantGrad, PackedDiff))


def maybe_decompress(payload):
    leaves = jax.tree.leaves(payload, is_leaf=_is_compressed)
    if any(_is_compressed(l) for l in leaves):
        return jax.tree.map(lambda l: l.dense() if _is_compressed(l) else l,
                            payload, is_leaf=_is_compressed)
    return payload


def _use_pallas() -> bool:
    # Pallas kernels compile natively on TPU; on CPU (interpret mode is
    # trace-speed) the jnp oracles inside the same jitted program are
    # the fast path and compute identical bits.
    return jax.default_backend() == "tpu"


def _fused_step(params, mu, nu, hyper, payload, use_pallas: bool):
    """Apply one differential — still in wire form — to every leaf via
    the fused decompress-and-apply kernels. Shared by serial replay and
    the device-resident scan so the two are bit-identical."""
    from repro.kernels import ops
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = jax.tree.leaves(payload, is_leaf=_is_compressed)
    if len(g_leaves) != len(p_leaves):
        raise ValueError(
            f"differential has {len(g_leaves)} leaves, model has "
            f"{len(p_leaves)}")
    out = [ops.fused_decode_apply(g, p, m, v, hyper, use_pallas=use_pallas)
           for g, p, m, v in zip(g_leaves, p_leaves,
                                 jax.tree.leaves(mu), jax.tree.leaves(nu))]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]),
            jax.tree.unflatten(treedef, [o[2] for o in out]))


def replay_serial(params, opt: AdamState, diffs: List[Tuple[int, Any]], *,
                  lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    """Apply each differential in order. diffs: [(step, payload)].

    Each step runs the fused decompress-and-apply path: the compressed
    payload is decoded *inside* the jitted Adam update (no dense host
    intermediate), which makes serial replay the bit-exact reference
    for ``replay_device`` — both execute the same per-element program.
    """
    from repro.kernels import ops
    mu, nu, count = opt.mu, opt.nu, opt.count
    up = _use_pallas()
    for _, payload in diffs:
        count = count + 1
        hyper = ops.adam_hyper_traced(lr, b1, b2, eps, count)
        params, mu, nu = _fused_step(params, mu, nu, hyper, payload, up)
    return params, AdamState(mu, nu, jnp.asarray(count, jnp.int32))


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps"))
def _parallel_replay(params, mu0, nu0, stacked, count0, lr, *,
                     b1=0.9, b2=0.999, eps=1e-8):
    n = jax.tree.leaves(stacked)[0].shape[0]

    def scan_moments(g, m0, beta):
        # affine recurrence x_j = beta * x_{j-1} + (1-beta) g_j as an
        # associative scan over (a, b) pairs; a broadcast to g's shape.
        a = jnp.broadcast_to(
            jnp.full((n,) + (1,) * (g.ndim - 1), beta, jnp.float32),
            g.shape)
        aa, bb = jax.lax.associative_scan(
            lambda l, r: (l[0] * r[0], r[1] + r[0] * l[1]),
            (a, (1.0 - beta) * g))
        return bb + aa * m0                         # (n, ...) moments

    counts = count0 + 1 + jnp.arange(n)
    c1 = 1.0 - b1 ** counts.astype(jnp.float32)
    c2 = 1.0 - b2 ** counts.astype(jnp.float32)

    def one(p, g, m0, v0):
        mu_j = scan_moments(g, m0, b1)
        nu_j = scan_moments(g * g, v0, b2)
        cs = (1,) * (g.ndim - 1)
        step = lr * (mu_j / c1.reshape((n,) + cs)) / (
            jnp.sqrt(nu_j / c2.reshape((n,) + cs)) + eps)
        p2 = (p.astype(jnp.float32) - step.sum(0)).astype(p.dtype)
        return p2, mu_j[-1], nu_j[-1]

    out = jax.tree.map(one, params, stacked, mu0, nu0)
    p2 = jax.tree.map(lambda t: t[0], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    mu2 = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    nu2 = jax.tree.map(lambda t: t[2], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return p2, mu2, nu2


def _decode_prefix(diffs: List[Tuple[int, Any]]):
    """Host-decode payloads in order, stopping at the first failure.
    Returns (dense grads for the longest decodable prefix, error or
    None) — ``contiguous_prefix`` semantics for *payload* corruption:
    a bad differential at position k cuts the chain at k instead of
    raising mid-replay and losing the whole recovery."""
    gs, err = [], None
    for _, payload in diffs:
        try:
            gs.append(maybe_decompress(payload))
        except Exception as e:          # decode failure, any backend
            err = e
            break
    return gs, err


def replay_parallel(params, opt: AdamState, diffs: List[Tuple[int, Any]], *,
                    lr=1e-3, b1=0.9, b2=0.999, eps=1e-8,
                    window: Optional[int] = None):
    """Exact log-depth replay via associative scan over the moment
    recurrences. Numerically identical (up to reassociation) to serial.
    The jitted kernel is cached across calls (shapes keyed).

    ``window`` bounds peak memory: instead of materializing all n
    differentials as one dense fp32 stack — O(n · model) host/device
    bytes — the scan runs over windows of at most ``window``
    differentials, carrying ``(params, mu, nu, count)`` between them.
    The moment recurrences chain exactly across the boundary (each
    window's scan is seeded with the previous window's final moments),
    so the result is numerically identical up to the same float
    reassociation the unwindowed scan already accepts. ``None`` (or 0)
    replays everything in one window.

    Each window is host-decoded *before* its scan launches; a payload
    that fails to decode cuts the chain there — the state replayed so
    far is returned rather than thrown away. Returns
    ``(params, opt, applied)`` with ``applied`` the number of
    differentials actually replayed (== ``len(diffs)`` when the whole
    chain was clean)."""
    from repro.checkpoint.io import COPY_METER
    if not diffs:
        return params, opt, 0
    if window is not None and window < 0:
        raise ValueError("window must be None or >= 0")
    w = int(window) if window else len(diffs)
    mu, nu, count = opt.mu, opt.nu, opt.count
    applied = 0
    for i in range(0, len(diffs), w):
        gs, err = _decode_prefix(diffs[i:i + w])
        if gs:
            stacked = jax.tree.map(lambda *xs: jnp.stack(
                [x.astype(jnp.float32) for x in xs]), *gs)
            COPY_METER.add_h2d(sum(l.nbytes
                                   for l in jax.tree.leaves(stacked)))
            params, mu, nu = _parallel_replay(params, mu, nu, stacked,
                                              count, jnp.float32(lr),
                                              b1=b1, b2=b2, eps=eps)
            count = count + len(gs)
            applied += len(gs)
        if err is not None:
            break
    return params, AdamState(mu, nu, count), applied


@functools.partial(jax.jit,
                   static_argnames=("b1", "b2", "eps", "use_pallas"))
def _device_replay(p_leaves, mu_leaves, nu_leaves, g_stacks, count0, lr, *,
                   b1=0.9, b2=0.999, eps=1e-8, use_pallas=False):
    """One jitted scan over a window of *compressed* payloads: each scan
    step slices one differential off the stacked wire buffers and runs
    the fused decompress-and-apply kernel per leaf — the dense gradient
    never exists outside the kernel accumulator. Leaf lists (not trees)
    because the payload containers are themselves pytree nodes."""
    from repro.kernels import ops

    def body(carry, gs):
        ps, mus, nus, c = carry
        c = c + 1
        hyper = ops.adam_hyper_traced(lr, b1, b2, eps, c)
        out = [ops.fused_decode_apply(g, p, m, v, hyper,
                                      use_pallas=use_pallas)
               for g, p, m, v in zip(gs, ps, mus, nus)]
        return ([o[0] for o in out], [o[1] for o in out],
                [o[2] for o in out], c), None

    init = (list(p_leaves), list(mu_leaves), list(nu_leaves),
            jnp.asarray(count0, jnp.int32))
    (p2, mu2, nu2, c2), _ = jax.lax.scan(body, init, tuple(g_stacks))
    return p2, mu2, nu2, c2


def _check_wire(payload) -> None:
    """Cheap consistency check of a payload's wire containers: the
    block-row count must match the dense shape the container claims to
    decode to — the device path never materializes the dense form, so a
    truncated/corrupt container would otherwise surface as a shape
    error deep inside the jitted scan instead of a clean chain cut."""
    import numpy as np
    for leaf in jax.tree.leaves(payload, is_leaf=_is_compressed):
        if not _is_compressed(leaf):
            continue
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        nb = -(-n // leaf.block)            # ceil div
        lead = getattr(leaf, "values", None)
        lead = leaf.q if lead is None else lead
        if lead.shape[0] != nb:
            raise ValueError(
                f"corrupt differential: {lead.shape[0]} block rows for "
                f"shape {leaf.shape} (expected {nb})")


def _stage_window(diffs: List[Tuple[int, Any]]):
    """H2D-stage a window's payloads in wire form. Uploads each
    differential's compressed buffers to the device (async
    ``device_put`` under the hood — the transfer overlaps whatever scan
    is already running) and stacks them along a leading axis for
    ``lax.scan``. A payload that fails to stage cuts the window there
    (``contiguous_prefix`` semantics). Returns
    ``(stacked | None, n_staged, error | None)``."""
    from repro.checkpoint.io import COPY_METER
    from repro.obs.trace import trace_span
    with trace_span("replay.h2d", "recovery", n=len(diffs)) as sp:
        staged, err, template = [], None, None
        nbytes = 0
        for _, payload in diffs:
            try:
                _check_wire(payload)
                dev = jax.tree.map(jnp.asarray, payload)
                tdef = jax.tree.structure(dev)
                if template is None:
                    template = tdef
                elif tdef != template:
                    raise ValueError("differential structure changed "
                                     "mid-window")
                nbytes += sum(l.nbytes for l in jax.tree.leaves(dev))
                staged.append(dev)
            except Exception as e:
                err = e
                break
        if not staged:
            return None, 0, err
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *staged)
        COPY_METER.add_h2d(nbytes)
        sp.set(bytes=nbytes, staged=len(staged))
        return stacked, len(staged), err


def replay_device(params, opt: AdamState, diffs: List[Tuple[int, Any]], *,
                  lr=1e-3, b1=0.9, b2=0.999, eps=1e-8,
                  window: Optional[int] = None,
                  use_pallas: Optional[bool] = None):
    """Device-resident serial-exact replay: payloads cross the
    interconnect compressed (ρ·dense bytes instead of dense fp32), and
    one jitted scan per window decodes-and-applies them with the fused
    kernels. Bit-identical to :func:`replay_serial` — same per-element
    program, different orchestration.

    Windows are double-buffered: window N's scan is dispatched
    asynchronously, then window N+1's payloads stage H2D while it runs.
    A payload that fails to decode/stage cuts the chain at that diff.
    Returns ``(params, opt, applied)``."""
    if not diffs:
        return params, opt, 0
    if window is not None and window < 0:
        raise ValueError("window must be None or >= 0")
    w = int(window) if window else len(diffs)
    up = _use_pallas() if use_pallas is None else use_pallas
    p_leaves, treedef = jax.tree.flatten(params)
    mu_l = jax.tree.leaves(opt.mu)
    nu_l = jax.tree.leaves(opt.nu)
    count = jnp.asarray(opt.count, jnp.int32)
    applied = 0
    windows = [diffs[i:i + w] for i in range(0, len(diffs), w)]
    nxt = _stage_window(windows[0])
    for i in range(len(windows)):
        stacked, n, err = nxt
        if n:
            g_stacks = jax.tree.leaves(stacked, is_leaf=_is_compressed)
            try:
                p_leaves, mu_l, nu_l, count = _device_replay(
                    p_leaves, mu_l, nu_l, g_stacks, count,
                    jnp.float32(lr), b1=b1, b2=b2, eps=eps, use_pallas=up)
                applied += n
            except Exception as e:      # structure/shape mismatch
                err = e
        if err is not None:
            break
        if i + 1 < len(windows):
            # double buffer: the scan above was dispatched async; the
            # next window's (compressed, hence small) H2D runs under it
            nxt = _stage_window(windows[i + 1])
    return (jax.tree.unflatten(treedef, p_leaves),
            AdamState(jax.tree.unflatten(treedef, mu_l),
                      jax.tree.unflatten(treedef, nu_l), count),
            applied)


# ---------------- device-resident patch-chain overlay ----------------

def overlay_device(state, updates, *, use_pallas: Optional[bool] = None):
    """Device-side twin of :func:`repro.checkpoint.store.merge_updates`
    for patch blobs: nested dicts merge, a quantized
    :class:`~repro.compression.quant_span.QuantSpan` leaf is
    dequantized-and-scattered into the state leaf by the fused
    ``quant_span_apply`` kernel (no host decode of the wire bytes), a
    raw :class:`RowUpdate` splices on host, anything else replaces.
    Mutates ``state`` in place; overlaid leaves come back as numpy.
    Bit-identical to the host overlay: the kernel performs the same f32
    dequant ops as the host codec."""
    import numpy as np

    from repro.checkpoint.io import COPY_METER
    from repro.checkpoint.patchset import RowUpdate
    from repro.compression.quant_span import QuantSpan
    from repro.kernels import ops
    up = _use_pallas() if use_pallas is None else use_pallas
    for k, v in updates.items():
        if isinstance(v, dict) and isinstance(state.get(k), dict):
            overlay_device(state[k], v, use_pallas=up)
        elif isinstance(v, QuantSpan):
            dst = jnp.asarray(np.asarray(state[k]))
            for start, q, sc in zip(v.starts, v.qs, v.scales):
                COPY_METER.add_h2d(q.nbytes + sc.nbytes)
                dst = ops.fused_span_apply(dst, int(start),
                                           jnp.asarray(q),
                                           jnp.asarray(sc),
                                           bits=v.bits, use_pallas=up)
            state[k] = np.asarray(dst)
        elif isinstance(v, RowUpdate):
            base = np.array(state[k])
            for sp in v.spans():
                base[sp.start:sp.stop] = sp.data
            state[k] = base
        else:
            state[k] = v


def load_state_device(store, *, use_pallas: Optional[bool] = None):
    """Hardware-recovery twin of ``store.load_latest_state`` that
    overlays the patch chain on device: quantized span payloads upload
    in wire form (1/4 to 1/8 of the raw span bytes over the
    interconnect) and the fused ``quant_span_apply`` kernel scatters
    the dequantized rows straight into the state leaf. Same fallback /
    chain-cut semantics as the host path, and bit-identical output.
    Returns ``(state, step)``."""
    from repro.checkpoint.io import FrameCorruptionError
    from repro.checkpoint.remote import RetryExhaustedError
    from repro.checkpoint.store import order_fulls
    with store._lock:
        fulls = order_fulls(store.manifest["fulls"])
    if not fulls:
        raise FileNotFoundError("no persisted checkpoint")
    last_err = None
    for entry in fulls:
        try:
            state = store.load_full(entry)
        except (FileNotFoundError, RetryExhaustedError,
                FrameCorruptionError) as e:
            last_err = e
            continue
        step = int(entry.get("state_step", entry["step"]))
        for pe in store.patch_chain(store._entry_key(entry)):
            try:
                blob = store.backend.get(store._entry_key(pe))
            except (FileNotFoundError, RetryExhaustedError,
                    FrameCorruptionError):
                break            # cut at the gap: prefix is committed
            overlay_device(state, blob["updates"], use_pallas=use_pallas)
            step = max(step, int(pe["step"]))
        return state, step
    raise FileNotFoundError(
        f"none of {len(fulls)} full checkpoints is loadable "
        f"(last error: {last_err})")


def merge_deltas_pairwise(deltas: List[Any]) -> Any:
    """Paper's literal pairwise tree merge for *state-delta* differentials
    (Naïve DC): log2(n) rounds of pairwise sums."""
    deltas = list(deltas)
    rounds = 0
    while len(deltas) > 1:
        nxt = []
        for i in range(0, len(deltas) - 1, 2):
            nxt.append(jax.tree.map(lambda a, b: a + b,
                                    deltas[i], deltas[i + 1]))
        if len(deltas) % 2:
            nxt.append(deltas[-1])
        deltas = nxt
        rounds += 1
    return deltas[0], rounds
