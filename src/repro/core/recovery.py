"""Recovery: differential replay (Algorithm 1) — serial and parallel.

Serial replay applies each differential through Adam in sequence:
``M_{j+1} = M_j + Adam(G_j)`` — n optimizer merges for n differentials.

Parallel recovery (paper §VII, Fig. 10) merges in log(n) depth. The
paper's pairwise merge is exact only for *state-delta* differentials
(Naïve DC); LowDiff differentials are gradients that pass through a
*stateful* optimizer. TPU/JAX adaptation: Adam's moment recurrences are
affine, so we parallelize them *exactly* with an associative scan
(log-depth, MXU-free elementwise work) — all intermediate (mu_j, nu_j)
drop out of one ``lax.associative_scan``, every step's param delta is then
computed in parallel, and a single sum produces M_n. This is the paper's
log(n) recovery without its approximation.
"""
from __future__ import annotations

import functools
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compression.sparse import SparseGrad, decompress_tree
from repro.optim.adam import AdamState, adam_update


def load_latest_chain(store):
    """Load the newest full checkpoint and the ordered differentials
    after it from whatever storage backend the store wraps (the backend
    re-assembles sharded leaves, hits the memory tier, or fetches and
    checksum-verifies remote chunks transparently).

    A full checkpoint that cannot be read back — missing blob, a
    corrupt frame (leaf sha256 mismatch), or a remote tier whose
    bounded re-fetches never produced checksum-clean chunks — does not
    abort recovery: the loader falls back to the next older full and
    replays the longer differential chain from there. Entries the
    maintenance scrubber quarantined were already removed from the
    manifest's chain kinds, so they are skipped proactively without
    touching storage at all.

    The fallback order is *source-aware* (``order_fulls``): fulls are
    preferred by the state they actually represent (``state_step``),
    then by nominal step, then by the durability of the tier that
    recorded them (durable > memory > peer). On a replacement host the
    peer-adopted entries are typically the ONLY entries — peer-first
    recovery at network speed — while on a host whose durable storage
    survived, a stale peer-served replica can never shadow a newer
    durable full. Returns (state, [(step, payload), ...]); raises
    FileNotFoundError when no full checkpoint is loadable."""
    from repro.checkpoint.io import FrameCorruptionError
    from repro.checkpoint.remote import RetryExhaustedError
    from repro.checkpoint.store import order_fulls
    fulls = order_fulls(store.manifest["fulls"])
    if not fulls:
        raise FileNotFoundError("no full checkpoint")
    last_err = None
    for entry in fulls:
        try:
            state = store.load_full(entry)
        except (FileNotFoundError, RetryExhaustedError,
                FrameCorruptionError) as e:
            last_err = e
            continue
        return state, store.diffs_after(entry["step"])
    raise FileNotFoundError(
        f"none of {len(fulls)} full checkpoints is loadable "
        f"(last error: {last_err})")


def contiguous_prefix(start: int, diffs: List[Tuple[int, Any]],
                      stride: int = 1) -> List[Tuple[int, Any]]:
    """Longest prefix of ``diffs`` whose steps advance by ``stride``
    from ``start``. Replaying *past* a hole — a differential whose
    async write-back never landed before the crash, leaving a
    mid-chain gap that ``_prune_missing`` (which assumes missing blobs
    are a FIFO suffix) cannot repair — would silently corrupt the
    recovered state, so callers that know their differential cadence
    cut the chain at the first gap and recover to the last provably
    consistent step instead. LowDiff emits one differential per
    iteration, hence stride 1; strategies with a sparser cadence pass
    their own stride."""
    out = []
    expect = start + stride
    for s, p in diffs:
        if s != expect:
            break
        out.append((s, p))
        expect = s + stride
    return out


def _is_compressed(x):
    from repro.compression.packed import PackedDiff
    from repro.compression.quant import QuantGrad
    return isinstance(x, (SparseGrad, QuantGrad, PackedDiff))


def maybe_decompress(payload):
    leaves = jax.tree.leaves(payload, is_leaf=_is_compressed)
    if any(_is_compressed(l) for l in leaves):
        return jax.tree.map(lambda l: l.dense() if _is_compressed(l) else l,
                            payload, is_leaf=_is_compressed)
    return payload


def replay_serial(params, opt: AdamState, diffs: List[Tuple[int, Any]], *,
                  lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    """Apply each differential in order. diffs: [(step, payload)]."""
    for _, payload in diffs:
        g = maybe_decompress(payload)
        params, opt = adam_update(params, g, opt, lr=lr, b1=b1, b2=b2,
                                  eps=eps)
    return params, opt


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps"))
def _parallel_replay(params, mu0, nu0, stacked, count0, lr, *,
                     b1=0.9, b2=0.999, eps=1e-8):
    n = jax.tree.leaves(stacked)[0].shape[0]

    def scan_moments(g, m0, beta):
        # affine recurrence x_j = beta * x_{j-1} + (1-beta) g_j as an
        # associative scan over (a, b) pairs; a broadcast to g's shape.
        a = jnp.broadcast_to(
            jnp.full((n,) + (1,) * (g.ndim - 1), beta, jnp.float32),
            g.shape)
        aa, bb = jax.lax.associative_scan(
            lambda l, r: (l[0] * r[0], r[1] + r[0] * l[1]),
            (a, (1.0 - beta) * g))
        return bb + aa * m0                         # (n, ...) moments

    counts = count0 + 1 + jnp.arange(n)
    c1 = 1.0 - b1 ** counts.astype(jnp.float32)
    c2 = 1.0 - b2 ** counts.astype(jnp.float32)

    def one(p, g, m0, v0):
        mu_j = scan_moments(g, m0, b1)
        nu_j = scan_moments(g * g, v0, b2)
        cs = (1,) * (g.ndim - 1)
        step = lr * (mu_j / c1.reshape((n,) + cs)) / (
            jnp.sqrt(nu_j / c2.reshape((n,) + cs)) + eps)
        p2 = (p.astype(jnp.float32) - step.sum(0)).astype(p.dtype)
        return p2, mu_j[-1], nu_j[-1]

    out = jax.tree.map(one, params, stacked, mu0, nu0)
    p2 = jax.tree.map(lambda t: t[0], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    mu2 = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    nu2 = jax.tree.map(lambda t: t[2], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return p2, mu2, nu2


def replay_parallel(params, opt: AdamState, diffs: List[Tuple[int, Any]], *,
                    lr=1e-3, b1=0.9, b2=0.999, eps=1e-8,
                    window: Optional[int] = None):
    """Exact log-depth replay via associative scan over the moment
    recurrences. Numerically identical (up to reassociation) to serial.
    The jitted kernel is cached across calls (shapes keyed).

    ``window`` bounds peak memory: instead of materializing all n
    differentials as one dense fp32 stack — O(n · model) host/device
    bytes — the scan runs over windows of at most ``window``
    differentials, carrying ``(params, mu, nu, count)`` between them.
    The moment recurrences chain exactly across the boundary (each
    window's scan is seeded with the previous window's final moments),
    so the result is numerically identical up to the same float
    reassociation the unwindowed scan already accepts. ``None`` (or 0)
    replays everything in one window."""
    if not diffs:
        return params, opt
    if window is not None and window < 0:
        raise ValueError("window must be None or >= 0")
    w = int(window) if window else len(diffs)
    mu, nu, count = opt.mu, opt.nu, opt.count
    for i in range(0, len(diffs), w):
        gs = [maybe_decompress(p) for _, p in diffs[i:i + w]]
        stacked = jax.tree.map(lambda *xs: jnp.stack(
            [x.astype(jnp.float32) for x in xs]), *gs)
        params, mu, nu = _parallel_replay(params, mu, nu, stacked,
                                          count, jnp.float32(lr),
                                          b1=b1, b2=b2, eps=eps)
        count = count + len(gs)
    return params, AdamState(mu, nu, count)


def merge_deltas_pairwise(deltas: List[Any]) -> Any:
    """Paper's literal pairwise tree merge for *state-delta* differentials
    (Naïve DC): log2(n) rounds of pairwise sums."""
    deltas = list(deltas)
    rounds = 0
    while len(deltas) > 1:
        nxt = []
        for i in range(0, len(deltas) - 1, 2):
            nxt.append(jax.tree.map(lambda a, b: a + b,
                                    deltas[i], deltas[i + 1]))
        if len(deltas) % 2:
            nxt.append(deltas[-1])
        deltas = nxt
        rounds += 1
    return deltas[0], rounds
