"""Reusing Queue (paper §V-A): the FIFO channel between training and
checkpointing.

JAX adaptation of the CUDA-IPC zero-copy queue: ``jax.Array`` values are
immutable, so *enqueuing the array object itself is the zero-copy hand-off*
— no process boundary and no IPC handle needed; the consumer performs the
single mandatory D2H copy (``np.asarray``) on its own thread, overlapping
the next training step (TPU D2H DMAs run concurrently with compute, and
``jax.jit`` dispatch is asynchronous, so ``put`` returns before the step
finishes).

FIFO order satisfies Requirement 1 (differentials must apply in sequence);
bounded capacity provides the backpressure that caps device-memory held by
in-flight checkpoints (the paper's Limitation 2).

Liveness: a handler exception inside :meth:`drain` is captured in
:attr:`error` instead of silently killing the consumer thread — the
producer's ``flush`` re-raises it (see :func:`wait_drained`) rather than
busy-waiting forever on a counter that will never advance. ``close`` never
blocks, even on a full queue.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Optional


class CheckpointingError(RuntimeError):
    """The background checkpointing consumer failed; raised from the
    producer side (``flush``) with the original handler exception as
    ``__cause__``."""


class ReusingQueue:
    #: stats() keys, synced against the instrument set by
    #: tests/test_observability.py (``consumer_error`` is derived)
    KEYS = ("enqueued", "dequeued", "put_block_time", "max_depth")

    def __init__(self, maxsize: int = 4):
        from repro.obs.metrics import InstrumentSet
        self._q: "queue.Queue" = queue.Queue(maxsize=maxsize)
        self._inst = InstrumentSet("queue")
        self._enqueued = self._inst.counter("enqueued")
        self._dequeued = self._inst.counter("dequeued")
        # per-put block time histogram: the registry dump gets the
        # backpressure distribution, stats() keeps the legacy sum key
        self._put_block = self._inst.histogram("put_block_time")
        self._max_depth = self._inst.gauge("max_depth")
        self._lock = threading.Lock()
        self._closed = threading.Event()
        #: the exception that killed the consumer's handler, if any
        self.error: Optional[BaseException] = None

    # legacy attribute surface (wait_drained and tests read these raw)
    @property
    def enqueued(self) -> int:
        return int(self._enqueued.value)

    @property
    def dequeued(self) -> int:
        return int(self._dequeued.value)

    @property
    def put_block_time(self) -> float:
        return self._put_block.sum

    @property
    def max_depth(self) -> int:
        return int(self._max_depth.value)

    def put(self, step: int, payload: Any) -> float:
        """Called from the training loop. Blocks only on backpressure.
        Returns the seconds this call blocked so the producer can
        charge the step's stall attribution."""
        t0 = time.perf_counter()
        self._q.put((step, payload))
        dt = time.perf_counter() - t0
        self._enqueued.add(1)
        self._put_block.observe(dt)
        with self._lock:
            if self._q.qsize() > self._max_depth.value:
                self._max_depth.set(self._q.qsize())
        return dt

    def get(self, timeout: Optional[float] = None):
        """Called from the checkpointing thread. Returns (step, payload).
        The close() sentinel is not a differential and is not counted in
        ``dequeued``."""
        item = self._q.get(timeout=timeout)
        if item[0] is not None:
            self._dequeued.add(1)
        return item

    def close(self):
        """Signal the consumer to exit once the queue is drained. Never
        blocks: on a full queue the sentinel is skipped and the closed
        flag alone stops the drain loop."""
        self._closed.set()
        try:
            self._q.put_nowait((None, None))
        except queue.Full:
            pass

    def drain(self, handler: Callable[[int, Any], None],
              stop_event: Optional[threading.Event] = None):
        """Consumer loop: call handler(step, payload) until close().
        Items already enqueued when close() lands are still handled.
        A handler exception is recorded in :attr:`error` and ends the
        loop — the producer re-raises it from flush(). A poisoned queue
        (error already set) refuses to drain: persisting differentials
        *after* a lost one would durably write a chain with a hole."""
        if self.error is not None:
            return
        while True:
            try:
                step, payload = self.get(timeout=0.2)
            except queue.Empty:
                if self._closed.is_set():
                    return
                if stop_event is not None and stop_event.is_set():
                    return
                continue
            if step is None:
                return
            try:
                handler(step, payload)
            except BaseException as e:  # noqa: B036 - must survive anything
                self.error = e
                return

    def instruments(self):
        """The backing :class:`~repro.obs.metrics.InstrumentSet`."""
        return self._inst

    def stats(self):
        return {**{k: getattr(self, k) for k in self.KEYS},
                "consumer_error": repr(self.error) if self.error else None}


def wait_drained(q: ReusingQueue, processed: Callable[[], int],
                 consumer: Optional[threading.Thread], timeout: float,
                 poll_s: float = 0.005):
    """Producer-side wait until every enqueued item has been handled.

    Raises :class:`CheckpointingError` (chaining the handler exception)
    if the consumer died, and :class:`TimeoutError` when ``timeout``
    elapses — a flush must never hang forever on a counter the dead
    consumer can no longer advance.
    """
    deadline = time.monotonic() + timeout
    while processed() < q.enqueued:
        if q.error is not None:
            raise CheckpointingError(
                "checkpointing consumer failed; differentials after step "
                "of failure were not persisted") from q.error
        if consumer is None or not consumer.is_alive():
            raise CheckpointingError(
                "checkpointing consumer thread is not running but "
                f"{q.enqueued - processed()} differential(s) remain queued")
        if time.monotonic() > deadline:
            raise TimeoutError(
                f"flush did not drain within {timeout:.1f}s "
                f"({processed()}/{q.enqueued} handled)")
        time.sleep(poll_s)
    if q.error is not None:
        raise CheckpointingError(
            "checkpointing consumer failed") from q.error
