"""Reusing Queue (paper §V-A): the FIFO channel between training and
checkpointing.

JAX adaptation of the CUDA-IPC zero-copy queue: ``jax.Array`` values are
immutable, so *enqueuing the array object itself is the zero-copy hand-off*
— no process boundary and no IPC handle needed; the consumer performs the
single mandatory D2H copy (``np.asarray``) on its own thread, overlapping
the next training step (TPU D2H DMAs run concurrently with compute, and
``jax.jit`` dispatch is asynchronous, so ``put`` returns before the step
finishes).

FIFO order satisfies Requirement 1 (differentials must apply in sequence);
bounded capacity provides the backpressure that caps device-memory held by
in-flight checkpoints (the paper's Limitation 2).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Optional


class ReusingQueue:
    def __init__(self, maxsize: int = 4):
        self._q: "queue.Queue" = queue.Queue(maxsize=maxsize)
        self.enqueued = 0
        self.dequeued = 0
        self.put_block_time = 0.0     # training stalls caused by backpressure
        self.max_depth = 0
        self._lock = threading.Lock()

    def put(self, step: int, payload: Any):
        """Called from the training loop. Blocks only on backpressure."""
        t0 = time.perf_counter()
        self._q.put((step, payload))
        dt = time.perf_counter() - t0
        with self._lock:
            self.enqueued += 1
            self.put_block_time += dt
            self.max_depth = max(self.max_depth, self._q.qsize())

    def get(self, timeout: Optional[float] = None):
        """Called from the checkpointing thread. Returns (step, payload)."""
        item = self._q.get(timeout=timeout)
        with self._lock:
            self.dequeued += 1
        return item

    def close(self):
        self._q.put((None, None))

    def drain(self, handler, stop_event: Optional[threading.Event] = None):
        """Consumer loop: call handler(step, payload) until close()."""
        while True:
            try:
                step, payload = self.get(timeout=0.2)
            except queue.Empty:
                if stop_event is not None and stop_event.is_set():
                    return
                continue
            if step is None:
                return
            handler(step, payload)

    def stats(self):
        return {"enqueued": self.enqueued, "dequeued": self.dequeued,
                "put_block_time": self.put_block_time,
                "max_depth": self.max_depth}
