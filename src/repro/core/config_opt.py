"""Checkpoint configuration optimization (paper §V-C, Eq. 8-10).

Models the wasted time as a function of full-checkpoint frequency f
(full checkpoints per iteration, i.e. 1/FCF-interval) and differential
batching size b, and returns the closed-form optimum (f*, b*). A grid
verifier cross-checks the closed form (used by tests and Table-I-style
benchmarks), and ``OnlineTuner`` adapts the constants from runtime
measurements the way §VII's optimal-configuration module does.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class SystemParams:
    """Constants of Eq. 8 (units: iterations for time-like quantities)."""
    N: int = 8            # GPUs/chips
    M: float = 3600.0     # mean time between failures
    W: float = 5e9        # checkpoint write bandwidth (bytes/iteration-time)
    S: float = 1e9        # full checkpoint size (bytes)
    T: float = 1e5        # total training run-time
    R_F: float = 10.0     # time to load a full checkpoint
    R_D: float = 0.5      # time to merge one differential checkpoint


def wasted_time(f: float, b: float, p: SystemParams) -> float:
    """Eq. (8). f in (0, 1]: full checkpoints per iteration; b >= 1."""
    recovery = (p.N * p.T / p.M) * (
        b / 2.0 + p.R_F + (p.R_D / 2.0) * (1.0 / (f * b) - 1.0))
    steady = p.N * p.T * p.S * f / p.W
    return recovery + steady


def optimal_config(p: SystemParams) -> Tuple[float, float]:
    """Eq. (10): (f*, b*) closed form."""
    f_star = (p.R_D * p.W ** 2 / (4.0 * p.S ** 2 * p.M ** 2)) ** (1.0 / 3.0)
    b_star = (2.0 * p.S * p.R_D * p.M / p.W) ** (1.0 / 3.0)
    return f_star, b_star


def grid_verify(p: SystemParams, f_grid=None, b_grid=None):
    """Brute-force minimum over a grid (tests the closed form)."""
    f_star, b_star = optimal_config(p)
    if f_grid is None:
        f_grid = np.geomspace(f_star / 30, min(1.0, f_star * 30), 400)
    if b_grid is None:
        b_grid = np.geomspace(max(1e-2, b_star / 30), b_star * 30, 400)
    F, B = np.meshgrid(f_grid, b_grid, indexing="ij")
    Wt = np.vectorize(lambda f, b: wasted_time(f, b, p))(F, B)
    i, j = np.unravel_index(np.argmin(Wt), Wt.shape)
    return float(F[i, j]), float(B[i, j]), float(Wt[i, j])


def practical_config(p: SystemParams, max_interval: int = 1000):
    """Integer (full-checkpoint interval, batch size) actually deployed."""
    f_star, b_star = optimal_config(p)
    interval = int(np.clip(round(1.0 / max(f_star, 1e-9)), 1, max_interval))
    b = int(np.clip(round(b_star), 1, interval))
    return interval, b


class OnlineTuner:
    """Stepwise runtime adaptation of (M, W, R_D) -> (interval, batch).

    Mirrors the paper's optimal-configuration module: start from defaults,
    fold in observed failure gaps / write bandwidths / merge times with an
    EMA, re-solve Eq. (10) after each observation.
    """

    def __init__(self, params: SystemParams, ema: float = 0.3):
        self.p = dataclasses.replace(params)
        self.ema = ema
        #: EMA of the step-path stall fraction (0..1) observed by the
        #: StepTimeline — 0.0 keeps Eq. (10) untouched, so runs without
        #: the observability pipeline behave exactly as before
        self.stall_fraction = 0.0

    def _fold(self, attr: str, value: float):
        old = getattr(self.p, attr)
        setattr(self.p, attr, (1 - self.ema) * old + self.ema * value)

    def observe_failure_gap(self, gap: float):
        self._fold("M", gap)

    def observe_write_bandwidth(self, bw: float):
        self._fold("W", bw)

    def observe_merge_time(self, t: float):
        self._fold("R_D", t)

    def observe_full_size(self, s: float):
        self._fold("S", s)

    def observe_stall_fraction(self, frac: float):
        """Fold in the timeline's attributed stall share of step wall.
        Unlike raw wall-clock (which conflates checkpoint cost with
        compute jitter), this is exactly the fraction of step time the
        persistence pipeline *caused*, so it modulates the effective
        write bandwidth Eq. (10) sees: a pipeline stalling the step
        loop looks slower than its raw device-to-storage rate."""
        frac = min(max(float(frac), 0.0), 1.0)
        self.stall_fraction = ((1 - self.ema) * self.stall_fraction
                               + self.ema * frac)

    def current(self) -> Tuple[int, int]:
        if self.stall_fraction <= 0.0:
            return practical_config(self.p)
        # penalize W by the observed stall share (bounded at 2x so a
        # pathological window cannot collapse the checkpoint frequency)
        eff = dataclasses.replace(
            self.p, W=self.p.W / (1.0 + min(self.stall_fraction, 1.0)))
        return practical_config(eff)
