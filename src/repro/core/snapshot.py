"""Overlapped device-to-host snapshots (§V-B step ① without the stall).

The seed's ``host_copy`` walked the pytree calling ``np.asarray`` leaf
by leaf — each call blocks the caller until that leaf's D2H transfer
finishes, serializing the transfers *and* charging them to the training
loop. This module replaces it with the two-phase pattern:

1. **start** — issue ``copy_to_host_async()`` on every jax leaf. This
   only enqueues DMA descriptors; on TPU the transfers run out of a
   pinned staging area while the next training step computes.
2. **materialize** — ``np.asarray`` each leaf *later* (on the persist /
   consumer thread), which merely waits for the already-running
   transfers and hands back the landed host buffer. The D2H transfer is
   the single host-side copy of the tensor bytes; the frame serializer
   streams those same buffers to storage with no further copies.

:class:`SnapshotArena` adds double-buffering semantics on top: at most
``slots`` (default 2) snapshots may be in flight, so a slow persist
tier exerts backpressure on the training loop instead of accumulating
unbounded host copies of the model state — the JAX adaptation of a
fixed pinned-arena design (the runtime owns the actual pinned staging
memory; the arena owns the lifetime and the bound).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.io import COPY_METER


def start_host_transfer(tree) -> Any:
    """Phase 1: enqueue non-blocking D2H transfers for every jax leaf.
    Returns the tree unchanged (transfers run in the background)."""
    for leaf in jax.tree.leaves(tree):
        if isinstance(leaf, jax.Array):
            try:
                leaf.copy_to_host_async()
            except AttributeError:  # non-addressable / already on host
                pass
    return tree


def materialize(tree):
    """Phase 2: wait for the transfers and return a numpy-leaf tree.
    Counts the D2H bytes as the one metered host copy."""
    out = jax.tree.map(np.asarray, tree)
    COPY_METER.add(sum(a.nbytes for a in jax.tree.leaves(out)
                       if isinstance(a, np.ndarray)))
    return out


def host_copy(tree):
    """Batched synchronous snapshot: start *all* transfers first, then
    gather — the transfers overlap each other even though the caller
    still blocks until the last one lands. Drop-in replacement for the
    seed's per-leaf ``np.asarray`` walk."""
    return materialize(start_host_transfer(tree))


class PendingSnapshot:
    """A snapshot whose D2H transfers have been issued but not awaited.

    ``result()`` (any thread) materializes the host tree — the first
    caller pays only the residual transfer wait, later callers get the
    cached tree. ``release()`` frees the arena slot and drops the
    buffer references; call it once the snapshot has been persisted.
    """

    def __init__(self, tree, arena: Optional["SnapshotArena"] = None):
        self._tree = start_host_transfer(tree)
        self._arena = arena
        self._host: Any = None
        self._done = False
        self._lock = threading.Lock()

    def result(self):
        with self._lock:
            if not self._done:
                self._host = materialize(self._tree)
                self._tree = None          # device refs no longer needed
                self._done = True
            return self._host

    def release(self) -> None:
        with self._lock:
            self._tree = None
            self._host = None
            self._done = True
        if self._arena is not None:
            self._arena._release()
            self._arena = None

    def __enter__(self):
        return self.result()

    def __exit__(self, *exc):
        self.release()


class SnapshotArena:
    """Double-buffered snapshot permits.

    ``snapshot_async(tree)`` issues the async transfers and returns a
    :class:`PendingSnapshot`; it blocks only when ``slots`` snapshots
    are already in flight (persist tier behind by two full states) —
    bounded memory, no unbounded queue of model copies.
    """

    def __init__(self, slots: int = 2):
        if slots < 1:
            raise ValueError("slots must be >= 1")
        self.slots = slots
        self._sem = threading.Semaphore(slots)
        self._lock = threading.Lock()
        self.snapshots = 0
        self.stalls = 0

    def snapshot_async(self, tree) -> PendingSnapshot:
        if not self._sem.acquire(blocking=False):
            with self._lock:
                self.stalls += 1
            self._sem.acquire()
        with self._lock:
            self.snapshots += 1
        return PendingSnapshot(tree, arena=self)

    def _release(self) -> None:
        self._sem.release()

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"slots": self.slots, "snapshots": self.snapshots,
                    "stalls": self.stalls}
