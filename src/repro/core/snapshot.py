"""Overlapped device-to-host snapshots (§V-B step ① without the stall).

The seed's ``host_copy`` walked the pytree calling ``np.asarray`` leaf
by leaf — each call blocks the caller until that leaf's D2H transfer
finishes, serializing the transfers *and* charging them to the training
loop. This module replaces it with the two-phase pattern:

1. **start** — issue ``copy_to_host_async()`` on every jax leaf. This
   only enqueues DMA descriptors; on TPU the transfers run out of a
   pinned staging area while the next training step computes.
2. **materialize** — ``np.asarray`` each leaf *later* (on the persist /
   consumer thread), which merely waits for the already-running
   transfers and hands back the landed host buffer. The D2H transfer is
   the single host-side copy of the tensor bytes; the frame serializer
   streams those same buffers to storage with no further copies.

:class:`SnapshotArena` adds double-buffering semantics on top: at most
``slots`` (default 2) snapshots may be in flight, so a slow persist
tier exerts backpressure on the training loop instead of accumulating
unbounded host copies of the model state — the JAX adaptation of a
fixed pinned-arena design (the runtime owns the actual pinned staging
memory; the arena owns the lifetime and the bound).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.io import COPY_METER


def start_host_transfer(tree) -> Any:
    """Phase 1: enqueue non-blocking D2H transfers for every jax leaf.
    Returns the tree unchanged (transfers run in the background)."""
    for leaf in jax.tree.leaves(tree):
        if isinstance(leaf, jax.Array):
            try:
                leaf.copy_to_host_async()
            except AttributeError:  # non-addressable / already on host
                pass
    return tree


def materialize(tree):
    """Phase 2: wait for the transfers and return a numpy-leaf tree.
    Counts the D2H bytes as the one metered host copy."""
    out = jax.tree.map(np.asarray, tree)
    COPY_METER.add(sum(a.nbytes for a in jax.tree.leaves(out)
                       if isinstance(a, np.ndarray)))
    return out


def host_copy(tree):
    """Batched synchronous snapshot: start *all* transfers first, then
    gather — the transfers overlap each other even though the caller
    still blocks until the last one lands. Drop-in replacement for the
    seed's per-leaf ``np.asarray`` walk."""
    return materialize(start_host_transfer(tree))


class PendingSnapshot:
    """A snapshot whose D2H transfers have been issued but not awaited.

    ``result()`` (any thread) materializes the host tree — the first
    caller pays only the residual transfer wait, later callers get the
    cached tree. ``release()`` frees the arena slot and drops the
    buffer references; call it once the snapshot has been persisted.
    """

    def __init__(self, tree, arena: Optional["SnapshotArena"] = None):
        self._tree = start_host_transfer(tree)
        self._arena = arena
        self._host: Any = None
        self._done = False
        self._lock = threading.Lock()

    def result(self):
        with self._lock:
            if not self._done:
                self._host = materialize(self._tree)
                self._tree = None          # device refs no longer needed
                self._done = True
            return self._host

    def release(self) -> None:
        with self._lock:
            self._tree = None
            self._host = None
            self._done = True
        if self._arena is not None:
            self._arena._release()
            self._arena = None

    def __enter__(self):
        return self.result()

    def __exit__(self, *exc):
        self.release()


def _partition_leaves(nbytes: List[int], shards: int) -> List[List[int]]:
    """Split leaf positions into up to ``shards`` contiguous groups of
    roughly equal bytes (contiguity preserves the producer's layer
    order, so shard 0 holds the leaves the backward pass finishes
    first and its D2H can start while later layers still compute)."""
    if not nbytes:
        return []
    shards = max(1, min(int(shards), len(nbytes)))
    weights = nbytes if sum(nbytes) else [1] * len(nbytes)
    total = sum(weights)
    groups: List[List[int]] = [[]]
    acc = 0
    for i, w in enumerate(weights):
        if (groups[-1] and len(groups) < shards
                and acc >= total * len(groups) / shards):
            groups.append([])
        groups[-1].append(i)
        acc += w
    return groups


class ShardedPendingSnapshot:
    """Per-shard overlapped D2H snapshot (§V-B step ① at DMA grain).

    The tree's leaves are partitioned into contiguous byte-balanced
    shards and each shard's ``copy_to_host_async`` descriptors are
    enqueued immediately at construction — on TPU the transfers drain
    behind the still-running step (issue order matches the backward
    pass's layer order, so a shard's DMA starts as soon as its grads
    are available rather than after the whole post-step batch).

    ``result()`` (persist thread) then materializes shard by shard and
    *releases each shard's device references as soon as its bytes
    land* — the donation analogue: the runtime can reuse a shard's
    staging memory while later shards are still in flight, instead of
    the whole model's worth of buffers pinning until the last leaf.
    The residual block time per shard vs the issue-to-landed window is
    reported to :data:`COPY_METER` as the measured overlap ratio.
    """

    def __init__(self, tree, shards: int = 4,
                 arena: Optional["SnapshotArena"] = None):
        self._leaves, self._treedef = jax.tree.flatten(tree)
        sizes = [getattr(l, "nbytes", 0) or 0 for l in self._leaves]
        self._groups = _partition_leaves(sizes, shards)
        self._arena = arena
        self._host: Any = None
        self._done = False
        self._lock = threading.Lock()
        self._issued_at = time.perf_counter()
        for group in self._groups:      # chunked issue, shard order
            for i in group:
                leaf = self._leaves[i]
                if isinstance(leaf, jax.Array):
                    try:
                        leaf.copy_to_host_async()
                    except AttributeError:
                        pass

    @property
    def shards(self) -> int:
        return len(self._groups)

    def result(self):
        from repro.obs.trace import trace_span
        with self._lock, trace_span("snapshot.d2h", "snapshot",
                                    shards=len(self._groups)) as sp:
            if self._done:
                return self._host
            host: List[Any] = list(self._leaves)
            wait = 0.0
            nbytes = 0
            for group in self._groups:
                t0 = time.perf_counter()
                for i in group:
                    host[i] = np.asarray(self._leaves[i])
                    self._leaves[i] = None     # early release: the
                    # shard's device/staging buffers free while later
                    # shards are still transferring
                    if isinstance(host[i], np.ndarray):
                        nbytes += host[i].nbytes
                wait += time.perf_counter() - t0
            span = time.perf_counter() - self._issued_at
            COPY_METER.add(nbytes)             # the one metered host copy
            COPY_METER.add_d2h(nbytes, wait_s=wait, span_s=span)
            sp.set(bytes=nbytes, wait_ms=round(wait * 1e3, 3))
            self._host = jax.tree.unflatten(self._treedef, host)
            self._leaves = []
            self._done = True
            return self._host

    def release(self) -> None:
        with self._lock:
            self._leaves = []
            self._host = None
            self._done = True
        if self._arena is not None:
            self._arena._release()
            self._arena = None

    def __enter__(self):
        return self.result()

    def __exit__(self, *exc):
        self.release()


class SnapshotArena:
    """Double-buffered snapshot permits.

    ``snapshot_async(tree)`` issues the async transfers and returns a
    :class:`PendingSnapshot`; it blocks only when ``slots`` snapshots
    are already in flight (persist tier behind by two full states) —
    bounded memory, no unbounded queue of model copies.
    ``snapshot_sharded_async`` is the per-shard variant: same permit
    semantics, but the transfers issue and land shard by shard so the
    D2H overlaps the still-running step and buffers release early.
    """

    #: stats() keys, synced against the instrument set by
    #: tests/test_observability.py (``slots`` is config, not a metric)
    KEYS = ("snapshots", "stalls")

    def __init__(self, slots: int = 2):
        from repro.obs.metrics import InstrumentSet
        if slots < 1:
            raise ValueError("slots must be >= 1")
        self.slots = slots
        self._sem = threading.Semaphore(slots)
        self._inst = InstrumentSet("snapshot_arena")
        self._snapshots = self._inst.counter("snapshots")
        self._stalls = self._inst.counter("stalls")
        self._stall_time = self._inst.histogram("stall_time_s")

    # legacy attribute surface
    @property
    def snapshots(self) -> int:
        return int(self._snapshots.value)

    @property
    def stalls(self) -> int:
        return int(self._stalls.value)

    def _acquire(self) -> float:
        """Acquire a permit; returns the seconds the caller blocked so
        the producer can charge snapshot-stall attribution."""
        stalled = 0.0
        if not self._sem.acquire(blocking=False):
            from repro.obs.timeline import TIMELINE
            from repro.obs.trace import trace_span
            self._stalls.add(1)
            t0 = time.perf_counter()
            with trace_span("snapshot.permit_wait", "snapshot"):
                self._sem.acquire()
            stalled = time.perf_counter() - t0
            self._stall_time.observe(stalled)
            TIMELINE.charge("snapshot_stall", stalled)
        self._snapshots.add(1)
        return stalled

    def snapshot_async(self, tree) -> PendingSnapshot:
        self._acquire()
        return PendingSnapshot(tree, arena=self)

    def snapshot_sharded_async(self, tree,
                               shards: int = 4) -> ShardedPendingSnapshot:
        self._acquire()
        return ShardedPendingSnapshot(tree, shards=shards, arena=self)

    def _release(self) -> None:
        self._sem.release()

    def instruments(self):
        """The backing :class:`~repro.obs.metrics.InstrumentSet`."""
        return self._inst

    def stats(self) -> Dict[str, int]:
        return {"slots": self.slots, "snapshots": self.snapshots,
                "stalls": self.stalls}
