"""Jitted training-step builders with LowDiff integrated as a first-class
feature.

Modes:
  dense         — plain Adam step (baselines; checkpoint reads the state).
  lowdiff       — paper Algorithm 1 training process: compress the
                  synchronized gradient, *update the model from the
                  decompressed compressed gradient* (that identity is what
                  makes G̃_t an exact differential checkpoint), return G̃_t
                  as an extra jit output for the Reusing Queue.
  lowdiff_plus  — §VI: no compression; the dense gradient is the extra
                  output, streamed leaf-by-leaf ("layer-wise") to the host.

Gradient accumulation (cfg.grad_accum) scans over microbatches inside the
step — the accumulated gradient is what gets compressed/checkpointed,
exactly as a DeepSpeed gradient-accumulation boundary would.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.compression.error_feedback import (ef_compress_tree,
                                              ef_compress_tree_with, ef_init)
from repro.compression.sparse import compress_tree, decompress_tree
from repro.optim.adam import adam_init, adam_update


def init_state(model, rng, *, mode: str = "lowdiff",
               error_feedback: bool = True) -> Dict[str, Any]:
    params = model.init(rng)
    state = {"params": params, "opt": adam_init(params),
             "step": jnp.zeros((), jnp.int32)}
    if mode == "lowdiff" and error_feedback:
        state["ef"] = ef_init(params)
    return state


def _grads(model, params, batch, accum: int):
    acc_dt = jnp.dtype(model.cfg.grad_accum_dtype)
    if accum <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def micro(i, batch):
        return jax.tree.map(
            lambda x: x.reshape((accum, -1) + x.shape[1:])[i]
            if x.ndim >= 1 else x, batch)

    def body(carry, i):
        acc, loss_acc = carry
        (loss, _), g = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, micro(i, batch))
        acc = jax.tree.map(lambda a, b: a + b.astype(acc_dt), acc, g)
        return (acc, loss_acc + loss), None

    from repro.models.ops import scan_unroll
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
    (gsum, loss_sum), _ = jax.lax.scan(body, (zeros, jnp.float32(0)),
                                       jnp.arange(accum),
                                       unroll=scan_unroll())
    grads = jax.tree.map(lambda g: g / accum, gsum)
    loss = loss_sum / accum
    return loss, {"xent": loss, "aux": jnp.float32(0),
                  "tokens": jnp.float32(0)}, grads


def make_train_step(model, *, mode: str = "lowdiff", rho: float = 0.01,
                    lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999,
                    eps: float = 1e-8, error_feedback: bool = True,
                    compressor: str = "topk", jit: bool = True):
    """``compressor``: 'topk' (sparsification, paper default), 'quant8'
    (blockwise int8 — the paper's other §II-C compression family) or
    'packed' (fused top-k + int8 quantize + wire pack — the differential
    leaves the device already in frame layout). All produce reusable
    differential checkpoints; EF applies to topk and packed."""
    cfg = model.cfg
    accum = cfg.grad_accum

    def step(state, batch):
        params = state["params"]
        loss, metrics, grads = _grads(model, params, batch, accum)
        extra = None
        if mode == "lowdiff":
            if compressor == "quant8":
                from repro.compression.quant import (quant_compress,
                                                     quant_decompress)
                cg = jax.tree.map(quant_compress, grads)
                g_upd = jax.tree.map(
                    quant_decompress, cg,
                    is_leaf=lambda x: hasattr(x, "scale"))
                ef = None
                extra = cg
                params2, opt2 = adam_update(params, g_upd, state["opt"],
                                            lr=lr, b1=b1, b2=b2, eps=eps)
                return ({"params": params2, "opt": opt2,
                         "step": state["step"] + 1},
                        dict(metrics, loss=loss), extra)
            if compressor == "packed":
                from repro.compression.packed import PackedDiff
                from repro.kernels.ops import (packed_compress,
                                               packed_decompress)
                is_pd = lambda x: isinstance(x, PackedDiff)  # noqa: E731
                if error_feedback and "ef" in state:
                    cg, ef = ef_compress_tree_with(
                        grads, state["ef"],
                        lambda g: packed_compress(g, rho),
                        packed_decompress)
                else:
                    cg = jax.tree.map(lambda g: packed_compress(g, rho),
                                      grads)
                    ef = None
                g_upd = jax.tree.map(packed_decompress, cg, is_leaf=is_pd)
            elif error_feedback and "ef" in state:
                cg, ef = ef_compress_tree(grads, state["ef"], rho)
                g_upd = decompress_tree(cg)
            else:
                cg, ef = compress_tree(grads, rho), None
                g_upd = decompress_tree(cg)
            extra = cg
        else:
            g_upd, ef = grads, None
            if mode == "lowdiff_plus":
                extra = grads
        params2, opt2 = adam_update(params, g_upd, state["opt"], lr=lr,
                                    b1=b1, b2=b2, eps=eps)
        new_state = {"params": params2, "opt": opt2,
                     "step": state["step"] + 1}
        if ef is not None:
            new_state["ef"] = ef
        metrics = dict(metrics, loss=loss)
        return new_state, metrics, extra

    return jax.jit(step) if jit else step
