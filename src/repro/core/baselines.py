"""Baseline checkpointing strategies the paper compares against (§VIII-A).

All share the LowDiff strategy interface (train_step / flush / recover /
stats) so the benchmark harness can swap them:

* ``FullSync``      — "Torch.save": blocking full-state write every
                      ``interval`` iterations.
* ``CheckFreq``     — [FAST'21]: snapshot (sync D2H) + asynchronous
                      persist, pipelined; per-paper default interval 10.
* ``Gemini``        — [SOSP'23]: per-iteration snapshot into (peer) host
                      memory as the primary checkpoint, rare persistence;
                      recovery from host memory.
* ``NaiveDC``       — Check-N-Run style differential checkpointing for a
                      dense model: differential = M_{t+1} - M_t over the
                      *full* model state (3Ψ), top-k compressed each
                      iteration — i.e. DC *without* gradient reuse. This
                      carries the paper's Challenge-1 compression cost and
                      Challenge-2 transmission cost by construction.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.compression.sparse import compress_tree, decompress_tree
from repro.core.lowdiff import host_copy
from repro.core.steps import make_train_step


class _Base:
    def __init__(self, model, store: CheckpointStore, *, lr=1e-3,
                 interval: int = 1):
        self.model, self.store, self.lr = model, store, lr
        self.interval = interval
        self.step_fn = make_train_step(model, mode="dense", lr=lr)
        self.ckpt_time = 0.0
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: List[Any] = []

    def flush(self):
        for f in self._pending:
            f.result()
        self._pending.clear()
        self.store.flush()

    def close(self):
        self.flush()
        self.store.close()

    def recover(self):
        entry = self.store.latest_full()
        if entry is None:
            raise FileNotFoundError("no checkpoint")
        return self.store.load_full(entry), 0

    def stats(self):
        return {"store": self.store.stats(),
                "train_loop_ckpt_time": self.ckpt_time}


class FullSync(_Base):
    name = "full_sync"

    def train_step(self, state, batch):
        state, metrics, _ = self.step_fn(state, batch)
        step = int(state["step"])
        if step % self.interval == 0:
            t0 = time.perf_counter()
            self.store.save_full(step, host_copy(state))   # blocking
            self.ckpt_time += time.perf_counter() - t0
        return state, metrics


class CheckFreq(_Base):
    name = "checkfreq"

    def __init__(self, model, store, *, lr=1e-3, interval: int = 10):
        super().__init__(model, store, lr=lr, interval=interval)

    def train_step(self, state, batch):
        state, metrics, _ = self.step_fn(state, batch)
        step = int(state["step"])
        if step % self.interval == 0:
            t0 = time.perf_counter()
            # snapshot() is synchronous w.r.t. the update (WAR hazard in
            # the paper's analysis); persist() is async.
            snap = host_copy(state)
            self.ckpt_time += time.perf_counter() - t0
            self.flush()   # CheckFreq admits at most one in-flight persist
            self._pending.append(
                self._pool.submit(self.store.save_full, step, snap))
        return state, metrics


class Gemini(_Base):
    """In-memory checkpointing to (simulated peer) host DRAM."""
    name = "gemini"

    def __init__(self, model, store, *, lr=1e-3, interval: int = 1,
                 persist_interval: int = 100):
        super().__init__(model, store, lr=lr, interval=interval)
        self.persist_interval = persist_interval
        self.memory_ckpt: Optional[Dict] = None
        self.memory_step = -1

    def train_step(self, state, batch):
        state, metrics, _ = self.step_fn(state, batch)
        step = int(state["step"])
        if step % self.interval == 0:
            t0 = time.perf_counter()
            self.memory_ckpt = host_copy(state)      # "peer CPU memory"
            self.memory_step = step
            self.ckpt_time += time.perf_counter() - t0
        if step % self.persist_interval == 0:
            self._pending.append(self._pool.submit(
                self.store.save_full, step, self.memory_ckpt))
        return state, metrics

    def recover(self):
        if self.memory_ckpt is not None:
            return self.memory_ckpt, 0
        return super().recover()


class NaiveDC(_Base):
    """Differential checkpointing without gradient reuse (Check-N-Run
    transplanted to dense models). The differential is computed and
    compressed *inside the training loop* — the compression stall the
    paper measures in Fig. 1 — then written asynchronously."""
    name = "naive_dc"

    def __init__(self, model, store, *, lr=1e-3, rho=0.01,
                 interval: int = 1, full_interval: int = 50):
        super().__init__(model, store, lr=lr, interval=interval)
        self.rho = rho
        self.full_interval = full_interval

        @jax.jit
        def diff_compress(new_state, old_state):
            delta = {
                "params": jax.tree.map(
                    lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                    new_state["params"], old_state["params"]),
                "mu": jax.tree.map(lambda a, b: a - b, new_state["opt"].mu,
                                   old_state["opt"].mu),
                "nu": jax.tree.map(lambda a, b: a - b, new_state["opt"].nu,
                                   old_state["opt"].nu),
            }
            return compress_tree(delta, self.rho)   # compress all 3Ψ

        self._diff_compress = diff_compress

    def train_step(self, state, batch):
        old_state = state
        state, metrics, _ = self.step_fn(state, batch)
        step = int(state["step"])
        t0 = time.perf_counter()
        if step % self.interval == 0:
            cd = self._diff_compress(state, old_state)
            jax.block_until_ready(jax.tree.leaves(cd)[0])   # Challenge 1 stall
            payload = host_copy(cd)
            self._pending.append(
                self._pool.submit(self.store.save_diff, step, payload))
        if step % self.full_interval == 0:
            self._pending.append(self._pool.submit(
                self.store.save_full, step, host_copy(state)))
        self.ckpt_time += time.perf_counter() - t0
        return state, metrics

    def recover(self):
        from repro.core.recovery import load_latest_chain, \
            merge_deltas_pairwise
        state, diffs = load_latest_chain(self.store)
        if diffs:
            deltas = [decompress_tree(p) for _, p in diffs]
            merged, _ = merge_deltas_pairwise(deltas)
            state["params"] = jax.tree.map(
                lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
                state["params"], merged["params"])
            opt = state["opt"]
            state["opt"] = type(opt)(
                jax.tree.map(lambda a, b: a + b, opt.mu, merged["mu"]),
                jax.tree.map(lambda a, b: a + b, opt.nu, merged["nu"]),
                opt.count + len(diffs))
            state["step"] = np.asarray(diffs[-1][0], np.int32)
        return state, len(diffs)
