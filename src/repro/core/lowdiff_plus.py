"""LowDiff+: frequent checkpointing without gradient compression (§VI).

Two mechanisms on top of LowDiff:

* **Layer-wise gradient reusing & snapshotting** (Insight 1): the dense
  gradient pytree is snapshotted leaf-by-leaf by a thread pool — the JAX
  analogue of streaming each layer's bucket as backprop produces it (on
  TPU the D2H DMAs overlap compute; on this CPU container the overlap is
  the thread pool's concurrency). Each leaf is enqueued to the reusing
  queue as soon as its copy lands.

* **CPU-resident model replica + asynchronous persistence** (Insight 2):
  the checkpointing thread maintains a numpy replica of (params, Adam
  moments) and applies the reused gradient with a numpy Adam step — an
  always-up-to-date in-memory checkpoint (Gemini-style). Persistence
  writes the *replica*, never the raw gradients, every
  ``persist_interval`` steps — full+diff fused in host memory, so storage
  traffic is one model state, not a gradient stream.

Recovery: software failures restore from the in-memory replica
(near-instant); hardware failures reload the last persisted replica.

**Incremental-merging persistence** (``persist_mode="incremental"``):
the replica tracks which leaves each Adam apply actually changed, and
every persist after the first writes a *patch blob* holding only those
dirty leaves — storage bytes and host copies per persist are
O(changed bytes), not O(model). An optional ``persist_threshold``
defers near-converged leaves (accumulated relative L∞ drift below the
threshold) so they stop being re-persisted until they move enough to
matter. The checkpoint store journals each patch against its base full
and a background fold (the maintenance service's incremental merger)
pwrites accumulated patches into the base frame in place, so recovery
stays one frame read and the chain never grows unboundedly.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.patchset import RowUpdate, mask_to_intervals
from repro.checkpoint.store import CheckpointStore
from repro.compression.quant_span import (DIFF_QUANTS, QUANT_METER,
                                          QuantSpan, decode_rows,
                                          encode_rows, quant_bits)
from repro.core.reusing_queue import (CheckpointingError, ReusingQueue,
                                      wait_drained)
from repro.core.snapshot import host_copy, start_host_transfer
from repro.core.steps import make_train_step
from repro.obs.timeline import TIMELINE
from repro.obs.trace import trace_span


class _NumpyAdam:
    """Host-side Adam replica (elementwise; matches repro.optim.adam).

    With ``track_dirty`` the replica records, per leaf, whether its
    bytes diverged from the last persisted snapshot — the dirty set the
    incremental-merging persistence engine snapshots instead of the
    whole replica. A leaf whose gradient *and* both moments are all
    zero is provably unchanged by the step (the update is exactly 0)
    and is skipped without touching it; every other applied leaf is
    marked dirty and its accumulated L∞ parameter drift tracked for
    the optional ``--persist-threshold`` filter.

    ``dirty_granularity="row"`` drops the tracked unit from leaves to
    axis-0 rows: a row is provably unchanged when its gradient and both
    pre-update moment rows are all zero (its Adam update is exactly
    0.0), so a sparse step — one routed expert's rows of a big MoE
    table — dirties only those rows. Per-row drift carries the
    ``--persist-threshold`` semantics at row granularity, and adjacent
    dirty runs separated by up to ``coalesce_rows`` *clean* rows merge
    into one span before snapshot (re-writing a clean row is a
    byte-identical no-op, so bridging trades a few redundant bytes for
    far fewer spans; a dirty-but-deferred row is never bridged over).
    Scalar and single-row leaves keep leaf granularity.

    ``diff_quant`` ("int8"/"int4") additionally quantizes each persisted
    row span against per-row absmax scales
    (:class:`~repro.compression.quant_span.QuantSpan` payloads instead
    of raw :class:`RowUpdate`), holding a per-row **error-feedback
    residual** per component: the next quantization of a row encodes
    ``value + residual``, so deferred quantization error is corrected
    on the next persist instead of silently drifting. With a persist
    threshold active, a row whose residual exceeds the threshold is
    immediately re-marked dirty (at most once per quantized persist —
    a re-marked row that re-persists without a fresh gradient is not
    re-marked again, so a static row cannot ping-pong forever)."""

    GRANULARITIES = ("leaf", "row")

    def __init__(self, params, mu, nu, count, *, lr, b1=0.9, b2=0.999,
                 eps=1e-8, track_dirty: bool = False,
                 dirty_granularity: str = "leaf", coalesce_rows: int = 4,
                 diff_quant: str = "off"):
        if dirty_granularity not in self.GRANULARITIES:
            raise ValueError(f"dirty_granularity must be one of "
                             f"{self.GRANULARITIES}")
        if diff_quant not in DIFF_QUANTS:
            raise ValueError(f"diff_quant must be one of {DIFF_QUANTS}")
        self.params = {k: np.array(v, np.float32) if v.dtype != np.float32
                       else np.array(v) for k, v in params.items()}
        self.dtypes = {k: v.dtype for k, v in params.items()}
        self.mu = {k: np.array(v) for k, v in mu.items()}
        self.nu = {k: np.array(v) for k, v in nu.items()}
        self.count = int(count)
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps
        self.track_dirty = track_dirty
        self.dirty_granularity = dirty_granularity
        self.coalesce_rows = int(coalesce_rows)
        #: leaves whose replica bytes differ from the last snapshot
        self._dirty = set(self.params)
        #: accumulated L∞ parameter change since the leaf last persisted
        self._drift = {k: 0.0 for k in self.params}
        #: row-granular leaves: per-row dirty mask + drift (everything
        #: starts dirty, like the leaf-level set — nothing is persisted
        #: yet)
        self._row_dirty: Dict[str, np.ndarray] = {}
        self._row_drift: Dict[str, np.ndarray] = {}
        self.diff_quant = diff_quant
        #: per-(component, leaf) error-feedback residuals (f32, lazily
        #: allocated on a leaf's first quantized persist)
        self._row_resid: Dict[tuple, np.ndarray] = {}
        #: rows dirty *only* because quantization error re-marked them —
        #: they get one corrective persist, not an endless loop
        self._row_qpending: Dict[str, np.ndarray] = {}
        if track_dirty and dirty_granularity == "row":
            for k, v in self.params.items():
                if v.ndim >= 1 and v.shape[0] > 1:
                    self._row_dirty[k] = np.ones(v.shape[0], bool)
                    self._row_drift[k] = np.zeros(v.shape[0], np.float32)
                    if diff_quant != "off":
                        self._row_qpending[k] = np.zeros(v.shape[0], bool)
        self.skipped_applies = 0

    def _resid(self, comp: str, k: str, like: np.ndarray) -> np.ndarray:
        key = (comp, k)
        r = self._row_resid.get(key)
        if r is None:
            r = np.zeros(like.shape, np.float32)
            self._row_resid[key] = r
        return r

    @staticmethod
    def _row_any(a: np.ndarray) -> np.ndarray:
        """Per-row nonzero mask (bool, shape (rows,))."""
        return a.reshape(a.shape[0], -1).any(axis=1)

    def apply(self, grads: Dict[str, np.ndarray]):
        self.count += 1
        c1 = 1.0 - self.b1 ** self.count
        c2 = 1.0 - self.b2 ** self.count
        for k, g in grads.items():
            g = np.asarray(g, np.float32)
            mu = self.mu[k]
            nu = self.nu[k]
            if self.track_dirty and not (g.any() or mu.any() or nu.any()):
                # zero gradient onto zero moments: the update is exactly
                # zero and the moments stay zero — the leaf provably
                # does not change, so neither math nor dirty-marking runs
                self.skipped_applies += 1
                continue
            rd = self._row_dirty.get(k) if self.track_dirty else None
            if rd is not None:
                # pre-update mask: a row changes iff its gradient or a
                # pre-update moment row is nonzero (same proof as the
                # leaf-level skip, per row)
                changed = (self._row_any(g) | self._row_any(mu)
                           | self._row_any(nu))
            mu *= self.b1
            mu += (1 - self.b1) * g
            nu *= self.b2
            nu += (1 - self.b2) * g * g
            upd = self.lr * (mu / c1) / (np.sqrt(nu / c2) + self.eps)
            self.params[k] -= upd
            if self.track_dirty:
                self._dirty.add(k)
                if upd.size:
                    self._drift[k] += float(np.max(np.abs(upd)))
                if rd is not None:
                    rd |= changed
                    qp = self._row_qpending.get(k)
                    if qp is not None:
                        # a fresh gradient supersedes a pending
                        # quantization correction: the row is again
                        # eligible for an error-feedback re-mark
                        qp[changed] = False
                    if upd.size:
                        rowmax = np.abs(
                            upd.reshape(upd.shape[0], -1)).max(axis=1)
                        dr = self._row_drift[k]
                        dr[changed] += rowmax[changed].astype(np.float32)

    def state(self):
        return {"params": dict(self.params), "mu": dict(self.mu),
                "nu": dict(self.nu), "count": self.count}

    # -- persistence snapshots (caller holds the replica lock) ---------
    def snapshot_full(self):
        """Copy every leaf for a full persist; the whole replica is
        the persisted state, so all leaves become clean."""
        snap = {"params": {k: np.array(v) for k, v in self.params.items()},
                "mu": {k: np.array(v) for k, v in self.mu.items()},
                "nu": {k: np.array(v) for k, v in self.nu.items()},
                "count": np.array(self.count, np.int64)}
        if self.track_dirty:
            self._dirty.clear()
            self._drift = {k: 0.0 for k in self._drift}
            for k in self._row_dirty:
                self._row_dirty[k][:] = False
                self._row_drift[k][:] = 0.0
            # a raw full persists exact bytes: no deferred quant error
            for r in self._row_resid.values():
                r[:] = 0.0
            for qp in self._row_qpending.values():
                qp[:] = False
        return snap

    def snapshot_dirty(self, threshold: float = 0.0):
        """Copy only the dirty leaves — or, at row granularity, only
        each dirty leaf's dirty row spans as :class:`RowUpdate` values —
        plus the always-advancing Adam count, for an incremental
        persist. With ``threshold`` > 0 a dirty leaf (or row) whose
        accumulated relative L∞ drift is still below ``threshold``
        (scaled by the leaf's max |param|) is *deferred*: it stays
        dirty and its drift keeps accumulating, so near-converged state
        stops being re-persisted until it has moved enough to matter.
        Returns ``(partial state dict, deferred leaf count)`` — a
        row-granular leaf counts deferred only when *none* of its dirty
        rows passed the threshold."""
        updates = {"params": {}, "mu": {}, "nu": {},
                   "count": np.array(self.count, np.int64)}
        deferred = 0
        for k in sorted(self._dirty):
            rd = self._row_dirty.get(k)
            if rd is None:
                # leaf granularity (or a scalar / single-row leaf)
                if threshold > 0.0:
                    p = self.params[k]
                    scale = float(np.max(np.abs(p))) if p.size else 0.0
                    if self._drift[k] <= threshold * (scale + 1e-12):
                        deferred += 1
                        continue
                updates["params"][k] = np.array(self.params[k])
                updates["mu"][k] = np.array(self.mu[k])
                updates["nu"][k] = np.array(self.nu[k])
                self._dirty.discard(k)
                self._drift[k] = 0.0
                continue
            dr = self._row_drift[k]
            if threshold > 0.0:
                p = self.params[k]
                scale = float(np.max(np.abs(p))) if p.size else 0.0
                persist = rd & (dr > threshold * (scale + 1e-12))
            else:
                persist = rd.copy()
            if not persist.any():
                deferred += 1
                continue
            # bridge only across *clean* rows: a deferred dirty row's
            # replica bytes differ from its persisted bytes, so writing
            # it would defeat the deferral — a clean row re-writes to
            # identical bytes
            ivs = mask_to_intervals(persist, bridgeable=~rd,
                                    max_gap=self.coalesce_rows)
            rows = int(rd.shape[0])
            if self.diff_quant == "off":
                for comp, src in (("params", self.params),
                                  ("mu", self.mu), ("nu", self.nu)):
                    a = src[k]
                    if len(ivs) == 1 and ivs[0] == (0, rows):
                        # every row persists: plain whole-leaf update
                        # (same blob shape leaf granularity writes)
                        updates[comp][k] = np.array(a)
                    else:
                        updates[comp][k] = RowUpdate(
                            starts=np.asarray([s for s, _ in ivs],
                                              np.int64),
                            rows=[np.array(a[s:e]) for s, e in ivs],
                            shape=tuple(a.shape))
                rd[persist] = False
                dr[persist] = 0.0
            else:
                self._snapshot_quant(k, ivs, updates)
                rd[persist] = False
                # error feedback: the persisted rows now carry their
                # quantization error as drift — below any threshold it
                # just waits for the next real update to fold in, above
                # it the row is re-marked dirty for one corrective pass
                pres = self._row_resid[("params", k)]
                qerr = np.abs(pres.reshape(rows, -1)).max(axis=1) \
                    .astype(np.float32)
                dr[persist] = qerr[persist]
                if threshold > 0.0:
                    p = self.params[k]
                    scale = float(np.max(np.abs(p))) if p.size else 0.0
                    qp = self._row_qpending[k]
                    redo = (persist & (qerr > threshold * (scale + 1e-12))
                            & ~qp)
                    qp[persist] = False
                    qp[redo] = True
                    rd[redo] = True
            if rd.any():
                self._drift[k] = float(dr[rd].max())
            else:
                self._dirty.discard(k)
                self._drift[k] = 0.0
        return updates, deferred

    def _snapshot_quant(self, k: str, ivs, updates) -> None:
        """Emit one leaf's persisting intervals as
        :class:`~repro.compression.quant_span.QuantSpan` payloads,
        folding each component's error-feedback residual into the values
        being quantized and storing the fresh residual back.

        The Adam moments floor at 8 bits even under ``int4``: the
        update divides ``mu`` by ``sqrt(nu)``, so per-row quantization
        error in the moments is amplified by ``1/sqrt(nu)`` at small-
        moment elements — 4-bit moments make a resumed run take a huge
        first step and diverge, while 4-bit params + 8-bit moments
        resume within noise of raw (and still cut the patch stream
        >4x)."""
        pbits = quant_bits(self.diff_quant)
        t0 = time.perf_counter()
        bytes_in = bytes_out = 0
        starts = tuple(int(s) for s, _ in ivs)
        for comp, src in (("params", self.params), ("mu", self.mu),
                          ("nu", self.nu)):
            bits = pbits if comp == "params" else max(pbits, 8)
            a = src[k]
            res = self._resid(comp, k, a)
            qs, scales = [], []
            for s, e in ivs:
                corrected = a[s:e].astype(np.float32) + res[s:e]
                q, sc = encode_rows(corrected, bits)
                c2 = corrected.reshape(e - s, -1)
                deq = decode_rows(q, sc, c2.shape[1], bits)
                res[s:e] = (c2 - deq).reshape(corrected.shape)
                qs.append(q)
                scales.append(sc)
                bytes_in += int(a[s:e].nbytes)
            span = QuantSpan(starts=starts, qs=qs, scales=scales,
                             shape=tuple(a.shape), bits=bits,
                             dtype=np.dtype(a.dtype).name)
            bytes_out += span.nbytes
            updates[comp][k] = span
        QUANT_METER.add_encode(time.perf_counter() - t0, bytes_in,
                               bytes_out)

    def remark_dirty(self, updates) -> None:
        """Undo a snapshot's clean-marking after its persist *failed*:
        the leaves (or row spans) it carried never became durable, so
        they must ride the next persist or every later recovery
        silently restores stale values for them. Infinite drift defeats
        any threshold."""
        for k, v in updates.get("params", {}).items():
            self._dirty.add(k)
            self._drift[k] = float("inf")
            rd = self._row_dirty.get(k)
            if rd is None:
                continue
            dr = self._row_drift[k]
            if isinstance(v, (RowUpdate, QuantSpan)):
                extents = v.extents()
            else:
                extents = [(0, rd.shape[0])]
            for s, e in extents:
                rd[s:e] = True
                dr[s:e] = np.inf
                for comp in ("params", "mu", "nu"):
                    # the residual was computed against a snapshot that
                    # never landed — stale correction must not leak into
                    # the next quantization of these rows
                    res = self._row_resid.get((comp, k))
                    if res is not None:
                        res[s:e] = 0.0
                qp = self._row_qpending.get(k)
                if qp is not None:
                    qp[s:e] = False


def fold_due(since_fold: int, fold_interval: int, amplification: float,
             fold_amplification: float) -> bool:
    """Fold-trigger policy: adaptive on observed chain-read
    amplification (chain overlay bytes / base frame bytes crossing
    ``fold_amplification``), with the fixed patch count
    ``fold_interval`` as a cap. ``fold_interval == 0`` keeps its
    historical meaning — never fold — and ``fold_amplification <= 0``
    disables the adaptive trigger."""
    if not fold_interval:
        return False
    return (since_fold >= fold_interval
            or (fold_amplification > 0
                and amplification >= fold_amplification))


def _flatten(tree):
    """path-keyed flat dict of leaves."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(k): v for k, v in flat}


def _unflatten_like(tree, flat):
    leaves, treedef = jax.tree.flatten(tree)
    keys = [jax.tree_util.keystr(k)
            for k, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    return jax.tree.unflatten(treedef, [flat[k] for k in keys])


class LowDiffPlus:
    name = "lowdiff_plus"

    PERSIST_MODES = ("full", "incremental")

    def __init__(self, model, store: CheckpointStore, *, lr: float = 1e-3,
                 persist_interval: int = 1, snapshot_workers: int = 4,
                 queue_size: int = 8, flush_timeout: float = 120.0,
                 persist_mode: str = "full",
                 persist_threshold: float = 0.0, fold_interval: int = 16,
                 dirty_granularity: str = "leaf",
                 fold_amplification: float = 1.5,
                 diff_quant: str = "off"):
        if persist_mode not in self.PERSIST_MODES:
            raise ValueError(f"persist_mode must be one of "
                             f"{self.PERSIST_MODES}")
        if dirty_granularity not in _NumpyAdam.GRANULARITIES:
            raise ValueError(f"dirty_granularity must be one of "
                             f"{_NumpyAdam.GRANULARITIES}")
        if diff_quant not in DIFF_QUANTS:
            raise ValueError(f"diff_quant must be one of {DIFF_QUANTS}")
        if diff_quant != "off" and (persist_mode != "incremental"
                                    or dirty_granularity != "row"):
            raise ValueError(
                "--diff-quant quantizes row-span differentials: it "
                "requires --persist-mode incremental and "
                "--dirty-granularity row")
        if (persist_mode == "incremental" and store is not None
                and getattr(store.backend, "fmt", "npz") == "npz"):
            raise ValueError(
                "--persist-mode incremental patches checkpoint leaves "
                "in place, which requires the frame format; this store "
                "writes npz — use --format frame or --persist-mode full")
        self.model, self.store, self.lr = model, store, lr
        self.persist_interval = persist_interval
        self.flush_timeout = flush_timeout
        self.persist_mode = persist_mode
        self.persist_threshold = float(persist_threshold)
        #: schedule a background fold after this many patches (0 = never)
        self.fold_interval = int(fold_interval)
        self.dirty_granularity = dirty_granularity
        self.diff_quant = diff_quant
        #: adaptive fold trigger: fold when chain overlay bytes / base
        #: frame bytes crosses this (<= 0 disables; fold_interval caps)
        self.fold_amplification = float(fold_amplification)
        self.step_fn = make_train_step(model, mode="lowdiff_plus", lr=lr)
        self.queue = ReusingQueue(maxsize=queue_size)
        self._snap_pool = ThreadPoolExecutor(max_workers=snapshot_workers,
                                             thread_name_prefix="snapshot")
        self._persist_pool = ThreadPoolExecutor(max_workers=1,
                                                thread_name_prefix="persist")
        self._replica: Optional[_NumpyAdam] = None
        self._replica_lock = threading.Lock()
        self._consumer: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # _handle appends on the consumer thread while flush() iterates
        # and clears on the caller thread — must be locked
        self._pending = []
        self._pending_lock = threading.Lock()
        self._processed = 0
        self.ckpt_time = 0.0
        self.persists = 0
        self.patch_persists = 0
        self.leaves_deferred = 0
        self.adaptive_folds = 0
        # incremental-persist chain state: only ever touched on the
        # consumer / persist threads (single-threaded each, FIFO between)
        self._base_step: Optional[int] = None
        self._since_fold = 0

    # ------------------------------------------------------------------
    def attach(self, state):
        """Initialize the CPU replica from the live state (deepcopy)."""
        params = _flatten(state["params"])
        mu = _flatten(state["opt"].mu)
        nu = _flatten(state["opt"].nu)
        self._replica = _NumpyAdam(
            host_copy(params), host_copy(mu), host_copy(nu),
            int(state["opt"].count), lr=self.lr,
            track_dirty=(self.persist_mode == "incremental"),
            dirty_granularity=self.dirty_granularity,
            diff_quant=self.diff_quant)
        self._replica_step = int(state["step"])
        self._base_step = None

    def _start_consumer(self):
        if self.queue.error is not None:
            # a lost gradient means the replica is stale forever after:
            # fail fast instead of resuming the apply stream over a hole
            raise CheckpointingError(
                "checkpointing consumer previously failed; the CPU "
                "replica is missing gradients") from self.queue.error
        if self._consumer is None or not self._consumer.is_alive():
            self._stop.clear()
            self._consumer = threading.Thread(
                target=self.queue.drain, args=(self._handle, self._stop),
                daemon=True, name="lowdiffplus-ckpt")
            self._consumer.start()

    # ------------------------------------------------------------------
    def train_step(self, state, batch):
        if self._replica is None:
            self.attach(state)
            self._step_counter = int(state["step"])
        state, metrics, grads = self.step_fn(state, batch)
        t0 = time.perf_counter()
        self._step_counter += 1
        step = self._step_counter   # host-side: never forces the device
        self._start_consumer()
        flat = _flatten(grads)
        # layer-wise snapshot: enqueue every leaf's non-blocking D2H
        # transfer first (they all run concurrently with the next step),
        # then let the pool materialize each leaf as its bytes land
        start_host_transfer(flat)
        futures = {k: self._snap_pool.submit(np.asarray, v)
                   for k, v in flat.items()}
        blocked = self.queue.put(step, futures)
        TIMELINE.charge("queue_backpressure", blocked)
        self.ckpt_time += time.perf_counter() - t0
        return state, metrics

    def _handle(self, step: int, futures):
        with trace_span("ckpt.offload", "persist", step=step):
            grads = {k: f.result() for k, f in futures.items()}
        with self._replica_lock, \
                trace_span("replica.apply", "persist", step=step):
            self._replica.apply(grads)        # in-memory checkpoint update
            self._replica_step = step
        if step % self.persist_interval == 0:
            # snapshot under the lock (a concurrent recover_software
            # must never see a half-copied persist image) but submit
            # outside it — the lock is held only for the copy, and in
            # incremental mode the copy is only the *dirty* leaves, not
            # an O(model) deep copy of the whole replica
            incremental = (self.persist_mode == "incremental"
                           and self._base_step is not None)
            with self._replica_lock:
                if incremental:
                    updates, deferred = self._replica.snapshot_dirty(
                        self.persist_threshold)
                    self.leaves_deferred += deferred
                    snap = ("patch", self._base_step, updates)
                else:
                    snap = ("full", None, self._replica.snapshot_full())
            if snap[0] == "full" and self.persist_mode == "incremental":
                self._base_step = step      # later persists chain on it
            with self._pending_lock:
                self._pending.append(
                    self._persist_pool.submit(self._persist, step, snap))
        self._processed += 1

    def _persist(self, step: int, snap):
        kind, base_step, payload = snap
        with trace_span(f"persist.{kind}", "persist", step=step):
            return self._persist_impl(step, kind, base_step, payload)

    def _persist_impl(self, step: int, kind, base_step, payload):
        if kind == "full":
            self.store.save_full(
                step, payload,
                record_names=(self.persist_mode == "incremental"))
        else:
            try:
                self.store.save_patch(step, f"full_{base_step:08d}", payload)
            except BaseException:
                # the dirty bits were cleared at snapshot time; a lost
                # patch must re-dirty its leaves or no later patch ever
                # carries them again (an invisible, permanent hole)
                with self._replica_lock:
                    self._replica.remark_dirty(payload)
                raise
            self.patch_persists += 1
            self._since_fold += 1
            amp = self.store.chain_amplification()
            if fold_due(self._since_fold, self.fold_interval, amp,
                        self.fold_amplification):
                # bound the patch chain: fold it into the base frame off
                # the hot path (maintenance service when attached)
                if self._since_fold < self.fold_interval:
                    self.adaptive_folds += 1   # amplification fired first
                self._since_fold = 0
                self.store.request_fold()
        self.persists += 1

    def flush(self, timeout: Optional[float] = None):
        """Block until every enqueued gradient is applied to the replica
        and every scheduled persist (plus any pending maintenance
        slice) is durable. Never hangs: consumer failures re-raise here
        and the wait — including the store's maintenance drain — is
        deadline-bounded."""
        t = timeout if timeout is not None else self.flush_timeout
        deadline = time.monotonic() + t
        t0 = time.perf_counter()
        with trace_span("ckpt.flush", "persist"):
            wait_drained(self.queue, lambda: self._processed,
                         self._consumer, t)
            with self._pending_lock:
                pending = list(self._pending)
            for f in pending:
                f.result()              # a failure keeps the rest pending
            with self._pending_lock:
                # _handle only ever appends, so the futures just waited
                # on are exactly the list's prefix: drain it by index —
                # O(n) total — instead of the old O(n²) membership
                # re-scan
                del self._pending[:len(pending)]
            self.store.flush(timeout=max(0.0, deadline - time.monotonic()))
        TIMELINE.event("flush_stall", time.perf_counter() - t0,
                       step=self._step_counter)

    def close(self):
        try:
            self.flush()
        finally:
            self._stop.set()
            self.queue.close()
            if self._consumer is not None:
                self._consumer.join(timeout=5)
            self._snap_pool.shutdown(wait=True)
            self._persist_pool.shutdown(wait=True)
            self.store.close()

    # ------------------------------------------------------------------
    def recover_software(self, template_state):
        """Software failure: training process dies, checkpointing process
        (and its CPU replica) survives — restore from memory."""
        t_rec = time.perf_counter()
        with self._replica_lock, \
                trace_span("recovery.software", "recovery"):
            rep = self._replica.state()
        TIMELINE.event("recovery", time.perf_counter() - t_rec,
                       step=self._step_counter)
        dtypes = {k: np.asarray(v).dtype
                  for k, v in _flatten(template_state["params"]).items()}
        params = _unflatten_like(
            template_state["params"],
            {k: np.asarray(rep["params"][k]).astype(dtypes[k])
             for k in dtypes})
        opt = template_state["opt"]
        opt = type(opt)(_unflatten_like(opt.mu, rep["mu"]),
                        _unflatten_like(opt.nu, rep["nu"]),
                        np.asarray(rep["count"], np.int32))
        return {"params": params, "opt": opt,
                "step": np.asarray(self._replica_step, np.int32)}

    def recover_hardware(self, template_state):
        """Hardware failure: reload the last persisted replica — the
        latest full overlaid with its committed patch chain when
        persisting incrementally (one frame read once the background
        fold has consolidated it)."""
        t_rec = time.perf_counter()
        try:
            with trace_span("recovery.hardware", "recovery"):
                blob, step = self.store.load_latest_state()
        except FileNotFoundError:
            raise FileNotFoundError("no persisted checkpoint")
        TIMELINE.event("recovery", time.perf_counter() - t_rec,
                       step=self._step_counter)
        dtypes = {k: np.asarray(v).dtype
                  for k, v in _flatten(template_state["params"]).items()}
        params = _unflatten_like(
            template_state["params"],
            {k: np.asarray(blob["params"][k]).astype(dtypes[k])
             for k in dtypes})
        opt = template_state["opt"]
        opt = type(opt)(_unflatten_like(opt.mu, blob["mu"]),
                        _unflatten_like(opt.nu, blob["nu"]),
                        np.asarray(blob["count"], np.int32))
        return {"params": params, "opt": opt,
                "step": np.asarray(step, np.int32)}

    def stats(self):
        return {"queue": self.queue.stats(), "store": self.store.stats(),
                "train_loop_ckpt_time": self.ckpt_time,
                "persists": self.persists,
                "persist_mode": self.persist_mode,
                "dirty_granularity": self.dirty_granularity,
                "diff_quant": self.diff_quant,
                "quant": QUANT_METER.stats(),
                "patch_persists": self.patch_persists,
                "leaves_deferred": self.leaves_deferred,
                "fold_amplification": self.fold_amplification,
                "chain_amplification": self.store.chain_amplification(),
                "max_amplification": self.store.max_amplification,
                "adaptive_folds": self.adaptive_folds,
                "apply_leaves_skipped": (self._replica.skipped_applies
                                         if self._replica is not None
                                         else 0),
                "timeline": TIMELINE.stats()}
