"""LowDiff+: frequent checkpointing without gradient compression (§VI).

Two mechanisms on top of LowDiff:

* **Layer-wise gradient reusing & snapshotting** (Insight 1): the dense
  gradient pytree is snapshotted leaf-by-leaf by a thread pool — the JAX
  analogue of streaming each layer's bucket as backprop produces it (on
  TPU the D2H DMAs overlap compute; on this CPU container the overlap is
  the thread pool's concurrency). Each leaf is enqueued to the reusing
  queue as soon as its copy lands.

* **CPU-resident model replica + asynchronous persistence** (Insight 2):
  the checkpointing thread maintains a numpy replica of (params, Adam
  moments) and applies the reused gradient with a numpy Adam step — an
  always-up-to-date in-memory checkpoint (Gemini-style). Persistence
  writes the *replica*, never the raw gradients, every
  ``persist_interval`` steps — full+diff fused in host memory, so storage
  traffic is one model state, not a gradient stream.

Recovery: software failures restore from the in-memory replica
(near-instant); hardware failures reload the last persisted replica.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.core.reusing_queue import (CheckpointingError, ReusingQueue,
                                      wait_drained)
from repro.core.snapshot import host_copy, start_host_transfer
from repro.core.steps import make_train_step


class _NumpyAdam:
    """Host-side Adam replica (elementwise; matches repro.optim.adam)."""

    def __init__(self, params, mu, nu, count, *, lr, b1=0.9, b2=0.999,
                 eps=1e-8):
        self.params = {k: np.array(v, np.float32) if v.dtype != np.float32
                       else np.array(v) for k, v in params.items()}
        self.dtypes = {k: v.dtype for k, v in params.items()}
        self.mu = {k: np.array(v) for k, v in mu.items()}
        self.nu = {k: np.array(v) for k, v in nu.items()}
        self.count = int(count)
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps

    def apply(self, grads: Dict[str, np.ndarray]):
        self.count += 1
        c1 = 1.0 - self.b1 ** self.count
        c2 = 1.0 - self.b2 ** self.count
        for k, g in grads.items():
            g = np.asarray(g, np.float32)
            mu = self.mu[k]
            nu = self.nu[k]
            mu *= self.b1
            mu += (1 - self.b1) * g
            nu *= self.b2
            nu += (1 - self.b2) * g * g
            self.params[k] -= self.lr * (mu / c1) / (np.sqrt(nu / c2)
                                                     + self.eps)

    def state(self):
        return {"params": dict(self.params), "mu": dict(self.mu),
                "nu": dict(self.nu), "count": self.count}


def _flatten(tree):
    """path-keyed flat dict of leaves."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(k): v for k, v in flat}


def _unflatten_like(tree, flat):
    leaves, treedef = jax.tree.flatten(tree)
    keys = [jax.tree_util.keystr(k)
            for k, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    return jax.tree.unflatten(treedef, [flat[k] for k in keys])


class LowDiffPlus:
    name = "lowdiff_plus"

    def __init__(self, model, store: CheckpointStore, *, lr: float = 1e-3,
                 persist_interval: int = 1, snapshot_workers: int = 4,
                 queue_size: int = 8, flush_timeout: float = 120.0):
        self.model, self.store, self.lr = model, store, lr
        self.persist_interval = persist_interval
        self.flush_timeout = flush_timeout
        self.step_fn = make_train_step(model, mode="lowdiff_plus", lr=lr)
        self.queue = ReusingQueue(maxsize=queue_size)
        self._snap_pool = ThreadPoolExecutor(max_workers=snapshot_workers,
                                             thread_name_prefix="snapshot")
        self._persist_pool = ThreadPoolExecutor(max_workers=1,
                                                thread_name_prefix="persist")
        self._replica: Optional[_NumpyAdam] = None
        self._replica_lock = threading.Lock()
        self._consumer: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # _handle appends on the consumer thread while flush() iterates
        # and clears on the caller thread — must be locked
        self._pending = []
        self._pending_lock = threading.Lock()
        self._processed = 0
        self.ckpt_time = 0.0
        self.persists = 0

    # ------------------------------------------------------------------
    def attach(self, state):
        """Initialize the CPU replica from the live state (deepcopy)."""
        params = _flatten(state["params"])
        mu = _flatten(state["opt"].mu)
        nu = _flatten(state["opt"].nu)
        self._replica = _NumpyAdam(host_copy(params), host_copy(mu),
                                   host_copy(nu), int(state["opt"].count),
                                   lr=self.lr)
        self._replica_step = int(state["step"])

    def _start_consumer(self):
        if self.queue.error is not None:
            # a lost gradient means the replica is stale forever after:
            # fail fast instead of resuming the apply stream over a hole
            raise CheckpointingError(
                "checkpointing consumer previously failed; the CPU "
                "replica is missing gradients") from self.queue.error
        if self._consumer is None or not self._consumer.is_alive():
            self._stop.clear()
            self._consumer = threading.Thread(
                target=self.queue.drain, args=(self._handle, self._stop),
                daemon=True, name="lowdiffplus-ckpt")
            self._consumer.start()

    # ------------------------------------------------------------------
    def train_step(self, state, batch):
        if self._replica is None:
            self.attach(state)
            self._step_counter = int(state["step"])
        state, metrics, grads = self.step_fn(state, batch)
        t0 = time.perf_counter()
        self._step_counter += 1
        step = self._step_counter   # host-side: never forces the device
        self._start_consumer()
        flat = _flatten(grads)
        # layer-wise snapshot: enqueue every leaf's non-blocking D2H
        # transfer first (they all run concurrently with the next step),
        # then let the pool materialize each leaf as its bytes land
        start_host_transfer(flat)
        futures = {k: self._snap_pool.submit(np.asarray, v)
                   for k, v in flat.items()}
        self.queue.put(step, futures)
        self.ckpt_time += time.perf_counter() - t0
        return state, metrics

    def _handle(self, step: int, futures):
        grads = {k: f.result() for k, f in futures.items()}
        with self._replica_lock:
            self._replica.apply(grads)        # in-memory checkpoint update
            self._replica_step = step
        if step % self.persist_interval == 0:
            snap = {"params": {k: np.array(v) for k, v in
                               self._replica.params.items()},
                    "mu": {k: np.array(v) for k, v in self._replica.mu.items()},
                    "nu": {k: np.array(v) for k, v in self._replica.nu.items()},
                    "count": self._replica.count}
            with self._pending_lock:
                self._pending.append(
                    self._persist_pool.submit(self._persist, step, snap))
        self._processed += 1

    def _persist(self, step: int, payload):
        self.store.save_full(step, payload)
        self.persists += 1

    def flush(self, timeout: Optional[float] = None):
        """Block until every enqueued gradient is applied to the replica
        and every scheduled persist (plus any pending maintenance
        slice) is durable. Never hangs: consumer failures re-raise here
        and the wait — including the store's maintenance drain — is
        deadline-bounded."""
        t = timeout if timeout is not None else self.flush_timeout
        deadline = time.monotonic() + t
        wait_drained(self.queue, lambda: self._processed, self._consumer, t)
        with self._pending_lock:
            pending = list(self._pending)
        for f in pending:
            f.result()                  # a failure keeps the rest pending
        with self._pending_lock:
            self._pending = [f for f in self._pending if f not in pending]
        self.store.flush(timeout=max(0.0, deadline - time.monotonic()))

    def close(self):
        try:
            self.flush()
        finally:
            self._stop.set()
            self.queue.close()
            if self._consumer is not None:
                self._consumer.join(timeout=5)
            self._snap_pool.shutdown(wait=True)
            self._persist_pool.shutdown(wait=True)
            self.store.close()

    # ------------------------------------------------------------------
    def recover_software(self, template_state):
        """Software failure: training process dies, checkpointing process
        (and its CPU replica) survives — restore from memory."""
        with self._replica_lock:
            rep = self._replica.state()
        dtypes = {k: np.asarray(v).dtype
                  for k, v in _flatten(template_state["params"]).items()}
        params = _unflatten_like(
            template_state["params"],
            {k: np.asarray(rep["params"][k]).astype(dtypes[k])
             for k in dtypes})
        opt = template_state["opt"]
        opt = type(opt)(_unflatten_like(opt.mu, rep["mu"]),
                        _unflatten_like(opt.nu, rep["nu"]),
                        np.asarray(rep["count"], np.int32))
        return {"params": params, "opt": opt,
                "step": np.asarray(self._replica_step, np.int32)}

    def recover_hardware(self, template_state):
        """Hardware failure: reload the last persisted replica."""
        entry = self.store.latest_full()
        if entry is None:
            raise FileNotFoundError("no persisted checkpoint")
        blob = self.store.load_full(entry)
        dtypes = {k: np.asarray(v).dtype
                  for k, v in _flatten(template_state["params"]).items()}
        params = _unflatten_like(
            template_state["params"],
            {k: np.asarray(blob["params"][k]).astype(dtypes[k])
             for k in dtypes})
        opt = template_state["opt"]
        opt = type(opt)(_unflatten_like(opt.mu, blob["mu"]),
                        _unflatten_like(opt.nu, blob["nu"]),
                        np.asarray(blob["count"], np.int32))
        return {"params": params, "opt": opt,
                "step": np.asarray(entry["step"], np.int32)}

    def stats(self):
        return {"queue": self.queue.stats(), "store": self.store.stats(),
                "train_loop_ckpt_time": self.ckpt_time,
                "persists": self.persists}
