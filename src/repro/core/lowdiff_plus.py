"""LowDiff+: frequent checkpointing without gradient compression (§VI).

Two mechanisms on top of LowDiff:

* **Layer-wise gradient reusing & snapshotting** (Insight 1): the dense
  gradient pytree is snapshotted leaf-by-leaf by a thread pool — the JAX
  analogue of streaming each layer's bucket as backprop produces it (on
  TPU the D2H DMAs overlap compute; on this CPU container the overlap is
  the thread pool's concurrency). Each leaf is enqueued to the reusing
  queue as soon as its copy lands.

* **CPU-resident model replica + asynchronous persistence** (Insight 2):
  the checkpointing thread maintains a numpy replica of (params, Adam
  moments) and applies the reused gradient with a numpy Adam step — an
  always-up-to-date in-memory checkpoint (Gemini-style). Persistence
  writes the *replica*, never the raw gradients, every
  ``persist_interval`` steps — full+diff fused in host memory, so storage
  traffic is one model state, not a gradient stream.

Recovery: software failures restore from the in-memory replica
(near-instant); hardware failures reload the last persisted replica.

**Incremental-merging persistence** (``persist_mode="incremental"``):
the replica tracks which leaves each Adam apply actually changed, and
every persist after the first writes a *patch blob* holding only those
dirty leaves — storage bytes and host copies per persist are
O(changed bytes), not O(model). An optional ``persist_threshold``
defers near-converged leaves (accumulated relative L∞ drift below the
threshold) so they stop being re-persisted until they move enough to
matter. The checkpoint store journals each patch against its base full
and a background fold (the maintenance service's incremental merger)
pwrites accumulated patches into the base frame in place, so recovery
stays one frame read and the chain never grows unboundedly.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.core.reusing_queue import (CheckpointingError, ReusingQueue,
                                      wait_drained)
from repro.core.snapshot import host_copy, start_host_transfer
from repro.core.steps import make_train_step


class _NumpyAdam:
    """Host-side Adam replica (elementwise; matches repro.optim.adam).

    With ``track_dirty`` the replica records, per leaf, whether its
    bytes diverged from the last persisted snapshot — the dirty set the
    incremental-merging persistence engine snapshots instead of the
    whole replica. A leaf whose gradient *and* both moments are all
    zero is provably unchanged by the step (the update is exactly 0)
    and is skipped without touching it; every other applied leaf is
    marked dirty and its accumulated L∞ parameter drift tracked for
    the optional ``--persist-threshold`` filter."""

    def __init__(self, params, mu, nu, count, *, lr, b1=0.9, b2=0.999,
                 eps=1e-8, track_dirty: bool = False):
        self.params = {k: np.array(v, np.float32) if v.dtype != np.float32
                       else np.array(v) for k, v in params.items()}
        self.dtypes = {k: v.dtype for k, v in params.items()}
        self.mu = {k: np.array(v) for k, v in mu.items()}
        self.nu = {k: np.array(v) for k, v in nu.items()}
        self.count = int(count)
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps
        self.track_dirty = track_dirty
        #: leaves whose replica bytes differ from the last snapshot
        self._dirty = set(self.params)
        #: accumulated L∞ parameter change since the leaf last persisted
        self._drift = {k: 0.0 for k in self.params}
        self.skipped_applies = 0

    def apply(self, grads: Dict[str, np.ndarray]):
        self.count += 1
        c1 = 1.0 - self.b1 ** self.count
        c2 = 1.0 - self.b2 ** self.count
        for k, g in grads.items():
            g = np.asarray(g, np.float32)
            mu = self.mu[k]
            nu = self.nu[k]
            if self.track_dirty and not (g.any() or mu.any() or nu.any()):
                # zero gradient onto zero moments: the update is exactly
                # zero and the moments stay zero — the leaf provably
                # does not change, so neither math nor dirty-marking runs
                self.skipped_applies += 1
                continue
            mu *= self.b1
            mu += (1 - self.b1) * g
            nu *= self.b2
            nu += (1 - self.b2) * g * g
            upd = self.lr * (mu / c1) / (np.sqrt(nu / c2) + self.eps)
            self.params[k] -= upd
            if self.track_dirty:
                self._dirty.add(k)
                if upd.size:
                    self._drift[k] += float(np.max(np.abs(upd)))

    def state(self):
        return {"params": dict(self.params), "mu": dict(self.mu),
                "nu": dict(self.nu), "count": self.count}

    # -- persistence snapshots (caller holds the replica lock) ---------
    def snapshot_full(self):
        """Copy every leaf for a full persist; the whole replica is
        the persisted state, so all leaves become clean."""
        snap = {"params": {k: np.array(v) for k, v in self.params.items()},
                "mu": {k: np.array(v) for k, v in self.mu.items()},
                "nu": {k: np.array(v) for k, v in self.nu.items()},
                "count": np.array(self.count, np.int64)}
        if self.track_dirty:
            self._dirty.clear()
            self._drift = {k: 0.0 for k in self._drift}
        return snap

    def snapshot_dirty(self, threshold: float = 0.0):
        """Copy only the dirty leaves (plus the always-advancing Adam
        count) for an incremental persist. With ``threshold`` > 0 a
        dirty leaf whose accumulated relative L∞ drift is still below
        ``threshold`` is *deferred*: it stays dirty and its drift keeps
        accumulating, so a near-converged leaf stops being re-persisted
        until it has moved enough to matter. Returns ``(partial state
        dict, deferred leaf count)``."""
        updates = {"params": {}, "mu": {}, "nu": {},
                   "count": np.array(self.count, np.int64)}
        deferred = 0
        for k in sorted(self._dirty):
            if threshold > 0.0:
                p = self.params[k]
                scale = float(np.max(np.abs(p))) if p.size else 0.0
                if self._drift[k] <= threshold * (scale + 1e-12):
                    deferred += 1
                    continue
            updates["params"][k] = np.array(self.params[k])
            updates["mu"][k] = np.array(self.mu[k])
            updates["nu"][k] = np.array(self.nu[k])
            self._dirty.discard(k)
            self._drift[k] = 0.0
        return updates, deferred

    def remark_dirty(self, updates) -> None:
        """Undo a snapshot's clean-marking after its persist *failed*:
        the leaves it carried never became durable, so they must ride
        the next persist or every later recovery silently restores
        stale values for them. Infinite drift defeats any threshold."""
        for k in updates.get("params", {}):
            self._dirty.add(k)
            self._drift[k] = float("inf")


def _flatten(tree):
    """path-keyed flat dict of leaves."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(k): v for k, v in flat}


def _unflatten_like(tree, flat):
    leaves, treedef = jax.tree.flatten(tree)
    keys = [jax.tree_util.keystr(k)
            for k, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    return jax.tree.unflatten(treedef, [flat[k] for k in keys])


class LowDiffPlus:
    name = "lowdiff_plus"

    PERSIST_MODES = ("full", "incremental")

    def __init__(self, model, store: CheckpointStore, *, lr: float = 1e-3,
                 persist_interval: int = 1, snapshot_workers: int = 4,
                 queue_size: int = 8, flush_timeout: float = 120.0,
                 persist_mode: str = "full",
                 persist_threshold: float = 0.0, fold_interval: int = 16):
        if persist_mode not in self.PERSIST_MODES:
            raise ValueError(f"persist_mode must be one of "
                             f"{self.PERSIST_MODES}")
        if (persist_mode == "incremental" and store is not None
                and getattr(store.backend, "fmt", "npz") == "npz"):
            raise ValueError(
                "--persist-mode incremental patches checkpoint leaves "
                "in place, which requires the frame format; this store "
                "writes npz — use --format frame or --persist-mode full")
        self.model, self.store, self.lr = model, store, lr
        self.persist_interval = persist_interval
        self.flush_timeout = flush_timeout
        self.persist_mode = persist_mode
        self.persist_threshold = float(persist_threshold)
        #: schedule a background fold after this many patches (0 = never)
        self.fold_interval = int(fold_interval)
        self.step_fn = make_train_step(model, mode="lowdiff_plus", lr=lr)
        self.queue = ReusingQueue(maxsize=queue_size)
        self._snap_pool = ThreadPoolExecutor(max_workers=snapshot_workers,
                                             thread_name_prefix="snapshot")
        self._persist_pool = ThreadPoolExecutor(max_workers=1,
                                                thread_name_prefix="persist")
        self._replica: Optional[_NumpyAdam] = None
        self._replica_lock = threading.Lock()
        self._consumer: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # _handle appends on the consumer thread while flush() iterates
        # and clears on the caller thread — must be locked
        self._pending = []
        self._pending_lock = threading.Lock()
        self._processed = 0
        self.ckpt_time = 0.0
        self.persists = 0
        self.patch_persists = 0
        self.leaves_deferred = 0
        # incremental-persist chain state: only ever touched on the
        # consumer / persist threads (single-threaded each, FIFO between)
        self._base_step: Optional[int] = None
        self._since_fold = 0

    # ------------------------------------------------------------------
    def attach(self, state):
        """Initialize the CPU replica from the live state (deepcopy)."""
        params = _flatten(state["params"])
        mu = _flatten(state["opt"].mu)
        nu = _flatten(state["opt"].nu)
        self._replica = _NumpyAdam(
            host_copy(params), host_copy(mu), host_copy(nu),
            int(state["opt"].count), lr=self.lr,
            track_dirty=(self.persist_mode == "incremental"))
        self._replica_step = int(state["step"])
        self._base_step = None

    def _start_consumer(self):
        if self.queue.error is not None:
            # a lost gradient means the replica is stale forever after:
            # fail fast instead of resuming the apply stream over a hole
            raise CheckpointingError(
                "checkpointing consumer previously failed; the CPU "
                "replica is missing gradients") from self.queue.error
        if self._consumer is None or not self._consumer.is_alive():
            self._stop.clear()
            self._consumer = threading.Thread(
                target=self.queue.drain, args=(self._handle, self._stop),
                daemon=True, name="lowdiffplus-ckpt")
            self._consumer.start()

    # ------------------------------------------------------------------
    def train_step(self, state, batch):
        if self._replica is None:
            self.attach(state)
            self._step_counter = int(state["step"])
        state, metrics, grads = self.step_fn(state, batch)
        t0 = time.perf_counter()
        self._step_counter += 1
        step = self._step_counter   # host-side: never forces the device
        self._start_consumer()
        flat = _flatten(grads)
        # layer-wise snapshot: enqueue every leaf's non-blocking D2H
        # transfer first (they all run concurrently with the next step),
        # then let the pool materialize each leaf as its bytes land
        start_host_transfer(flat)
        futures = {k: self._snap_pool.submit(np.asarray, v)
                   for k, v in flat.items()}
        self.queue.put(step, futures)
        self.ckpt_time += time.perf_counter() - t0
        return state, metrics

    def _handle(self, step: int, futures):
        grads = {k: f.result() for k, f in futures.items()}
        with self._replica_lock:
            self._replica.apply(grads)        # in-memory checkpoint update
            self._replica_step = step
        if step % self.persist_interval == 0:
            # snapshot under the lock (a concurrent recover_software
            # must never see a half-copied persist image) but submit
            # outside it — the lock is held only for the copy, and in
            # incremental mode the copy is only the *dirty* leaves, not
            # an O(model) deep copy of the whole replica
            incremental = (self.persist_mode == "incremental"
                           and self._base_step is not None)
            with self._replica_lock:
                if incremental:
                    updates, deferred = self._replica.snapshot_dirty(
                        self.persist_threshold)
                    self.leaves_deferred += deferred
                    snap = ("patch", self._base_step, updates)
                else:
                    snap = ("full", None, self._replica.snapshot_full())
            if snap[0] == "full" and self.persist_mode == "incremental":
                self._base_step = step      # later persists chain on it
            with self._pending_lock:
                self._pending.append(
                    self._persist_pool.submit(self._persist, step, snap))
        self._processed += 1

    def _persist(self, step: int, snap):
        kind, base_step, payload = snap
        if kind == "full":
            self.store.save_full(
                step, payload,
                record_names=(self.persist_mode == "incremental"))
        else:
            try:
                self.store.save_patch(step, f"full_{base_step:08d}", payload)
            except BaseException:
                # the dirty bits were cleared at snapshot time; a lost
                # patch must re-dirty its leaves or no later patch ever
                # carries them again (an invisible, permanent hole)
                with self._replica_lock:
                    self._replica.remark_dirty(payload)
                raise
            self.patch_persists += 1
            self._since_fold += 1
            if self.fold_interval and self._since_fold >= self.fold_interval:
                # bound the patch chain: fold it into the base frame off
                # the hot path (maintenance service when attached)
                self._since_fold = 0
                self.store.request_fold()
        self.persists += 1

    def flush(self, timeout: Optional[float] = None):
        """Block until every enqueued gradient is applied to the replica
        and every scheduled persist (plus any pending maintenance
        slice) is durable. Never hangs: consumer failures re-raise here
        and the wait — including the store's maintenance drain — is
        deadline-bounded."""
        t = timeout if timeout is not None else self.flush_timeout
        deadline = time.monotonic() + t
        wait_drained(self.queue, lambda: self._processed, self._consumer, t)
        with self._pending_lock:
            pending = list(self._pending)
        for f in pending:
            f.result()                  # a failure keeps the rest pending
        with self._pending_lock:
            # _handle only ever appends, so the futures just waited on
            # are exactly the list's prefix: drain it by index — O(n)
            # total — instead of the old O(n²) membership re-scan
            del self._pending[:len(pending)]
        self.store.flush(timeout=max(0.0, deadline - time.monotonic()))

    def close(self):
        try:
            self.flush()
        finally:
            self._stop.set()
            self.queue.close()
            if self._consumer is not None:
                self._consumer.join(timeout=5)
            self._snap_pool.shutdown(wait=True)
            self._persist_pool.shutdown(wait=True)
            self.store.close()

    # ------------------------------------------------------------------
    def recover_software(self, template_state):
        """Software failure: training process dies, checkpointing process
        (and its CPU replica) survives — restore from memory."""
        with self._replica_lock:
            rep = self._replica.state()
        dtypes = {k: np.asarray(v).dtype
                  for k, v in _flatten(template_state["params"]).items()}
        params = _unflatten_like(
            template_state["params"],
            {k: np.asarray(rep["params"][k]).astype(dtypes[k])
             for k in dtypes})
        opt = template_state["opt"]
        opt = type(opt)(_unflatten_like(opt.mu, rep["mu"]),
                        _unflatten_like(opt.nu, rep["nu"]),
                        np.asarray(rep["count"], np.int32))
        return {"params": params, "opt": opt,
                "step": np.asarray(self._replica_step, np.int32)}

    def recover_hardware(self, template_state):
        """Hardware failure: reload the last persisted replica — the
        latest full overlaid with its committed patch chain when
        persisting incrementally (one frame read once the background
        fold has consolidated it)."""
        try:
            blob, step = self.store.load_latest_state()
        except FileNotFoundError:
            raise FileNotFoundError("no persisted checkpoint")
        dtypes = {k: np.asarray(v).dtype
                  for k, v in _flatten(template_state["params"]).items()}
        params = _unflatten_like(
            template_state["params"],
            {k: np.asarray(blob["params"][k]).astype(dtypes[k])
             for k in dtypes})
        opt = template_state["opt"]
        opt = type(opt)(_unflatten_like(opt.mu, blob["mu"]),
                        _unflatten_like(opt.nu, blob["nu"]),
                        np.asarray(blob["count"], np.int32))
        return {"params": params, "opt": opt,
                "step": np.asarray(step, np.int32)}

    def stats(self):
        return {"queue": self.queue.stats(), "store": self.store.stats(),
                "train_loop_ckpt_time": self.ckpt_time,
                "persists": self.persists,
                "persist_mode": self.persist_mode,
                "patch_persists": self.patch_persists,
                "leaves_deferred": self.leaves_deferred,
                "apply_leaves_skipped": (self._replica.skipped_applies
                                         if self._replica is not None
                                         else 0)}
