"""Declarative engine configuration + the single engine factory.

``train.py`` used to hand-thread ~35 argparse flags through
``make_store`` and a ``build_strategy`` dispatch; ``serve.py``,
examples and benchmarks each re-threaded their own subset. This module
owns that mapping in one place:

* :class:`EngineConfig` — strategy + optimizer/persistence knobs + a
  nested :class:`~repro.checkpoint.config.StoreConfig`.
* :meth:`EngineConfig.from_args` — the *only* flag -> config mapping,
  driven by :data:`FLAG_MAP` (which ``tests/test_flag_config_sync.py``
  checks against the actual parser, so a new flag without a config
  field — or vice versa — fails CI).
* :func:`make_engine` — one factory covering LowDiff / LowDiff+ and
  every baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.checkpoint.config import StoreConfig, StoreConfigError

STRATEGIES = ("none", "lowdiff", "lowdiff_plus", "checkfreq", "gemini",
              "naive_dc", "full_sync")

#: argparse dest -> (scope, field). Scopes: "engine" (EngineConfig
#: field), "store" (StoreConfig field), "tier:<kind>" (TierSpec field
#: on that tier). The single source of truth for from_args AND for the
#: flag<->config sync guard — add a flag here or the guard fails.
FLAG_MAP: Dict[str, tuple] = {
    "strategy": ("engine", "strategy"),
    "lr": ("engine", "lr"),
    "rho": ("engine", "rho"),
    "full_interval": ("engine", "full_interval"),
    "batch_size": ("engine", "batch_size"),
    "compressor": ("engine", "compressor"),
    "persist_mode": ("engine", "persist_mode"),
    "persist_threshold": ("engine", "persist_threshold"),
    "dirty_granularity": ("engine", "dirty_granularity"),
    "diff_quant": ("engine", "diff_quant"),
    "fold_interval": ("engine", "fold_interval"),
    "fold_amplification": ("engine", "fold_amplification"),
    "replay_window": ("engine", "replay_window"),
    "replay_device": ("engine", "replay_device"),
    "snapshot_shards": ("engine", "snapshot_shards"),
    "maintenance": ("engine", "maintenance"),
    "gc_slice": ("engine", "gc_slice"),
    "merge_slice": ("engine", "merge_slice"),
    "scrub_interval": ("engine", "scrub_interval"),
    "trace_out": ("engine", "trace_out"),
    "metrics_out": ("engine", "metrics_out"),
    "trace_buffer": ("engine", "trace_buffer"),
    "ckpt_dir": ("store", "root"),
    "format": ("store", "fmt"),
    "retention": ("store", "retention_fulls"),
    "host_id": ("store", "host_id"),
    "backend": ("store", "tiers"),          # legacy name -> tier list
    "shards": ("tier:sharded", "shards"),
    "memory_capacity_mb": ("tier:memory", "capacity_mb"),
    "eviction": ("tier:memory", "eviction"),
    "remote_url": ("tier:remote", "url"),
    "chunk_mb": ("tier:remote", "chunk_mb"),
    "max_retries": ("tier:remote", "max_retries"),
    "remote_fault_rate": ("tier:remote", "fault_rate"),
    "peers": ("tier:peer", "replicas"),
    "peer_hub": ("tier:peer", "hub"),
    "peer_domain": ("tier:peer", "domain"),
    "peer_window": ("tier:peer", "window"),
    "peer_fault_rate": ("tier:peer", "fault_rate"),
}

#: parser dests that are runtime inputs, not engine/store config
RUNTIME_FLAGS = frozenset({"arch", "reduced", "steps", "batch", "seq",
                           "seed", "log_every", "fail_at", "clean",
                           "log_level"})


@dataclasses.dataclass
class EngineConfig:
    """Everything needed to build a checkpointing engine: the strategy,
    its knobs, and the store topology it persists through."""

    strategy: str = "lowdiff"
    lr: float = 1e-3
    rho: float = 0.01
    full_interval: int = 20     #: 0 = Eq. (10) optimum + online tuning
    batch_size: int = 2         #: 0 = Eq. (10) optimum + online tuning
    compressor: str = "topk"
    persist_mode: str = "full"
    persist_threshold: float = 0.0
    dirty_granularity: str = "leaf"
    diff_quant: str = "off"     #: quantize row-span patches (int8/int4)
    fold_interval: int = 16
    fold_amplification: float = 1.5
    replay_window: int = 0
    replay_device: bool = False   #: scan compressed payloads on device
    snapshot_shards: int = 4      #: 0 = whole-tree D2H, >0 = per-shard
    maintenance: bool = False
    gc_slice: int = 64
    merge_slice: int = 64
    scrub_interval: float = 0.0
    trace_out: Optional[str] = None   #: Chrome trace_event JSON path
    metrics_out: Optional[str] = None  #: step/metric JSONL path
    trace_buffer: int = 65536          #: span ring-buffer capacity
    store: Optional[StoreConfig] = None

    # ------------------------------------------------------------------
    def validate(self) -> None:
        if self.strategy not in STRATEGIES:
            raise StoreConfigError(
                f"strategy: {self.strategy!r} is not one of {STRATEGIES}")
        if self.persist_mode not in ("full", "incremental"):
            raise StoreConfigError(
                f"persist_mode: {self.persist_mode!r} is not "
                f"'full'/'incremental'")
        if self.dirty_granularity not in ("leaf", "row"):
            raise StoreConfigError(
                f"dirty_granularity: {self.dirty_granularity!r} is not "
                f"'leaf'/'row'")
        if self.diff_quant not in ("off", "int8", "int4"):
            raise StoreConfigError(
                f"diff_quant: {self.diff_quant!r} is not one of "
                f"('off', 'int8', 'int4')")
        if self.compressor not in ("topk", "quant8", "packed"):
            raise StoreConfigError(
                f"compressor: {self.compressor!r} is not one of "
                f"('topk', 'quant8', 'packed')")
        if self.store is not None:
            self.store.validate()

    # ------------------------------------------------------------------
    @classmethod
    def from_args(cls, ns: Any) -> "EngineConfig":
        """Build from an argparse namespace (tolerates missing
        attributes — ``examples/train_with_failures.py`` passes a
        partial Namespace). The one flag -> config mapping."""
        defaults = {f.name: f.default for f in dataclasses.fields(cls)}

        def flag(dest: str, default: Any) -> Any:
            return getattr(ns, dest, default)

        kw: Dict[str, Any] = {}
        for dest, (scope, field) in FLAG_MAP.items():
            if scope != "engine":
                continue
            kw[field] = flag(dest, defaults[field])
        # bool knobs are on/off choices on the CLI
        for b in ("maintenance", "replay_device"):
            if isinstance(kw.get(b), str):
                kw[b] = kw[b] == "on"
        root = flag("ckpt_dir", None)
        store = None
        if root:
            store = StoreConfig.from_legacy(
                root,
                backend=flag("backend", "local"),
                shards=flag("shards", 4),
                capacity_mb=flag("memory_capacity_mb", None),
                retention_fulls=flag("retention", 0),
                remote_url=flag("remote_url", None),
                chunk_mb=flag("chunk_mb", 4.0),
                max_retries=flag("max_retries", 4),
                remote_fault_rate=flag("remote_fault_rate", 0.0),
                fmt=flag("format", "frame"),
                eviction=flag("eviction", "fifo"),
                host_id=flag("host_id", None),
                peers=flag("peers", 0),
                peer_hub=flag("peer_hub", None),
                peer_domain=flag("peer_domain", "d0"),
                peer_window=flag("peer_window", 8),
                peer_fault_rate=flag("peer_fault_rate", 0.0),
                simulate_peers=True)
        cfg = cls(store=store, **kw)
        cfg.validate()
        return cfg

    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        out = {f.name: getattr(self, f.name)
               for f in dataclasses.fields(self) if f.name != "store"}
        out["store"] = None if self.store is None else self.store.to_dict()
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "EngineConfig":
        d = dict(d)
        store_raw = d.pop("store", None)
        known = {f.name for f in dataclasses.fields(cls)}
        for k in d:
            if k not in known:
                raise StoreConfigError(f"{k}: unknown field")
        cfg = cls(store=(None if store_raw is None
                         else StoreConfig.from_dict(store_raw)), **d)
        cfg.validate()
        return cfg

    # ------------------------------------------------------------------
    def build_store(self):
        """Build the store (None when no store is configured) and, when
        ``maintenance`` is on, attach + start the background service."""
        if self.store is None:
            return None
        store = self.store.build()
        if self.maintenance:
            from repro.maintenance import MaintenanceService
            svc = MaintenanceService(store, gc_slice=self.gc_slice,
                                     merge_slice=self.merge_slice,
                                     scrub_interval=self.scrub_interval)
            store.attach_maintenance(svc)
            svc.start()
        return store


def make_engine(cfg: EngineConfig, model, store=None):
    """The single engine factory: build the configured strategy over
    ``store`` (built from ``cfg.store`` when not supplied). Returns
    None for strategy "none" — the caller runs the bare train step."""
    cfg.validate()
    if store is None:
        store = cfg.build_store()
    if cfg.strategy == "none":
        return None
    from repro.core.baselines import CheckFreq, FullSync, Gemini, NaiveDC
    from repro.core.config_opt import SystemParams
    from repro.core.lowdiff import LowDiff
    from repro.core.lowdiff_plus import LowDiffPlus
    if cfg.strategy == "lowdiff":
        # 0 = auto: seed (f, b) from the Eq. (10) closed form and keep
        # adapting them from observed merge times (online tuning)
        return LowDiff(model, store, rho=cfg.rho, lr=cfg.lr,
                       full_interval=cfg.full_interval or None,
                       batch_size=cfg.batch_size or None,
                       compressor=cfg.compressor,
                       sys_params=SystemParams(),
                       replay_window=cfg.replay_window or None,
                       replay_device=cfg.replay_device,
                       snapshot_shards=cfg.snapshot_shards)
    if cfg.strategy == "lowdiff_plus":
        return LowDiffPlus(model, store, lr=cfg.lr,
                           persist_interval=cfg.batch_size or 1,
                           persist_mode=cfg.persist_mode,
                           persist_threshold=cfg.persist_threshold,
                           dirty_granularity=cfg.dirty_granularity,
                           fold_interval=cfg.fold_interval,
                           fold_amplification=cfg.fold_amplification,
                           diff_quant=cfg.diff_quant)
    if cfg.strategy == "checkfreq":
        return CheckFreq(model, store, lr=cfg.lr, interval=10)
    if cfg.strategy == "gemini":
        return Gemini(model, store, lr=cfg.lr, interval=1,
                      persist_interval=cfg.full_interval)
    if cfg.strategy == "naive_dc":
        return NaiveDC(model, store, lr=cfg.lr, rho=cfg.rho,
                       full_interval=cfg.full_interval)
    if cfg.strategy == "full_sync":
        return FullSync(model, store, lr=cfg.lr, interval=cfg.full_interval)
    raise StoreConfigError(f"strategy: unknown strategy {cfg.strategy!r}")
