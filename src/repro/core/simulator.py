"""Event-driven failure/checkpoint simulator (Exp. 3, 4, 9, 10).

The paper's cluster-scale results (wasted time under MTBF, effective
training-time ratio vs #GPUs) depend on wall-clock constants this CPU
container cannot reproduce directly; the simulator replays the *logic* of
each strategy with measured-or-paper-sourced constants:

  iter_time          seconds per training iteration
  ckpt_overhead      extra seconds added to an iteration that checkpoints
  ckpt_interval      iterations between (differential or full) checkpoints
  recovery(t_fail)   seconds to restore + iterations of lost progress

Failures arrive as a Poisson process with the given MTBF. Deterministic
given the seed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class StrategyProfile:
    name: str
    iter_time: float                 # s, no checkpointing
    ckpt_overhead: float             # s added on checkpointing iterations
    ckpt_interval: int               # iterations between checkpoints
    restore_time: float              # s to load/restore a checkpoint
    per_diff_replay: float = 0.0     # s per differential replayed
    full_interval: Optional[int] = None   # for differential strategies
    batch_size: int = 1              # differentials lost with a failure


@dataclasses.dataclass
class SimResult:
    total_time: float
    useful_time: float
    wasted_time: float
    failures: int

    @property
    def effective_ratio(self) -> float:
        return self.useful_time / self.total_time


def simulate(profile: StrategyProfile, *, run_iters: int, mtbf_s: float,
             seed: int = 0) -> SimResult:
    rng = np.random.default_rng(seed)
    t = 0.0
    useful = 0.0
    done = 0
    failures = 0
    next_failure = rng.exponential(mtbf_s)
    last_ckpt_iter = 0

    while done < run_iters:
        it = profile.iter_time
        if (done + 1) % profile.ckpt_interval == 0:
            it += profile.ckpt_overhead
        if t + it >= next_failure:
            # failure mid-iteration: lose progress back to last checkpoint
            failures += 1
            t = next_failure
            lost_iters = done - last_ckpt_iter
            # half a batch of differentials lost on average (paper §V-C)
            lost_iters += profile.batch_size / 2.0
            done = max(last_ckpt_iter, 0)
            useful -= lost_iters * profile.iter_time
            # restore + replay differentials since the last full checkpoint
            n_diffs = 0
            if profile.full_interval:
                n_diffs = (last_ckpt_iter % profile.full_interval)
            t += profile.restore_time + n_diffs * profile.per_diff_replay
            next_failure = t + rng.exponential(mtbf_s)
            continue
        t += it
        done += 1
        useful += profile.iter_time
        if done % profile.ckpt_interval == 0:
            last_ckpt_iter = done

    useful = max(useful, 0.0)
    return SimResult(total_time=t, useful_time=useful,
                     wasted_time=t - useful, failures=failures)


# ----------------------------------------------------------------------
# Strategy profile factories: constants measured by the benchmark suite
# (CPU) or taken from the paper's hardware description, scaled by model
# checkpoint size.
# ----------------------------------------------------------------------

def paper_profiles(*, iter_time: float, full_bytes: float,
                   diff_bytes: float, write_bw: float = 5e9,
                   d2h_bw: float = 20e9, compress_stall: float = 0.0,
                   batch_size: int = 2, full_interval: int = 20):
    """Profiles for the five strategies with a shared cost model."""
    full_write = full_bytes / write_bw
    full_snap = full_bytes / d2h_bw
    diff_write = diff_bytes / write_bw

    return {
        # blocking snapshot + blocking write every 5 iterations
        "full_sync": StrategyProfile(
            "full_sync", iter_time, full_snap + full_write, 5,
            restore_time=full_write * 2),
        # synchronous snapshot every 10 iterations, async persist
        "checkfreq": StrategyProfile(
            "checkfreq", iter_time, full_snap, 10,
            restore_time=full_write * 2),
        # per-iteration in-memory ckpt; traffic scheduling hides most of
        # the peer copy — ~15% of the snapshot is non-overlappable
        "gemini": StrategyProfile(
            "gemini", iter_time, full_snap * 0.15, 1,
            restore_time=full_snap),
        # per-checkpoint: compress the 3Ψ differential (blocking) + write;
        # run at its own feasible interval (Exp. 4: 2-8 iterations)
        "naive_dc": StrategyProfile(
            "naive_dc", iter_time,
            compress_stall * 3 + diff_bytes * 3 / write_bw, 4,
            restore_time=full_write * 2,
            per_diff_replay=diff_bytes * 3 / d2h_bw,
            full_interval=full_interval),
        # per-iteration; the compressed-gradient write overlaps with the
        # iteration (Fig. 4) — only the overflow beyond one iteration stalls
        "lowdiff": StrategyProfile(
            "lowdiff", iter_time,
            max(0.0, diff_write - iter_time), 1,
            restore_time=full_write * 2, per_diff_replay=diff_bytes / d2h_bw,
            full_interval=full_interval, batch_size=batch_size),
        # layer-wise snapshot overlap leaves ~8% of the D2H exposed;
        # recovery from host memory
        "lowdiff_plus_s": StrategyProfile(
            "lowdiff_plus_s", iter_time, full_snap * 0.08, 1,
            restore_time=full_snap * 0.5),
        "lowdiff_plus_p": StrategyProfile(
            "lowdiff_plus_p", iter_time, full_snap * 0.08,
            max(1, int(np.ceil(full_write / iter_time))),
            restore_time=full_write * 2),
    }
