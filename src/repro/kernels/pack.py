"""Pallas TPU kernel: fused top-k select + int8 quantize + wire pack.

The seed pipeline ran compression in two kernels (top-k select, then —
only for the quant family — int8 quantization) and left packing to the
host serializer. This kernel fuses all three for the differential fast
path: one (R, BLOCK) VMEM tile per grid step is read **once**, the k
iterative argmax passes run in registers exactly as in ``topk.py``, the
selected values are immediately quantized against a per-row absmax
scale, and the three wire buffers (q int8, block-local indices, f32
scales) come out contiguous — the frame serializer streams them to
storage byte-for-byte, so the differential leaves the device already in
its persisted format. Still a single pass over the gradient: the fusion
removes the second gradient read and the host-side re-encode, not just
kernel-launch overhead.

The max |value| of a block is by construction the first top-k pick, so
the quantization scale needs no second reduction over the tile — it
falls out of the selection loop for free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8          # rows (blocks) per grid step — one f32 sublane tile


def _pack_kernel(x_ref, q_ref, idx_ref, scale_ref, *, k: int, block: int):
    x = x_ref[...]                                     # (R, BLOCK)
    xf = x.astype(jnp.float32)
    mag = jnp.abs(xf)
    iota = jax.lax.broadcasted_iota(jnp.int32, mag.shape, 1)

    def body(i, carry):
        mag, vals, idxs = carry
        m = jnp.max(mag, axis=1, keepdims=True)        # (R, 1)
        hit = mag == m
        idx = jnp.min(jnp.where(hit, iota, block), axis=1)      # (R,)
        sel = iota == idx[:, None]
        val = jnp.sum(jnp.where(sel, xf, 0.0), axis=1)          # (R,)
        vals = jax.lax.dynamic_update_index_in_dim(vals, val, i, 1)
        idxs = jax.lax.dynamic_update_index_in_dim(idxs, idx, i, 1)
        mag = jnp.where(sel, -1.0, mag)
        return mag, vals, idxs

    vals0 = jnp.zeros((x.shape[0], k), jnp.float32)
    idxs0 = jnp.zeros((x.shape[0], k), jnp.int32)
    _, vals, idxs = jax.lax.fori_loop(0, k, body, (mag, vals0, idxs0))
    # the first selection is the absmax of the block — its magnitude is
    # the quantization range, no extra reduction over the tile needed
    scale = jnp.maximum(
        jnp.abs(jax.lax.dynamic_index_in_dim(vals, 0, 1)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(vals / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    idx_ref[...] = idxs
    scale_ref[...] = scale


def pack_select(xb: jax.Array, k: int, *, interpret: bool = False):
    """xb: (nb, block) -> (q int8 (nb,k), indices int32 (nb,k),
    scale f32 (nb,1)) — fused top-k + quantize + pack, one read of x."""
    nb, block = xb.shape
    rows = min(ROWS, nb)
    assert nb % rows == 0
    kernel = functools.partial(_pack_kernel, k=k, block=block)
    return pl.pallas_call(
        kernel,
        grid=(nb // rows,),
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, k), lambda i: (i, 0)),
                   pl.BlockSpec((rows, k), lambda i: (i, 0)),
                   pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, k), jnp.int8),
                   jax.ShapeDtypeStruct((nb, k), jnp.int32),
                   jax.ShapeDtypeStruct((nb, 1), jnp.float32)],
        interpret=interpret,
    )(xb)


def _span_pack_kernel(x_ref, q_ref, scale_ref, *, bits: int):
    """Row-blocked absmax quantizer for state-row spans: one scale per
    row, int8 values or two int4 nibbles per byte (two's complement,
    even/odd columns -> low/high nibble)."""
    x = x_ref[...].astype(jnp.float32)                  # (R, C)
    qmax = 127.0 if bits == 8 else 7.0
    # reciprocal-multiply (not /qmax): matches the numpy host codec bit
    # for bit regardless of XLA's divide-by-constant rewrite
    scale = jnp.maximum(
        jnp.max(jnp.abs(x), axis=1, keepdims=True)
        * jnp.float32(1.0 / qmax), 1e-12)
    qi = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    if bits == 8:
        q_ref[...] = qi.astype(jnp.int8)
    else:
        R, C = qi.shape                                 # C even (pre-padded)
        lo = jax.lax.slice(qi, (0, 0), (R, C - 1), (1, 2)) & 0xF
        hi = jax.lax.slice(qi, (0, 1), (R, C), (1, 2)) & 0xF
        q_ref[...] = (lo | (hi << 4)).astype(jnp.uint8)
    scale_ref[...] = scale


def span_pack(xb: jax.Array, *, bits: int, interpret: bool = False):
    """xb: (nb, cols) f32 rows (cols even when bits == 4) ->
    (q (nb, wire_cols), scale f32 (nb, 1)) where wire_cols is cols for
    int8 and cols // 2 for nibble-packed int4 — the fused row-span
    quantizer feeding :class:`~repro.compression.quant_span.QuantSpan`."""
    assert bits in (8, 4)
    nb, cols = xb.shape
    assert bits == 8 or cols % 2 == 0
    rows = min(ROWS, nb)
    assert nb % rows == 0
    wire_cols = cols if bits == 8 else cols // 2
    wire_dt = jnp.int8 if bits == 8 else jnp.uint8
    kernel = functools.partial(_span_pack_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=(nb // rows,),
        in_specs=[pl.BlockSpec((rows, cols), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, wire_cols), lambda i: (i, 0)),
                   pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, wire_cols), wire_dt),
                   jax.ShapeDtypeStruct((nb, 1), jnp.float32)],
        interpret=interpret,
    )(xb)


def _unpack_kernel(q_ref, idx_ref, scale_ref, out_ref, *, block: int):
    vals = q_ref[...].astype(jnp.float32) * scale_ref[...]      # (R, k)
    idxs = idx_ref[...]
    R, k = vals.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (R, block), 1)

    def body(i, acc):
        sel = iota == jax.lax.dynamic_index_in_dim(idxs, i, 1)  # (R,1)->bcast
        v = jax.lax.dynamic_index_in_dim(vals, i, 1)
        return acc + jnp.where(sel, v, 0.0)

    acc = jax.lax.fori_loop(0, k, body, jnp.zeros((R, block), jnp.float32))
    out_ref[...] = acc


def pack_scatter(q: jax.Array, idxs: jax.Array, scale: jax.Array,
                 block: int, *, interpret: bool = False):
    """Inverse of pack_select: fused dequant + block-local scatter to a
    dense (nb, block) f32 tile — again a single kernel pass."""
    nb, k = q.shape
    rows = min(ROWS, nb)
    assert nb % rows == 0
    kernel = functools.partial(_unpack_kernel, block=block)
    return pl.pallas_call(
        kernel,
        grid=(nb // rows,),
        in_specs=[pl.BlockSpec((rows, k), lambda i: (i, 0)),
                  pl.BlockSpec((rows, k), lambda i: (i, 0)),
                  pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=interpret,
    )(q, idxs, scale)
