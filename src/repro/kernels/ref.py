"""Pure-jnp oracles for every Pallas kernel (test + CPU fallback path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_select_ref(xb: jax.Array, k: int):
    """xb: (nb, block) -> (values, indices) — magnitude top-k per block."""
    mag = jnp.abs(xb.astype(jnp.float32))
    _, idx = jax.lax.top_k(mag, k)
    vals = jnp.take_along_axis(xb, idx, axis=1)
    return vals, idx.astype(jnp.int32)


def topk_scatter_ref(vals: jax.Array, idxs: jax.Array, block: int):
    nb, k = vals.shape
    out = jnp.zeros((nb, block), vals.dtype)
    return jax.vmap(lambda o, i, v: o.at[i].add(v))(out, idxs, vals)


def pack_select_ref(xb: jax.Array, k: int):
    """Fused compress-and-pack oracle: top-k by magnitude, then int8
    quantization of the selected values against the per-row absmax."""
    mag = jnp.abs(xb.astype(jnp.float32))
    _, idx = jax.lax.top_k(mag, k)
    vals = jnp.take_along_axis(xb.astype(jnp.float32), idx, axis=1)
    scale = jnp.maximum(jnp.max(jnp.abs(vals), axis=1, keepdims=True)
                        / 127.0, 1e-12)
    q = jnp.clip(jnp.round(vals / scale), -127, 127).astype(jnp.int8)
    return q, idx.astype(jnp.int32), scale


def pack_scatter_ref(q: jax.Array, idxs: jax.Array, scale: jax.Array,
                     block: int):
    vals = q.astype(jnp.float32) * scale
    nb, k = vals.shape
    out = jnp.zeros((nb, block), jnp.float32)
    return jax.vmap(lambda o, i, v: o.at[i].add(v))(out, idxs, vals)


def quantize_ref(xb: jax.Array):
    x = xb.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0,
                        1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def adam_tile_update_ref(p, g, mu, nu, hyper):
    lr, b1, b2, eps, c1, c2 = (hyper[0, i] for i in range(6))
    pf, gf = p.astype(jnp.float32), g.astype(jnp.float32)
    mu2 = b1 * mu + (1.0 - b1) * gf
    nu2 = b2 * nu + (1.0 - b2) * gf * gf
    step = lr * (mu2 / c1) / (jnp.sqrt(nu2 / c2) + eps)
    return (pf - step).astype(p.dtype), mu2, nu2


# ---------------- fused decompress-and-apply (replay path) -----------------

def adam_replay_update_ref(p, g, mu, nu, hyper):
    """Adam tail for the replay kernels: identical to
    ``adam_tile_update_ref`` except the moment complements come from
    hyper slots 6/7 (pre-rounded ``1-b1`` / ``1-b2``), matching
    ``optim.adam.adam_update`` bit for bit."""
    lr, b1, b2, eps, c1, c2, om1, om2 = (hyper[0, i] for i in range(8))
    pf, gf = p.astype(jnp.float32), g.astype(jnp.float32)
    mu2 = b1 * mu + om1 * gf
    nu2 = b2 * nu + om2 * gf * gf
    step = lr * (mu2 / c1) / (jnp.sqrt(nu2 / c2) + eps)
    return (pf - step).astype(p.dtype), mu2, nu2


def topk_apply_ref(vals, idxs, p, mu, nu, hyper, *, block: int):
    """Scatter-decode a top-k wire payload and apply one Adam step —
    oracle for ``replay.topk_apply`` (decode math == the host
    decompressors', update == ``optim.adam.adam_update``)."""
    nb, k = vals.shape
    g = jnp.zeros((nb, block), jnp.float32)
    g = jax.vmap(lambda o, i, v: o.at[i].add(v))(
        g, idxs, vals.astype(jnp.float32))
    return adam_replay_update_ref(p, g, mu, nu, hyper)


def packed_apply_ref(q, idxs, scale, p, mu, nu, hyper, *, block: int):
    """Dequant + scatter-decode a packed (int8 top-k) payload and apply
    one Adam step — oracle for ``replay.packed_apply``."""
    vals = q.astype(jnp.float32) * scale
    return topk_apply_ref(vals, idxs, p, mu, nu, hyper, block=block)


def quant_apply_ref(q, scale, p, mu, nu, hyper):
    """Dequant a quant8 payload and apply one Adam step — oracle for
    ``replay.quant_apply``. q: (nb, block) int8; scale: (nb, 1) f32."""
    g = q.astype(jnp.float32) * scale
    return adam_replay_update_ref(p, g, mu, nu, hyper)


# -------------------- quantized row-span codec -----------------------

def span_pack_ref(xb: jax.Array, bits: int):
    """Oracle for ``pack.span_pack``: per-row absmax quantize (int8 or
    nibble-packed int4). xb: (nb, cols) with cols even for int4."""
    x = xb.astype(jnp.float32)
    qmax = 127.0 if bits == 8 else 7.0
    # reciprocal-multiply, matching the numpy host codec bit for bit
    scale = jnp.maximum(
        jnp.max(jnp.abs(x), axis=1, keepdims=True)
        * jnp.float32(1.0 / qmax), 1e-12)
    qi = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    if bits == 8:
        return qi.astype(jnp.int8), scale
    lo = qi[:, 0::2] & 0xF
    hi = qi[:, 1::2] & 0xF
    return (lo | (hi << 4)).astype(jnp.uint8), scale


def span_decode_ref(q: jax.Array, scale: jax.Array, bits: int):
    """Oracle for ``replay.quant_span_decode``: wire bytes -> dense f32
    rows (cols = wire_cols for int8, 2*wire_cols for int4)."""
    if bits == 8:
        return q.astype(jnp.float32) * scale
    u = q.astype(jnp.int32)
    lo = u & 0xF
    hi = (u >> 4) & 0xF
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    R, W = u.shape
    even = jax.lax.broadcasted_iota(jnp.int32, (R, 2 * W), 1) % 2 == 0
    g = jnp.where(even, jnp.repeat(lo, 2, axis=1),
                  jnp.repeat(hi, 2, axis=1)).astype(jnp.float32)
    return g * scale


def quant_span_apply_ref(q, scale, dst, start, *, bits: int):
    """Oracle for ``replay.quant_span_apply``: dequantize one row-span
    payload and write it into rows [start, start+n) of ``dst``."""
    n = q.shape[0]
    dense = span_decode_ref(q, scale, bits)
    cols = 1
    for d in dst.shape[1:]:
        cols *= int(d)
    rows = dense[:n, :cols].reshape((n,) + dst.shape[1:]).astype(dst.dtype)
    return jax.lax.dynamic_update_slice(
        dst, rows, (start,) + (0,) * (dst.ndim - 1))
