"""Pure-jnp oracles for every Pallas kernel (test + CPU fallback path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_select_ref(xb: jax.Array, k: int):
    """xb: (nb, block) -> (values, indices) — magnitude top-k per block."""
    mag = jnp.abs(xb.astype(jnp.float32))
    _, idx = jax.lax.top_k(mag, k)
    vals = jnp.take_along_axis(xb, idx, axis=1)
    return vals, idx.astype(jnp.int32)


def topk_scatter_ref(vals: jax.Array, idxs: jax.Array, block: int):
    nb, k = vals.shape
    out = jnp.zeros((nb, block), vals.dtype)
    return jax.vmap(lambda o, i, v: o.at[i].add(v))(out, idxs, vals)


def pack_select_ref(xb: jax.Array, k: int):
    """Fused compress-and-pack oracle: top-k by magnitude, then int8
    quantization of the selected values against the per-row absmax."""
    mag = jnp.abs(xb.astype(jnp.float32))
    _, idx = jax.lax.top_k(mag, k)
    vals = jnp.take_along_axis(xb.astype(jnp.float32), idx, axis=1)
    scale = jnp.maximum(jnp.max(jnp.abs(vals), axis=1, keepdims=True)
                        / 127.0, 1e-12)
    q = jnp.clip(jnp.round(vals / scale), -127, 127).astype(jnp.int8)
    return q, idx.astype(jnp.int32), scale


def pack_scatter_ref(q: jax.Array, idxs: jax.Array, scale: jax.Array,
                     block: int):
    vals = q.astype(jnp.float32) * scale
    nb, k = vals.shape
    out = jnp.zeros((nb, block), jnp.float32)
    return jax.vmap(lambda o, i, v: o.at[i].add(v))(out, idxs, vals)


def quantize_ref(xb: jax.Array):
    x = xb.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0,
                        1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def adam_tile_update_ref(p, g, mu, nu, hyper):
    lr, b1, b2, eps, c1, c2 = (hyper[0, i] for i in range(6))
    pf, gf = p.astype(jnp.float32), g.astype(jnp.float32)
    mu2 = b1 * mu + (1.0 - b1) * gf
    nu2 = b2 * nu + (1.0 - b2) * gf * gf
    step = lr * (mu2 / c1) / (jnp.sqrt(nu2 / c2) + eps)
    return (pf - step).astype(p.dtype), mu2, nu2
