"""Jitted public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs as traced jnp on the host, which validates the exact
TPU program. On a TPU backend the same call sites compile the Mosaic
kernels. ``use_pallas=False`` routes to the pure-jnp oracle instead
(used to cross-check and as the default inside larger jitted graphs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.packed import PackedDiff
from repro.compression.quant import QuantGrad
from repro.compression.sparse import BLOCK, SparseGrad, _pad_len, k_for
from repro.kernels import fused_adam as _fa
from repro.kernels import pack as _pk
from repro.kernels import quant8 as _q8
from repro.kernels import ref as _ref
from repro.kernels import replay as _rp
from repro.kernels import topk as _tk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_blocks(x: jax.Array, block: int):
    flat = x.reshape(-1)
    pad = _pad_len(flat.size, block)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    xb = flat.reshape(-1, block)
    # pallas grid wants row-count divisible by the tile height
    rpad = _pad_len(xb.shape[0], _tk.ROWS)
    if rpad:
        xb = jnp.pad(xb, ((0, rpad), (0, 0)))
    return xb, xb.shape[0] - rpad


@functools.partial(jax.jit, static_argnames=("rho", "block", "use_pallas"))
def topk_compress(x: jax.Array, rho: float, *, block: int = BLOCK,
                  use_pallas: bool = True) -> SparseGrad:
    xb, nb = _to_blocks(x, block)
    k = k_for(rho, block)
    if use_pallas:
        vals, idx = _tk.topk_select(xb, k, interpret=_interpret())
    else:
        vals, idx = _ref.topk_select_ref(xb, k)
    return SparseGrad(vals[:nb], idx[:nb], x.shape, block)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def topk_decompress(sg: SparseGrad, *, use_pallas: bool = True) -> jax.Array:
    nb = sg.values.shape[0]
    rpad = _pad_len(nb, _tk.ROWS)
    vals = jnp.pad(sg.values, ((0, rpad), (0, 0)))
    idx = jnp.pad(sg.indices, ((0, rpad), (0, 0)))
    if use_pallas:
        dense = _tk.topk_scatter(vals, idx, sg.block, interpret=_interpret())
    else:
        dense = _ref.topk_scatter_ref(vals, idx, sg.block)
    n = int(np.prod(sg.shape)) if sg.shape else 1
    return dense[:nb].reshape(-1)[:n].reshape(sg.shape)


@functools.partial(jax.jit, static_argnames=("rho", "block", "use_pallas"))
def packed_compress(x: jax.Array, rho: float, *, block: int = BLOCK,
                    use_pallas: bool = True) -> PackedDiff:
    """Fused compress-and-pack: one kernel pass emits the wire-format
    (q int8, indices, scales) buffers — the differential comes off the
    device already in the frame serializer's layout."""
    xb, nb = _to_blocks(x, block)
    k = k_for(rho, block)
    if use_pallas:
        q, idx, scale = _pk.pack_select(xb, k, interpret=_interpret())
    else:
        q, idx, scale = _ref.pack_select_ref(xb, k)
    return PackedDiff(q[:nb], idx[:nb], scale[:nb], x.shape, block)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def packed_decompress(pd: PackedDiff, *, use_pallas: bool = True
                      ) -> jax.Array:
    """Inverse of packed_compress: fused dequant + scatter to dense."""
    nb = pd.q.shape[0]
    rpad = _pad_len(nb, _pk.ROWS)
    q = jnp.pad(pd.q, ((0, rpad), (0, 0)))
    idx = jnp.pad(pd.indices, ((0, rpad), (0, 0)))
    scale = jnp.pad(pd.scale, ((0, rpad), (0, 0)))
    if use_pallas:
        dense = _pk.pack_scatter(q, idx, scale, pd.block,
                                 interpret=_interpret())
    else:
        dense = _ref.pack_scatter_ref(q, idx, scale, pd.block)
    n = int(np.prod(pd.shape)) if pd.shape else 1
    return dense[:nb].reshape(-1)[:n].reshape(pd.shape)


@functools.partial(jax.jit, static_argnames=("block", "use_pallas"))
def quant_compress(x: jax.Array, *, block: int = BLOCK,
                   use_pallas: bool = True):
    xb, nb = _to_blocks(x, block)
    if use_pallas:
        q, scale = _q8.quantize(xb, interpret=_interpret())
    else:
        q, scale = _ref.quantize_ref(xb)
    return q[:nb], scale[:nb]


@functools.partial(jax.jit, static_argnames=("bits", "use_pallas"))
def quant_span_encode(x2d: jax.Array, *, bits: int,
                      use_pallas: bool = True):
    """Quantize a (rows, cols) f32 row block with per-row absmax scales:
    returns (q (rows, wire_cols), scale (rows, 1)). Pads rows to the
    kernel tile height and cols to even (int4) internally; the zero
    padding cannot change any row's absmax, so the wire bytes match the
    host codec exactly."""
    n, cols = x2d.shape
    cpad = (-cols) % 2 if bits == 4 else 0
    rpad = (-n) % _pk.ROWS
    xb = jnp.pad(x2d.astype(jnp.float32), ((0, rpad), (0, cpad)))
    if use_pallas:
        q, scale = _pk.span_pack(xb, bits=bits, interpret=_interpret())
    else:
        q, scale = _ref.span_pack_ref(xb, bits)
    return q[:n], scale[:n]


@functools.partial(jax.jit, static_argnames=("cols", "bits", "use_pallas"))
def quant_span_decode(q: jax.Array, scale: jax.Array, *, cols: int,
                      bits: int, use_pallas: bool = True) -> jax.Array:
    """Inverse of :func:`quant_span_encode`: wire bytes + per-row scales
    -> dense f32 (rows, cols)."""
    n = q.shape[0]
    rpad = (-n) % _rp.ROWS
    qp = jnp.pad(q, ((0, rpad), (0, 0)))
    sp = jnp.pad(scale, ((0, rpad), (0, 0)))
    if use_pallas:
        dense = _rp.quant_span_decode(qp, sp, bits=bits,
                                      interpret=_interpret())
    else:
        dense = _ref.span_decode_ref(qp, sp, bits)
    return dense[:n, :cols]


@functools.partial(jax.jit, static_argnames=("bits", "use_pallas"))
def fused_span_apply(dst: jax.Array, start, q: jax.Array,
                     scale: jax.Array, *, bits: int,
                     use_pallas: bool = True) -> jax.Array:
    """Fused dequantize + scatter of one quantized row-span payload into
    rows [start, start+n) of state leaf ``dst`` — the device-recovery
    overlay unit (``replay.quant_span_apply`` or its oracle)."""
    if use_pallas:
        return _rp.quant_span_apply(q, scale, dst, start, bits=bits,
                                    interpret=_interpret())
    return _ref.quant_span_apply_ref(q, scale, dst, start, bits=bits)


def adam_hyper(lr, b1, b2, eps, count) -> jax.Array:
    c1 = 1.0 - b1 ** count
    c2 = 1.0 - b2 ** count
    return jnp.asarray([[lr, b1, b2, eps, c1, c2, 1.0 - b1, 1.0 - b2]],
                       jnp.float32)


def adam_hyper_traced(lr, b1, b2, eps, count) -> jax.Array:
    """Traced variant of :func:`adam_hyper` for use inside jitted
    replay: the bias corrections are computed with the *same* f32 jnp
    ops as ``optim.adam.adam_update``, and the moment complements
    ``1-b1`` / ``1-b2`` are pre-rounded from python doubles exactly as
    the eager update's scalar promotion rounds them (recomputing
    ``1.0f - b1f`` on device is off by one ulp, which would break the
    device-replay == serial-replay bit-identity). ``count`` is the
    *post-increment* step count, i.e. ``state.count + 1``."""
    cf = jnp.asarray(count).astype(jnp.float32)
    c1 = 1.0 - b1 ** cf
    c2 = 1.0 - b2 ** cf
    row = jnp.stack([jnp.float32(lr), jnp.float32(b1), jnp.float32(b2),
                     jnp.float32(eps), c1.astype(jnp.float32),
                     c2.astype(jnp.float32), jnp.float32(1.0 - b1),
                     jnp.float32(1.0 - b2)])
    return row.reshape(1, 8)


def _unblock(x: jax.Array, shape, dt):
    n = int(np.prod(shape)) if shape else 1
    return x.reshape(-1)[:n].reshape(shape).astype(dt)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def fused_sparse_apply(sg: SparseGrad, p: jax.Array, mu: jax.Array,
                       nu: jax.Array, hyper: jax.Array, *,
                       use_pallas: bool = True):
    """Fused decompress-and-apply for a top-k differential: scatter the
    wire (values, indices) straight into the Adam update — no dense
    gradient is ever materialized outside the kernel's accumulator."""
    shape, block = p.shape, sg.block
    pb, _ = _to_blocks(p, block)
    mub, _ = _to_blocks(mu, block)
    nub, _ = _to_blocks(nu, block)
    rpad = pb.shape[0] - sg.values.shape[0]
    vals = jnp.pad(sg.values, ((0, rpad), (0, 0)))
    idx = jnp.pad(sg.indices, ((0, rpad), (0, 0)))
    if use_pallas:
        p2, mu2, nu2 = _rp.topk_apply(vals, idx, pb, mub, nub, hyper,
                                      block=block, interpret=_interpret())
    else:
        p2, mu2, nu2 = _ref.topk_apply_ref(vals, idx, pb, mub, nub, hyper,
                                           block=block)
    return (_unblock(p2, shape, p.dtype), _unblock(mu2, shape, jnp.float32),
            _unblock(nu2, shape, jnp.float32))


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def fused_packed_apply(pd: PackedDiff, p: jax.Array, mu: jax.Array,
                       nu: jax.Array, hyper: jax.Array, *,
                       use_pallas: bool = True):
    """Fused decompress-and-apply for a packed (int8 top-k) differential:
    dequantize + scatter + Adam in one pass over the wire buffers."""
    shape, block = p.shape, pd.block
    pb, _ = _to_blocks(p, block)
    mub, _ = _to_blocks(mu, block)
    nub, _ = _to_blocks(nu, block)
    rpad = pb.shape[0] - pd.q.shape[0]
    q = jnp.pad(pd.q, ((0, rpad), (0, 0)))
    idx = jnp.pad(pd.indices, ((0, rpad), (0, 0)))
    scale = jnp.pad(pd.scale, ((0, rpad), (0, 0)))
    if use_pallas:
        p2, mu2, nu2 = _rp.packed_apply(q, idx, scale, pb, mub, nub, hyper,
                                        block=block, interpret=_interpret())
    else:
        p2, mu2, nu2 = _ref.packed_apply_ref(q, idx, scale, pb, mub, nub,
                                             hyper, block=block)
    return (_unblock(p2, shape, p.dtype), _unblock(mu2, shape, jnp.float32),
            _unblock(nu2, shape, jnp.float32))


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def fused_quant_apply(qg: QuantGrad, p: jax.Array, mu: jax.Array,
                      nu: jax.Array, hyper: jax.Array, *,
                      use_pallas: bool = True):
    """Fused decompress-and-apply for a quant8 differential: dequantize
    the int8 blocks against their scales inside the Adam pass."""
    shape, block = p.shape, qg.block
    pb, _ = _to_blocks(p, block)
    mub, _ = _to_blocks(mu, block)
    nub, _ = _to_blocks(nu, block)
    rpad = pb.shape[0] - qg.q.shape[0]
    q = jnp.pad(qg.q, ((0, rpad), (0, 0)))
    scale = jnp.pad(qg.scale.reshape(-1, 1), ((0, rpad), (0, 0)))
    if use_pallas:
        p2, mu2, nu2 = _rp.quant_apply(q, scale, pb, mub, nub, hyper,
                                       interpret=_interpret())
    else:
        p2, mu2, nu2 = _ref.quant_apply_ref(q, scale, pb, mub, nub, hyper)
    return (_unblock(p2, shape, p.dtype), _unblock(mu2, shape, jnp.float32),
            _unblock(nu2, shape, jnp.float32))


def fused_decode_apply(payload, p, mu, nu, hyper, *,
                       use_pallas: bool = True):
    """Apply one compressed differential to (p, mu, nu) without a host
    decompress or a dense intermediate: dispatches on the wire container
    type to the matching fused kernel; dense arrays fall back to
    :func:`fused_adam_update`."""
    if isinstance(payload, SparseGrad):
        return fused_sparse_apply(payload, p, mu, nu, hyper,
                                  use_pallas=use_pallas)
    if isinstance(payload, PackedDiff):
        return fused_packed_apply(payload, p, mu, nu, hyper,
                                  use_pallas=use_pallas)
    if isinstance(payload, QuantGrad):
        return fused_quant_apply(payload, p, mu, nu, hyper,
                                 use_pallas=use_pallas)
    return fused_adam_update(p, jnp.asarray(payload), mu, nu, hyper,
                             use_pallas=use_pallas)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def fused_adam_update(p: jax.Array, g: jax.Array, mu: jax.Array,
                      nu: jax.Array, hyper: jax.Array, *,
                      use_pallas: bool = True):
    """Flat-tensor fused Adam. Shapes all equal; returns (p', mu', nu')."""
    shape = p.shape
    pb, nb = _to_blocks(p, _fa.COLS)
    gb, _ = _to_blocks(g, _fa.COLS)
    mub, _ = _to_blocks(mu, _fa.COLS)
    nub, _ = _to_blocks(nu, _fa.COLS)
    if use_pallas:
        p2, mu2, nu2 = _fa.adam_tile_update(pb, gb, mub, nub, hyper,
                                            interpret=_interpret())
    else:
        p2, mu2, nu2 = _ref.adam_tile_update_ref(pb, gb, mub, nub, hyper)
    n = int(np.prod(shape)) if shape else 1

    def unblock(x, dt):
        return x.reshape(-1)[:n].reshape(shape).astype(dt)

    return unblock(p2, p.dtype), unblock(mu2, jnp.float32), \
        unblock(nu2, jnp.float32)
