"""Jitted public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs as traced jnp on the host, which validates the exact
TPU program. On a TPU backend the same call sites compile the Mosaic
kernels. ``use_pallas=False`` routes to the pure-jnp oracle instead
(used to cross-check and as the default inside larger jitted graphs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.packed import PackedDiff
from repro.compression.sparse import BLOCK, SparseGrad, _pad_len, k_for
from repro.kernels import fused_adam as _fa
from repro.kernels import pack as _pk
from repro.kernels import quant8 as _q8
from repro.kernels import ref as _ref
from repro.kernels import topk as _tk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_blocks(x: jax.Array, block: int):
    flat = x.reshape(-1)
    pad = _pad_len(flat.size, block)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    xb = flat.reshape(-1, block)
    # pallas grid wants row-count divisible by the tile height
    rpad = _pad_len(xb.shape[0], _tk.ROWS)
    if rpad:
        xb = jnp.pad(xb, ((0, rpad), (0, 0)))
    return xb, xb.shape[0] - rpad


@functools.partial(jax.jit, static_argnames=("rho", "block", "use_pallas"))
def topk_compress(x: jax.Array, rho: float, *, block: int = BLOCK,
                  use_pallas: bool = True) -> SparseGrad:
    xb, nb = _to_blocks(x, block)
    k = k_for(rho, block)
    if use_pallas:
        vals, idx = _tk.topk_select(xb, k, interpret=_interpret())
    else:
        vals, idx = _ref.topk_select_ref(xb, k)
    return SparseGrad(vals[:nb], idx[:nb], x.shape, block)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def topk_decompress(sg: SparseGrad, *, use_pallas: bool = True) -> jax.Array:
    nb = sg.values.shape[0]
    rpad = _pad_len(nb, _tk.ROWS)
    vals = jnp.pad(sg.values, ((0, rpad), (0, 0)))
    idx = jnp.pad(sg.indices, ((0, rpad), (0, 0)))
    if use_pallas:
        dense = _tk.topk_scatter(vals, idx, sg.block, interpret=_interpret())
    else:
        dense = _ref.topk_scatter_ref(vals, idx, sg.block)
    n = int(np.prod(sg.shape)) if sg.shape else 1
    return dense[:nb].reshape(-1)[:n].reshape(sg.shape)


@functools.partial(jax.jit, static_argnames=("rho", "block", "use_pallas"))
def packed_compress(x: jax.Array, rho: float, *, block: int = BLOCK,
                    use_pallas: bool = True) -> PackedDiff:
    """Fused compress-and-pack: one kernel pass emits the wire-format
    (q int8, indices, scales) buffers — the differential comes off the
    device already in the frame serializer's layout."""
    xb, nb = _to_blocks(x, block)
    k = k_for(rho, block)
    if use_pallas:
        q, idx, scale = _pk.pack_select(xb, k, interpret=_interpret())
    else:
        q, idx, scale = _ref.pack_select_ref(xb, k)
    return PackedDiff(q[:nb], idx[:nb], scale[:nb], x.shape, block)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def packed_decompress(pd: PackedDiff, *, use_pallas: bool = True
                      ) -> jax.Array:
    """Inverse of packed_compress: fused dequant + scatter to dense."""
    nb = pd.q.shape[0]
    rpad = _pad_len(nb, _pk.ROWS)
    q = jnp.pad(pd.q, ((0, rpad), (0, 0)))
    idx = jnp.pad(pd.indices, ((0, rpad), (0, 0)))
    scale = jnp.pad(pd.scale, ((0, rpad), (0, 0)))
    if use_pallas:
        dense = _pk.pack_scatter(q, idx, scale, pd.block,
                                 interpret=_interpret())
    else:
        dense = _ref.pack_scatter_ref(q, idx, scale, pd.block)
    n = int(np.prod(pd.shape)) if pd.shape else 1
    return dense[:nb].reshape(-1)[:n].reshape(pd.shape)


@functools.partial(jax.jit, static_argnames=("block", "use_pallas"))
def quant_compress(x: jax.Array, *, block: int = BLOCK,
                   use_pallas: bool = True):
    xb, nb = _to_blocks(x, block)
    if use_pallas:
        q, scale = _q8.quantize(xb, interpret=_interpret())
    else:
        q, scale = _ref.quantize_ref(xb)
    return q[:nb], scale[:nb]


def adam_hyper(lr, b1, b2, eps, count) -> jax.Array:
    c1 = 1.0 - b1 ** count
    c2 = 1.0 - b2 ** count
    return jnp.asarray([[lr, b1, b2, eps, c1, c2, 0.0, 0.0]], jnp.float32)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def fused_adam_update(p: jax.Array, g: jax.Array, mu: jax.Array,
                      nu: jax.Array, hyper: jax.Array, *,
                      use_pallas: bool = True):
    """Flat-tensor fused Adam. Shapes all equal; returns (p', mu', nu')."""
    shape = p.shape
    pb, nb = _to_blocks(p, _fa.COLS)
    gb, _ = _to_blocks(g, _fa.COLS)
    mub, _ = _to_blocks(mu, _fa.COLS)
    nub, _ = _to_blocks(nu, _fa.COLS)
    if use_pallas:
        p2, mu2, nu2 = _fa.adam_tile_update(pb, gb, mub, nub, hyper,
                                            interpret=_interpret())
    else:
        p2, mu2, nu2 = _ref.adam_tile_update_ref(pb, gb, mub, nub, hyper)
    n = int(np.prod(shape)) if shape else 1

    def unblock(x, dt):
        return x.reshape(-1)[:n].reshape(shape).astype(dt)

    return unblock(p2, p.dtype), unblock(mu2, jnp.float32), \
        unblock(nu2, jnp.float32)
