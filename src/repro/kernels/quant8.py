"""Pallas TPU kernel: blockwise absmax int8 quantization (+ dequant).

One (R, BLOCK) VMEM tile per grid step; absmax row-reduce -> scale,
round-to-nearest-even via jnp.round, saturating cast. Memory-bound by
design (single pass over the gradient).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8


def _quant_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                 # (R, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0,
                        1e-12)                          # (R, 1)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale


def quantize(xb: jax.Array, *, interpret: bool = False):
    """xb: (nb, block) -> (q int8 (nb, block), scale f32 (nb, 1))."""
    nb, block = xb.shape
    rows = min(ROWS, nb)
    assert nb % rows == 0
    return pl.pallas_call(
        _quant_kernel,
        grid=(nb // rows,),
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0)),
                   pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, block), jnp.int8),
                   jax.ShapeDtypeStruct((nb, 1), jnp.float32)],
        interpret=interpret,
    )(xb)


def _dequant_kernel(q_ref, scale_ref, out_ref):
    out_ref[...] = q_ref[...].astype(jnp.float32) * scale_ref[...]


def dequantize(q: jax.Array, scale: jax.Array, *, interpret: bool = False):
    nb, block = q.shape
    rows = min(ROWS, nb)
    assert nb % rows == 0
    return pl.pallas_call(
        _dequant_kernel,
        grid=(nb // rows,),
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0)),
                  pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=interpret,
    )(q, scale)
