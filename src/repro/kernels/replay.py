"""Pallas TPU kernels: fused decompress-and-apply differential replay.

Recovery used to decode every compressed differential on host
(``maybe_decompress``) and ship the dense leaves over PCIe before the
replay scan touched them — recovery time was set by host CPU and
interconnect, not by the chain's information content. These kernels
take a differential's *wire form* — top-k (values, block-local
indices), packed (int8 q, indices, f32 scales) or quant8 (int8 blocks,
f32 scales) — resident in device memory and replay one optimizer step
in a single pass per tile: decode in registers (dequantize / scatter
into a VMEM accumulator), then the exact ``fused_adam`` moment update,
writing p'/mu'/nu' back out. No dense gradient ever exists in HBM and
the host never touches the payload bytes.

Per replayed step the HBM traffic is 3 reads + 3 writes of the model
state plus the (tiny) compressed payload read — the memory-bound
optimum for a stateful-optimizer replay, which is what lets a chain
replay approach the device memory-bandwidth roofline.

The decode math mirrors the pure-jnp decompressors bit-for-bit (f32
scatter of distinct per-block indices, ``q.astype(f32) * scale``
dequant) and the update mirrors ``optim.adam.adam_update``'s op order,
so a device-replayed chain is bit-identical to host serial replay.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8          # rows (blocks) per grid step — one f32 sublane tile


def _adam_epilogue(hyper_ref, g, p_ref, mu_ref, nu_ref,
                   p_out, mu_out, nu_out):
    """Shared fused-Adam tail: identical op order to ``fused_adam`` /
    ``optim.adam.adam_update`` (bit-identity with host replay)."""
    h = hyper_ref[...]                                  # (1, 8) f32
    lr, b1, b2, eps, c1, c2, om1, om2 = (h[0, i] for i in range(8))
    # om1/om2 are 1-b1 / 1-b2 pre-rounded from python doubles the way
    # the eager update's scalar promotion rounds them — recomputing
    # 1.0f - b1f here lands one ulp off and breaks bit-identity.
    p = p_ref[...].astype(jnp.float32)
    mu = b1 * mu_ref[...] + om1 * g
    nu = b2 * nu_ref[...] + om2 * g * g
    step = lr * (mu / c1) / (jnp.sqrt(nu / c2) + eps)
    p_out[...] = (p - step).astype(p_ref.dtype)
    mu_out[...] = mu
    nu_out[...] = nu


def _scatter(vals, idxs, block: int):
    """(R, k) values + block-local indices -> dense (R, block) f32.
    Indices within a block are distinct by construction (iterative
    argmax / top_k), so add-scatter == write-scatter."""
    R, k = vals.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (R, block), 1)

    def body(i, acc):
        sel = iota == jax.lax.dynamic_index_in_dim(idxs, i, 1)
        v = jax.lax.dynamic_index_in_dim(vals, i, 1)
        return acc + jnp.where(sel, v.astype(jnp.float32), 0.0)

    return jax.lax.fori_loop(0, k, body, jnp.zeros((R, block), jnp.float32))


def _topk_apply_kernel(hyper_ref, vals_ref, idx_ref, p_ref, mu_ref, nu_ref,
                       p_out, mu_out, nu_out, *, block: int):
    g = _scatter(vals_ref[...], idx_ref[...], block)
    _adam_epilogue(hyper_ref, g, p_ref, mu_ref, nu_ref,
                   p_out, mu_out, nu_out)


def _packed_apply_kernel(hyper_ref, q_ref, idx_ref, scale_ref,
                         p_ref, mu_ref, nu_ref,
                         p_out, mu_out, nu_out, *, block: int):
    vals = q_ref[...].astype(jnp.float32) * scale_ref[...]      # (R, k)
    g = _scatter(vals, idx_ref[...], block)
    _adam_epilogue(hyper_ref, g, p_ref, mu_ref, nu_ref,
                   p_out, mu_out, nu_out)


def _quant_apply_kernel(hyper_ref, q_ref, scale_ref,
                        p_ref, mu_ref, nu_ref,
                        p_out, mu_out, nu_out):
    g = q_ref[...].astype(jnp.float32) * scale_ref[...]         # (R, block)
    _adam_epilogue(hyper_ref, g, p_ref, mu_ref, nu_ref,
                   p_out, mu_out, nu_out)


def _call(kernel, wire_specs, wires, p, mu, nu, hyper, *, block: int,
          interpret: bool):
    nb = p.shape[0]
    rows = min(ROWS, nb)
    assert nb % rows == 0
    state = pl.BlockSpec((rows, block), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(nb // rows,),
        in_specs=[pl.BlockSpec((1, 8), lambda i: (0, 0)),
                  *wire_specs, state, state, state],
        out_specs=[state, state, state],
        out_shape=[jax.ShapeDtypeStruct(p.shape, p.dtype),
                   jax.ShapeDtypeStruct(p.shape, jnp.float32),
                   jax.ShapeDtypeStruct(p.shape, jnp.float32)],
        interpret=interpret,
    )(hyper, *wires, p, mu, nu)


def topk_apply(vals, idxs, p, mu, nu, hyper, *, block: int,
               interpret: bool = False):
    """Fused scatter-decode + Adam apply of a top-k differential.
    vals/idxs: (nb, k); p/mu/nu: (nb, block); hyper: (1, 8) f32 =
    [lr, b1, b2, eps, c1, c2, 0, 0]. Returns (p', mu', nu')."""
    nb, k = vals.shape
    if k == 0:
        return _zero_apply(p, mu, nu, hyper, interpret=interpret)
    rows = min(ROWS, nb)
    wire = pl.BlockSpec((rows, k), lambda i: (i, 0))
    kernel = functools.partial(_topk_apply_kernel, block=block)
    return _call(kernel, [wire, wire], (vals, idxs), p, mu, nu, hyper,
                 block=block, interpret=interpret)


def packed_apply(q, idxs, scale, p, mu, nu, hyper, *, block: int,
                 interpret: bool = False):
    """Fused dequant + scatter-decode + Adam apply of a packed (int8
    top-k) differential. q/idxs: (nb, k); scale: (nb, 1)."""
    nb, k = q.shape
    if k == 0:
        return _zero_apply(p, mu, nu, hyper, interpret=interpret)
    rows = min(ROWS, nb)
    wire = pl.BlockSpec((rows, k), lambda i: (i, 0))
    sspec = pl.BlockSpec((rows, 1), lambda i: (i, 0))
    kernel = functools.partial(_packed_apply_kernel, block=block)
    return _call(kernel, [wire, wire, sspec], (q, idxs, scale),
                 p, mu, nu, hyper, block=block, interpret=interpret)


def _zero_apply(p, mu, nu, hyper, *, interpret: bool):
    """k == 0 wire payload (an all-zero block's top-0): pallas rejects
    zero-width block specs, so run the identical Adam epilogue through
    the quant kernel with a zero payload — g == 0 exactly, same bits as
    the oracle's empty scatter."""
    return quant_apply(jnp.zeros(p.shape, jnp.int8),
                       jnp.zeros((p.shape[0], 1), jnp.float32),
                       p, mu, nu, hyper, interpret=interpret)


def quant_apply(q, scale, p, mu, nu, hyper, *, interpret: bool = False):
    """Fused dequant + Adam apply of a quant8 differential.
    q: (nb, block) int8; scale: (nb, 1) f32."""
    nb, block = q.shape
    rows = min(ROWS, nb)
    wire = pl.BlockSpec((rows, block), lambda i: (i, 0))
    sspec = pl.BlockSpec((rows, 1), lambda i: (i, 0))
    return _call(_quant_apply_kernel, [wire, sspec], (q, scale),
                 p, mu, nu, hyper, block=block, interpret=interpret)


# -------------------- quantized row-span recovery --------------------

def _quant_span_kernel(q_ref, scale_ref, out_ref, *, bits: int):
    """Dequantize a quantized row-span wire tile: int8 values or
    nibble-packed int4 (low nibble = even column, two's complement) ->
    dense f32 rows, scaled by the per-row absmax scale."""
    q = q_ref[...]
    if bits == 8:
        g = q.astype(jnp.float32)
    else:
        u = q.astype(jnp.int32)
        lo = u & 0xF
        hi = (u >> 4) & 0xF
        lo = jnp.where(lo > 7, lo - 16, lo)
        hi = jnp.where(hi > 7, hi - 16, hi)
        R, W = u.shape
        even = jax.lax.broadcasted_iota(jnp.int32, (R, 2 * W), 1) % 2 == 0
        g = jnp.where(even, jnp.repeat(lo, 2, axis=1),
                      jnp.repeat(hi, 2, axis=1)).astype(jnp.float32)
    out_ref[...] = g * scale_ref[...]


def quant_span_decode(q, scale, *, bits: int, interpret: bool = False):
    """q: (nb, wire_cols) + per-row scales -> dense f32 (nb, cols) where
    cols is wire_cols (int8) or 2*wire_cols (int4). nb % ROWS == 0."""
    assert bits in (8, 4)
    nb, wc = q.shape
    rows = min(ROWS, nb)
    assert nb % rows == 0
    cols = wc if bits == 8 else 2 * wc
    kernel = functools.partial(_quant_span_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=(nb // rows,),
        in_specs=[pl.BlockSpec((rows, wc), lambda i: (i, 0)),
                  pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, cols), jnp.float32),
        interpret=interpret,
    )(q, scale)


def quant_span_apply(q, scale, dst, start, *, bits: int,
                     interpret: bool = False):
    """Fused dequantize(+int4 unpack) of one quantized row-span payload,
    scattered straight into rows [start, start+n) of the destination
    state leaf ``dst`` (shape (N, *tail)) — the device-recovery overlay
    unit. The dequant math is bit-identical to the host codec
    (``repro.compression.quant_span``), so device overlay == host
    overlay byte for byte."""
    n = q.shape[0]
    rpad = -n % ROWS
    qp = jnp.pad(q, ((0, rpad), (0, 0)))
    sp = jnp.pad(scale, ((0, rpad), (0, 0)))
    dense = quant_span_decode(qp, sp, bits=bits, interpret=interpret)
    cols = 1
    for d in dst.shape[1:]:
        cols *= int(d)
    rows = dense[:n, :cols].reshape((n,) + dst.shape[1:]).astype(dst.dtype)
    return jax.lax.dynamic_update_slice(
        dst, rows, (start,) + (0,) * (dst.ndim - 1))
