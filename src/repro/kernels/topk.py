"""Pallas TPU kernel: blockwise top-k gradient selection.

TPU adaptation of GPU top-k compression: no global sort / no scatter.
Each grid step loads an (R, BLOCK) tile into VMEM (R rows of 1024-lane
blocks — BLOCK=1024 is 8 native 128-lane vregs) and runs k iterative
argmax passes entirely in registers: max-reduce along the lanes, first-hit
index via 2D iota + select, then mask and repeat. k = ceil(rho*1024) is
tiny (10 at the paper's rho=0.01), so the loop is short and every pass is
a dense VPU op — the MXU is untouched and the kernel is purely
memory-bound (one read of the gradient), which is the roofline optimum
for a compression pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8          # rows (blocks) per grid step — one f32 sublane tile


def _topk_kernel(x_ref, vals_ref, idx_ref, *, k: int, block: int):
    x = x_ref[...]                                     # (R, BLOCK)
    mag = jnp.abs(x.astype(jnp.float32))
    iota = jax.lax.broadcasted_iota(jnp.int32, mag.shape, 1)

    def body(i, carry):
        mag, vals, idxs = carry
        m = jnp.max(mag, axis=1, keepdims=True)        # (R, 1)
        hit = mag == m
        idx = jnp.min(jnp.where(hit, iota, block), axis=1)      # (R,)
        sel = iota == idx[:, None]
        val = jnp.sum(jnp.where(sel, x, 0), axis=1)    # (R,)
        vals = jax.lax.dynamic_update_index_in_dim(vals, val, i, 1)
        idxs = jax.lax.dynamic_update_index_in_dim(idxs, idx, i, 1)
        mag = jnp.where(sel, -1.0, mag)
        return mag, vals, idxs

    vals0 = jnp.zeros((x.shape[0], k), x.dtype)
    idxs0 = jnp.zeros((x.shape[0], k), jnp.int32)
    _, vals, idxs = jax.lax.fori_loop(0, k, body, (mag, vals0, idxs0))
    vals_ref[...] = vals
    idx_ref[...] = idxs


def topk_select(xb: jax.Array, k: int, *, interpret: bool = False):
    """xb: (nb, block) -> (values (nb,k), indices (nb,k) int32)."""
    nb, block = xb.shape
    rows = min(ROWS, nb)
    assert nb % rows == 0
    grid = (nb // rows,)
    kernel = functools.partial(_topk_kernel, k=k, block=block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, k), lambda i: (i, 0)),
                   pl.BlockSpec((rows, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, k), xb.dtype),
                   jax.ShapeDtypeStruct((nb, k), jnp.int32)],
        interpret=interpret,
    )(xb)


def _decompress_kernel(vals_ref, idx_ref, out_ref, *, block: int):
    vals = vals_ref[...]                               # (R, k)
    idxs = idx_ref[...]
    R, k = vals.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (R, block), 1)

    def body(i, acc):
        sel = iota == jax.lax.dynamic_index_in_dim(idxs, i, 1)  # (R,1)->bcast
        v = jax.lax.dynamic_index_in_dim(vals, i, 1)
        return acc + jnp.where(sel, v.astype(jnp.float32), 0.0)

    acc = jax.lax.fori_loop(0, k, body, jnp.zeros((R, block), jnp.float32))
    out_ref[...] = acc.astype(vals.dtype)


def topk_scatter(vals: jax.Array, idxs: jax.Array, block: int, *,
                 interpret: bool = False):
    """Inverse of topk_select: block-local scatter to dense (nb, block)."""
    nb, k = vals.shape
    rows = min(ROWS, nb)
    assert nb % rows == 0
    kernel = functools.partial(_decompress_kernel, block=block)
    return pl.pallas_call(
        kernel,
        grid=(nb // rows,),
        in_specs=[pl.BlockSpec((rows, k), lambda i: (i, 0)),
                  pl.BlockSpec((rows, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), vals.dtype),
        interpret=interpret,
    )(vals, idxs)
