"""Pallas TPU kernel: fused Adam update (the differential-merge hot spot).

Recovery replays differentials through the optimizer (Algorithm 1, lines
17-21): M_{j+1} = M_j + Adam(G_j). Unfused, each replayed step reads and
writes p/mu/nu in 6+ separate HBM passes; this kernel fuses the whole
update into a single read-modify-write per tile — 4 reads + 3 writes of
each element, the memory-bound optimum. Scalars (lr, bias corrections,
eps) arrive as a (1, 8) SMEM-resident operand so the kernel is trace-once
across steps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS, COLS = 8, 1024


def _adam_kernel(hyper_ref, p_ref, g_ref, mu_ref, nu_ref,
                 p_out, mu_out, nu_out):
    h = hyper_ref[...]                                  # (1, 8) f32
    lr, b1, b2, eps, c1, c2 = h[0, 0], h[0, 1], h[0, 2], h[0, 3], h[0, 4], h[0, 5]
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    mu = b1 * mu_ref[...] + (1.0 - b1) * g
    nu = b2 * nu_ref[...] + (1.0 - b2) * g * g
    step = lr * (mu / c1) / (jnp.sqrt(nu / c2) + eps)
    p_out[...] = (p - step).astype(p_ref.dtype)
    mu_out[...] = mu
    nu_out[...] = nu


def adam_tile_update(p, g, mu, nu, hyper, *, interpret: bool = False):
    """All tensor args (nb, COLS); hyper (1, 8) f32 =
    [lr, b1, b2, eps, c1, c2, 0, 0]. Returns (p', mu', nu')."""
    nb, cols = p.shape
    rows = min(ROWS, nb)
    assert nb % rows == 0
    tile = pl.BlockSpec((rows, cols), lambda i: (i, 0))
    return pl.pallas_call(
        _adam_kernel,
        grid=(nb // rows,),
        in_specs=[pl.BlockSpec((1, 8), lambda i: (0, 0)),
                  tile, tile, tile, tile],
        out_specs=[tile, tile, tile],
        out_shape=[jax.ShapeDtypeStruct(p.shape, p.dtype),
                   jax.ShapeDtypeStruct(p.shape, jnp.float32),
                   jax.ShapeDtypeStruct(p.shape, jnp.float32)],
        interpret=interpret,
    )(hyper, p, g, mu, nu)
