"""Crash-safe progress journal for background maintenance tasks.

Every maintenance task (resumable GC, integrity scrub, journal-segment
merge) checkpoints its own progress here as JSON-line records, so a
crash mid-task never strands blobs or re-does finished work: a
restarted service folds the log, finds the unfinished plans, and
resumes each from its last journaled cursor.

Record shapes::

    {"task": "gc",    "id": 3, "op": "plan", "doomed": [...], ...}
    {"task": "gc",    "id": 3, "op": "cursor", "pos": 128}
    {"task": "gc",    "id": 3, "op": "done"}

``plan`` carries everything needed to re-run the task from scratch,
``cursor`` advances a monotone position inside it, ``done`` retires it.
Torn tails (a crash mid-append) are tolerated exactly like the
manifest journal: the valid prefix is kept, the fragment truncated.
The log self-compacts — whenever no task is pending the file is
reset, so it stays O(active work), not O(history).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

from repro.checkpoint.journal import read_segment


class MemoryProgress:
    """Progress journal for stores with no durable root: crash-resume
    across processes is moot, but the same fold/pending API lets the
    service run unchanged over a pure-RAM tier."""

    def __init__(self):
        self.records: List[dict] = []
        self.appends = 0

    def append(self, rec: dict) -> None:
        self.records.append(dict(rec))
        self.appends += 1

    def next_id(self) -> int:
        return max((int(r.get("id", 0)) for r in self.records), default=0) + 1

    def pending(self) -> List[dict]:
        return _fold_pending(self.records)

    def compact_if_idle(self) -> None:
        if not self.pending():
            self.records = []

    def close(self) -> None:
        pass

    def stats(self) -> dict:
        return {"appends": self.appends, "pending": len(self.pending()),
                "durable": False}


class ProgressJournal:
    """Durable task-progress journal (``<root>/maintenance.log``, or
    ``maintenance.<host>.log`` for multi-controller jobs — each host's
    service journals its own progress; sharing one file would let host
    A's idle-compaction truncate host B's in-flight plan)."""

    FILE = "maintenance.log"

    def __init__(self, root: str, host: Optional[str] = None):
        self.root = root
        os.makedirs(root, exist_ok=True)
        name = f"maintenance.{host}.log" if host else self.FILE
        self.path = os.path.join(root, name)
        self.records, valid, total = read_segment(self.path)
        if valid < total:
            # drop the torn fragment so the next append starts fresh
            with open(self.path, "r+b") as f:
                f.truncate(valid)
        self._f = open(self.path, "a", encoding="utf-8")
        self.appends = 0
        self.compactions = 0

    def append(self, rec: dict) -> None:
        if self._f.closed:
            # the service was stopped and restarted: reopen for append
            self._f = open(self.path, "a", encoding="utf-8")
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        self.records.append(dict(rec))
        self.appends += 1

    def next_id(self) -> int:
        return max((int(r.get("id", 0)) for r in self.records), default=0) + 1

    def pending(self) -> List[dict]:
        """Unfinished tasks: each plan record merged with its latest
        cursor position, ordered by task id."""
        return _fold_pending(self.records)

    def compact_if_idle(self) -> None:
        """Reset the log when every journaled task has retired — keeps
        the file O(active work) over an arbitrarily long run."""
        if self.pending():
            return
        self._f.close()
        self._f = open(self.path, "w", encoding="utf-8")
        self.records = []
        self.compactions += 1

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def stats(self) -> dict:
        return {"appends": self.appends, "pending": len(self.pending()),
                "compactions": self.compactions, "durable": True}


def _fold_pending(records: List[dict]) -> List[dict]:
    plans: Dict[Tuple[str, int], dict] = {}
    for rec in records:
        k = (str(rec.get("task")), int(rec.get("id", 0)))
        op = rec.get("op")
        if op == "plan":
            merged = dict(rec)
            merged.setdefault("pos", 0)
            plans[k] = merged
        elif op == "cursor" and k in plans:
            plans[k]["pos"] = int(rec.get("pos", 0))
            if rec.get("folded"):
                # fold tasks mark sweep completion so a resume after a
                # crash mid-commit skips straight to retiring the chain
                plans[k]["folded"] = True
        elif op == "done":
            plans.pop(k, None)
    return [plans[k] for k in sorted(plans, key=lambda k: k[1])]
