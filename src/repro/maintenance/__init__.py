"""Checkpoint maintenance service: crash-resumable background GC,
integrity scrubbing, and multi-controller journal-segment merging.

Usage::

    store = StoreConfig(root, retention_fulls=2).build()
    svc = MaintenanceService(store, gc_slice=64, scrub_interval=30.0)
    store.attach_maintenance(svc)
    svc.start()                 # resumes any crashed task first
    ...                         # save_full() now schedules GC async
    store.flush()               # drains pending maintenance slices
    store.close()               # stops the service
"""
from __future__ import annotations

from repro.maintenance.progress import MemoryProgress, ProgressJournal
from repro.maintenance.service import InjectedCrash, MaintenanceService

__all__ = ["InjectedCrash", "MaintenanceService", "MemoryProgress",
           "ProgressJournal"]
