"""Journaled, crash-resumable background maintenance for the
checkpoint store.

Per-iteration differential checkpointing produces thousands of small
blobs per hour; metadata upkeep — garbage collection, integrity
scrubbing, journal compaction — becomes a first-order cost that must
never stall the training hot path (Check-N-Run / TierCheck both report
maintenance, not the write itself, dominating sustained checkpointing
cost at high frequency). :class:`MaintenanceService` owns one worker
thread and a set of *idempotent* tasks that checkpoint their own
progress into a :mod:`~repro.maintenance.progress` journal:

* **Resumable GC** — the mark phase (``CheckpointStore.gc_plan``) runs
  under the manifest lock only and its plan is journaled; the sweep
  runs in bounded ``gc_slice``-key slices with a cursor record after
  each, so a crash at *any* boundary (after mark, between the manifest
  del and the blob delete, between slices) loses no live-chain blob and
  leaks no dead one — the restarted service finishes the sweep from
  the journaled cursor.
* **Integrity scrub** — walks cold blobs, re-verifies every frame
  leaf / remote chunk sha256 (``StorageBackend.verify``), and
  quarantines corrupt entries so recovery skips them proactively
  instead of discovering them at restore time. Completion also sweeps
  storage orphans (``StorageBackend.sweep_orphans``).
* **Journal-segment merge** — folds multi-controller journal segments
  into the shared snapshot (``CheckpointStore.merge_journal``); the
  snapshot write is atomic and watermark-guarded, so a crash mid-merge
  re-merges idempotently.
* **Incremental merge (fold)** — folds a LowDiff+ incremental-persist
  patch chain into its base frame in place
  (``StorageBackend.patch``), ``merge_slice`` leaves per
  cursor-journaled slice, then retires the chain. The patch blobs are
  the fold's write-ahead log: they outlive the whole sweep, so a kill
  mid-pwrite, mid-header-rewrite, or at any slice boundary re-folds
  (or replays at recovery) to bit-identical bytes.

Concurrency discipline: the worker never holds the store's manifest
lock across blob I/O, task errors surface from :meth:`drain` with the
same deadline/error contract as the persist queue
(:class:`~repro.core.reusing_queue.CheckpointingError`), and
``crash_hook`` is the test seam the fault-injection harness uses to
kill the worker at named task boundaries.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint.remote import (RetryExhaustedError,
                                     TransientStoreError)
from repro.core.reusing_queue import CheckpointingError
from repro.maintenance.progress import MemoryProgress, ProgressJournal


class InjectedCrash(Exception):
    """Raised by a test crash_hook to simulate the maintenance worker
    being killed at a task boundary: the worker thread exits
    immediately, journaling nothing further — exactly what a SIGKILL
    between two journal appends leaves behind."""


class MaintenanceService:
    """Background task runtime for a :class:`~repro.checkpoint.store.
    CheckpointStore`. One worker thread; tasks are queued with
    ``request_*`` (non-blocking), drained with :meth:`drain`, and
    resumed from the progress journal on :meth:`start`."""

    def __init__(self, store, *, gc_slice: int = 64, scrub_slice: int = 8,
                 merge_slice: int = 64, scrub_interval: float = 0.0,
                 orphan_min_age_s: float = 60.0,
                 drain_timeout: float = 120.0):
        self.store = store
        self.gc_slice = max(1, int(gc_slice))
        self.scrub_slice = max(1, int(scrub_slice))
        self.merge_slice = max(1, int(merge_slice))
        self.scrub_interval = scrub_interval
        self.orphan_min_age_s = orphan_min_age_s
        self.drain_timeout = drain_timeout
        root = store.backend.persist_root
        self.progress = (
            ProgressJournal(root, host=getattr(store, "host_id", None))
            if root is not None else MemoryProgress())
        self._q: "queue.Queue[Tuple[str, Any]]" = queue.Queue()
        self._cv = threading.Condition()
        self._pending = 0            # submitted but not yet finished
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._last_scrub = time.monotonic()
        #: the exception that killed the worker, surfaced by drain()
        self.error: Optional[BaseException] = None
        #: test seam: callable(point:str) fired at named task
        #: boundaries; raising InjectedCrash simulates a worker kill
        self.crash_hook = None
        from repro.obs.metrics import InstrumentSet
        self._inst = InstrumentSet("maintenance")
        #: stats() counter keys, synced by tests/test_observability.py
        self.KEYS = ("gc_runs", "gc_swept", "scrub_runs", "scrubbed",
                     "scrub_transient_skips", "corrupt_found",
                     "orphans_swept", "merge_runs", "fold_runs",
                     "folded_patches", "fold_transient_skips",
                     "peer_prune_runs", "peer_pruned", "resumed")
        for k in self.KEYS:
            self._inst.counter(k)
        #: per-task worker latency, by task kind
        self._task_time = self._inst.histogram("task_time_s")

    def __getattr__(self, name):
        # legacy attribute surface: self.gc_runs etc. read counters
        if name != "KEYS" and name in getattr(self, "KEYS", ()):
            return int(self._inst.get(name).value)
        raise AttributeError(name)

    def instruments(self):
        """The backing :class:`~repro.obs.metrics.InstrumentSet`."""
        return self._inst

    def _count(self, attr: str, n: int = 1):
        self._inst.counter(attr).add(n)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "MaintenanceService":
        """Start (or restart) the worker. Unfinished tasks found in the
        progress journal (a previous crash, stop, or surfaced failure)
        are enqueued first, so crash-resume needs no caller action
        beyond constructing + starting. An explicit start() clears a
        previously surfaced error: journaled work is re-attempted,
        un-journaled queued requests from the dead worker are dropped
        (they are idempotent and re-requested by their callers)."""
        if self.running:
            return self
        self._stop.clear()
        self.error = None
        with self._cv:
            self._pending = 0
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        for rec in self.progress.pending():
            self._submit(("resume", rec))
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="ckpt-maintenance")
        self._thread.start()
        return self

    def stop(self, join_timeout: float = 10.0) -> None:
        """Stop after the current slice. Pending planned work stays in
        the progress journal and resumes on the next start()."""
        self._stop.set()
        t = self._thread
        if t is not None and t.is_alive():
            t.join(timeout=join_timeout)
        self.progress.close()

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every requested task has finished — the same
        deadline/error-surfacing contract as the persist queue: a task
        failure re-raises here as CheckpointingError, and the wait is
        bounded (TimeoutError) so flush() can never hang on a dead
        worker."""
        deadline = time.monotonic() + (timeout if timeout is not None
                                       else self.drain_timeout)
        with self._cv:
            while True:
                if self.error is not None:
                    raise CheckpointingError(
                        "maintenance worker failed; pending slices were "
                        "not applied") from self.error
                if self._pending == 0:
                    return
                if not self.running:
                    raise CheckpointingError(
                        f"maintenance worker is not running but "
                        f"{self._pending} task(s) remain pending")
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"maintenance drain did not complete in time "
                        f"({self._pending} task(s) pending)")
                self._cv.wait(min(remaining, 0.05))

    # ------------------------------------------------------------------
    # requests (non-blocking; called from the training/persist threads)
    # ------------------------------------------------------------------
    def request_gc(self, retention_fulls: Optional[int] = None) -> None:
        self._submit(("gc", retention_fulls))

    def request_scrub(self) -> None:
        self._submit(("scrub", None))

    def request_merge(self) -> None:
        self._submit(("merge", None))

    def request_fold(self) -> None:
        """Fold the newest full's accumulated patch chain into its base
        frame (incremental-merging persistence) — journaled and sliced
        like GC, so a kill at any boundary resumes."""
        self._submit(("fold", None))

    def request_peer_prune(self) -> None:
        """Drop peer replicas whose keys left the live manifest (folded
        patches, GC'd chains): peer memory is a recovery accelerator,
        not an archive, so it must track the live chain. Queued
        automatically after fold and GC completions when the store's
        backend has a peer tier; a no-op otherwise. Best-effort and
        idempotent (not journaled — a missed prune is re-covered by the
        next one)."""
        self._submit(("peer_prune", None))

    def _submit(self, req: Tuple[str, Any]) -> None:
        with self._cv:
            self._pending += 1
        self._q.put(req)

    # ------------------------------------------------------------------
    # worker
    # ------------------------------------------------------------------
    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                req = self._q.get(timeout=0.05)
            except queue.Empty:
                if (self.scrub_interval > 0
                        and time.monotonic() - self._last_scrub
                        >= self.scrub_interval):
                    self._last_scrub = time.monotonic()
                    self._submit(("scrub", None))
                continue
            try:
                self._execute(req)
            except InjectedCrash:
                # simulated kill: no bookkeeping, no further journal
                # records — pending work is exactly what a real crash
                # leaves for the next start() to resume
                return
            except BaseException as e:  # noqa: B036 - surfaced by drain
                with self._cv:
                    self.error = e
                    self._pending -= 1
                    self._cv.notify_all()
                return
            with self._cv:
                self._pending -= 1
                self._cv.notify_all()

    def _crash(self, point: str) -> None:
        if self.crash_hook is not None:
            self.crash_hook(point)

    def _execute(self, req: Tuple[str, Any]) -> None:
        kind, arg = req
        from repro.obs.trace import trace_span
        t0 = time.perf_counter()
        with trace_span(f"maint.{kind}", "maintenance"):
            self._dispatch(kind, arg)
        self._task_time.observe(time.perf_counter() - t0)

    def _dispatch(self, kind: str, arg: Any) -> None:
        if kind == "gc":
            self._run_gc(arg)
        elif kind == "scrub":
            self._run_scrub()
        elif kind == "merge":
            self._run_merge()
        elif kind == "fold":
            self._run_fold()
        elif kind == "peer_prune":
            self._run_peer_prune()
        elif kind == "resume":
            self._resume(arg)
        else:
            raise ValueError(f"unknown maintenance request {kind!r}")

    def _resume(self, rec: dict) -> None:
        task = rec.get("task")
        self._count("resumed")
        if task == "gc":
            self._gc_sweep(int(rec["id"]),
                           [tuple(d) for d in rec.get("doomed", [])],
                           rec.get("retention"), int(rec.get("pos", 0)))
        elif task == "scrub":
            self._scrub_sweep(int(rec["id"]),
                              [tuple(e) for e in rec.get("entries", [])],
                              int(rec.get("pos", 0)))
        elif task == "merge":
            # the merge itself is atomic + watermark-idempotent: redo it
            self._merge_step(int(rec["id"]))
        elif task == "fold":
            self._fold_sweep(int(rec["id"]), rec["base"],
                             list(rec.get("patches", [])),
                             int(rec.get("state_step", 0)),
                             int(rec.get("pos", 0)),
                             bool(rec.get("folded")))
        else:
            raise ValueError(f"unknown journaled task {task!r}")

    # ------------------------------------------------------------------
    # resumable GC: mark (journaled plan) -> sweep (journaled cursor)
    # ------------------------------------------------------------------
    def _run_gc(self, retention_fulls: Optional[int]) -> None:
        doomed = self.store.gc_plan(retention_fulls)
        if not doomed:
            return
        tid = self.progress.next_id()
        self.progress.append({"task": "gc", "id": tid, "op": "plan",
                              "retention": retention_fulls,
                              "doomed": [list(d) for d in doomed]})
        self._crash("gc:marked")
        self._gc_sweep(tid, doomed, retention_fulls, 0)

    def _gc_sweep(self, tid: int, doomed: List[Tuple[str, str]],
                  retention_fulls: Optional[int], pos: int) -> None:
        hook = ((lambda point, key: self._crash(point))
                if self.crash_hook is not None else None)
        while pos < len(doomed):
            chunk = doomed[pos:pos + self.gc_slice]
            removed = self.store.gc_apply(chunk, retention_fulls,
                                          crash_hook=hook)
            self._count("gc_swept", sum(removed.values()))
            pos += len(chunk)
            self._crash("gc:swept_slice")
            self.progress.append({"task": "gc", "id": tid,
                                  "op": "cursor", "pos": pos})
            self._crash("gc:cursored")
        self.progress.append({"task": "gc", "id": tid, "op": "done"})
        self.progress.compact_if_idle()
        self._count("gc_runs")
        self._queue_peer_prune()

    # ------------------------------------------------------------------
    # peer-replica pruning: peer memory tracks the live chain
    # ------------------------------------------------------------------
    def _queue_peer_prune(self) -> None:
        if getattr(self.store.backend, "prune_replicas", None) is not None:
            self.request_peer_prune()

    def _run_peer_prune(self) -> None:
        prune = getattr(self.store.backend, "prune_replicas", None)
        if prune is None:
            return
        # everything the live manifest still references stays; anything
        # this host replicated that fell out (folded patches, GC'd
        # chains, dropped quarantine) is deleted from the peers
        keep = {key for _, key in self.store.scrub_targets()}
        self._count("peer_pruned", int(prune(keep)))
        self._count("peer_prune_runs")

    # ------------------------------------------------------------------
    # integrity scrub: journaled walk over cold blobs
    # ------------------------------------------------------------------
    def _run_scrub(self) -> None:
        entries = self.store.scrub_targets()
        tid = self.progress.next_id()
        self.progress.append({"task": "scrub", "id": tid, "op": "plan",
                              "entries": [list(e) for e in entries]})
        self._crash("scrub:planned")
        self._scrub_sweep(tid, entries, 0)

    def _scrub_sweep(self, tid: int, entries: List[Tuple[str, str]],
                     pos: int) -> None:
        while pos < len(entries):
            for kind, key in entries[pos:pos + self.scrub_slice]:
                try:
                    reason = self.store.backend.verify(key)
                except FileNotFoundError:
                    continue  # GC'd or pruned since the plan — fine
                except (RetryExhaustedError, TransientStoreError):
                    # flaky infrastructure, not corruption: skip the
                    # blob, the next periodic scrub retries it — a
                    # transient must never poison the worker (every
                    # later flush() would fail on an intact store)
                    self._count("scrub_transient_skips")
                    continue
                self._count("scrubbed")
                if reason is not None:
                    if self.store.quarantine(kind, key, reason):
                        self._count("corrupt_found")
            pos = min(pos + self.scrub_slice, len(entries))
            self._crash("scrub:swept_slice")
            self.progress.append({"task": "scrub", "id": tid,
                                  "op": "cursor", "pos": pos})
            self._crash("scrub:cursored")
        try:
            self._count("orphans_swept", self.store.backend.sweep_orphans(
                self.orphan_min_age_s))
        except (RetryExhaustedError, TransientStoreError):
            self._count("scrub_transient_skips")  # orphans wait for next pass
        self.progress.append({"task": "scrub", "id": tid, "op": "done"})
        self.progress.compact_if_idle()
        self._count("scrub_runs")
        self._last_scrub = time.monotonic()

    # ------------------------------------------------------------------
    # incremental merge: fold the patch chain into its base frame
    # ------------------------------------------------------------------
    def _run_fold(self) -> None:
        plan = self.store.fold_plan()
        if plan is None:
            return
        base_key, patch_keys, state_step = plan
        tid = self.progress.next_id()
        self.progress.append({"task": "fold", "id": tid, "op": "plan",
                              "base": base_key, "patches": patch_keys,
                              "state_step": state_step})
        self._crash("fold:planned")
        self._fold_sweep(tid, base_key, patch_keys, state_step, 0, False)

    def _fold_sweep(self, tid: int, base_key: str, patch_keys: List[str],
                    state_step: int, pos: int, folded: bool) -> None:
        """Sweep phase: pwrite the merged dirty leaves into the base
        frame in bounded ``merge_slice``-leaf slices, a cursor record
        after each; then mark the sweep folded and retire the chain.
        Every slice is idempotent — the patch blobs (the write-ahead
        log) outlive the whole sweep, so a kill anywhere re-folds to
        identical bytes on resume."""
        if not folded:
            try:
                updates = self.store.fold_updates(base_key, patch_keys)
            except (RetryExhaustedError, TransientStoreError):
                # flaky infrastructure, not corruption: leave the plan
                # journaled (it resumes on the next start / request)
                # — a transient must never poison the worker
                self._count("fold_transient_skips")
                return
            if updates is None:
                # chain or base gone since the plan (superseded by a
                # newer full / GC): nothing left to fold — retire
                self.progress.append({"task": "fold", "id": tid,
                                      "op": "done"})
                self.progress.compact_if_idle()
                return
            names = updates.names()
            while pos < len(names):
                chunk = updates.subset(names[pos:pos + self.merge_slice])
                try:
                    self.store.fold_slice(base_key, chunk)
                except (RetryExhaustedError, TransientStoreError):
                    self._count("fold_transient_skips")
                    return                # cursor journaled: resumes here
                except FileNotFoundError:
                    # base deleted under the fold (concurrent GC after a
                    # newer full): the chain is superseded — retire
                    self.progress.append({"task": "fold", "id": tid,
                                          "op": "done"})
                    self.progress.compact_if_idle()
                    return
                pos += len(chunk)
                self._crash("fold:patched_slice")
                self.progress.append({"task": "fold", "id": tid,
                                      "op": "cursor", "pos": pos})
                self._crash("fold:cursored")
            self.progress.append({"task": "fold", "id": tid, "op": "cursor",
                                  "pos": pos, "folded": True})
            self._crash("fold:folded")
        self.store.fold_commit(base_key, patch_keys, state_step)
        self.progress.append({"task": "fold", "id": tid, "op": "done"})
        self.progress.compact_if_idle()
        self._count("fold_runs")
        self._count("folded_patches", len(patch_keys))
        self._queue_peer_prune()

    # ------------------------------------------------------------------
    # journal-segment merge
    # ------------------------------------------------------------------
    def _run_merge(self) -> None:
        tid = self.progress.next_id()
        self.progress.append({"task": "merge", "id": tid, "op": "plan"})
        self._crash("merge:planned")
        self._merge_step(tid)

    def _merge_step(self, tid: int) -> None:
        self.store.merge_journal()
        self.progress.append({"task": "merge", "id": tid, "op": "done"})
        self.progress.compact_if_idle()
        self._count("merge_runs")

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        with self._cv:
            pending = self._pending
        return {"running": self.running, "pending": pending,
                "gc_runs": self.gc_runs, "gc_swept": self.gc_swept,
                "scrub_runs": self.scrub_runs, "scrubbed": self.scrubbed,
                "scrub_transient_skips": self.scrub_transient_skips,
                "corrupt_found": self.corrupt_found,
                "orphans_swept": self.orphans_swept,
                "merge_runs": self.merge_runs,
                "fold_runs": self.fold_runs,
                "folded_patches": self.folded_patches,
                "fold_transient_skips": self.fold_transient_skips,
                "peer_prune_runs": self.peer_prune_runs,
                "peer_pruned": self.peer_pruned,
                "resumed": self.resumed,
                "error": repr(self.error) if self.error else None,
                "progress": self.progress.stats()}
