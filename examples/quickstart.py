"""Quickstart: per-iteration differential checkpointing with LowDiff.

Trains a small GPT-2-family model on CPU with checkpointing *every
iteration*, then simulates a crash and recovers — demonstrating that the
recovered state equals the live state (the compressed gradient IS the
differential checkpoint, Finding 1 of the paper).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import shutil

import jax
import numpy as np

from repro.checkpoint import StoreConfig, TierSpec
from repro.configs import get_config
from repro.core.engine import EngineConfig, make_engine
from repro.core.steps import init_state
from repro.data.synthetic import TokenStream
from repro.models.registry import build_model

CKPT_DIR = "/tmp/repro_quickstart"


def main():
    shutil.rmtree(CKPT_DIR, ignore_errors=True)
    cfg = get_config("gpt2-l").reduced()
    model = build_model(cfg)
    print(f"model: {cfg.name} ({model.n_params() / 1e6:.1f}M params)")

    # the store is a declarative tier stack: swap TierSpec("local") for
    # TierSpec("sharded")/TierSpec("memory")/... — or prepend
    # TierSpec("peer", replicas=2) for Checkmate-style peer replication
    store = StoreConfig(CKPT_DIR, tiers=[TierSpec("local")],
                        retention_fulls=2).build()
    lowdiff = make_engine(
        EngineConfig(strategy="lowdiff", rho=0.01, lr=1e-3,
                     full_interval=10, batch_size=2),
        model, store=store)
    state = init_state(model, jax.random.PRNGKey(0))
    stream = TokenStream(cfg, seq_len=64, batch=4)

    print("\ntraining 25 steps, checkpointing EVERY iteration...")
    for t in range(25):
        state, metrics = lowdiff.train_step(state, next(stream))
        if (t + 1) % 5 == 0:
            print(f"  step {t + 1:3d}  loss {float(metrics['loss']):.4f}")
    lowdiff.flush()

    s = lowdiff.stats()
    print(f"\ncheckpoints: {s['store']['fulls']} full, "
          f"{s['store']['batches']} batched-diff writes "
          f"({s['store']['bytes'] / 2 ** 20:.1f} MiB total)")
    print(f"checkpointing time inside the training loop: "
          f"{s['train_loop_ckpt_time'] * 1e3:.1f} ms over 25 steps")

    print("\n*** simulating failure; recovering from storage ***")
    recovered, n = lowdiff.recover()
    err = max(float(np.max(np.abs(np.asarray(a, np.float32)
                                  - np.asarray(b, np.float32))))
              for a, b in zip(jax.tree.leaves(recovered["params"]),
                              jax.tree.leaves(state["params"])))
    print(f"recovered to step {int(recovered['step'])} "
          f"(replayed {n} differentials); max |Δparam| vs live = {err:.2e}")
    assert err < 1e-6
    lowdiff.close()
    print("OK — recovery is exact.")


if __name__ == "__main__":
    main()
