"""Batched serving example: decode a batch of requests with a KV cache.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import argparse

from repro.launch import serve


def main():
    args = argparse.Namespace(arch="qwen2-1.5b", reduced=True, batch=8,
                              prompt_len=16, gen=32)
    out = serve.run(args)
    assert len(out) == args.gen
    print("OK")


if __name__ == "__main__":
    main()
