"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
LowDiff per-iteration checkpointing and two injected failures.

This is the deliverable-(b) end-to-end example; it delegates to the real
launcher (repro.launch.train). Expect ~10-20 min on one CPU core; pass
--quick for a 40-step smoke variant.

Run:  PYTHONPATH=src python examples/train_with_failures.py [--quick]
"""
import argparse

from repro.configs import get_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    q = ap.parse_args()

    argv = argparse.Namespace(
        arch="gpt2-l", reduced=False, steps=40 if q.quick else 300,
        batch=2, seq=64 if q.quick else 128, lr=1e-3, rho=0.01,
        strategy="lowdiff", full_interval=20, batch_size=2,
        ckpt_dir="/tmp/repro_e2e", clean=True,
        fail_at=20 if q.quick else 150, seed=0, log_every=10)
    # ~100M model: trim gpt2-l (762M) to a 12-layer/768-d variant
    cfg = get_config("gpt2-l").replace(
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
        vocab=16384 if q.quick else 50257)
    if q.quick:
        cfg = cfg.reduced()

    import repro.launch.train as T
    orig = T.get_config
    T.get_config = lambda name: cfg
    try:
        losses, times = T.run(argv)
    finally:
        T.get_config = orig
    assert losses[-1] < losses[0], "loss should decrease"
    print("\nend-to-end driver finished; loss decreased "
          f"{losses[0]:.3f} -> {losses[-1]:.3f} across an injected failure.")


if __name__ == "__main__":
    main()
