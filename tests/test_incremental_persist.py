"""Incremental-merging persistence engine tests.

Covers the subsystem's acceptance criteria:
  * ``patch_frame`` pwrites leaves in place, data before header, and
    rejects layout changes / npz files
  * every backend (LocalFS / Sharded / MemoryTier / Remote) patches
    bit-identically; the remote backend re-puts only intersecting
    chunks and reuses the rest by name
  * a kill mid-pwrite, mid-header-rewrite, or mid-merge-slice recovers
    bit-identical to the last committed persist (the patch chain is
    the fold's write-ahead log)
  * npz-format stores reject incremental persistence with a clear error
  * dirty tracking persists O(changed bytes): a sparse-update workload
    writes >= 5x fewer bytes per persist than full persistence
  * windowed parallel replay matches the unwindowed scan
"""
import os
import time

import numpy as np
import pytest

from repro.checkpoint import io as cio
from repro.checkpoint import make_store
from repro.checkpoint.remote import FakeObjectStore, RemoteObjectBackend
from repro.checkpoint.store import (CheckpointStore, merge_updates,
                                    payload_names, walk_leaves)
from repro.core.lowdiff_plus import _NumpyAdam
from repro.maintenance import InjectedCrash, MaintenanceService

RNG = np.random.default_rng(7)


def rand(shape, scale=1.0):
    return (scale * RNG.standard_normal(shape)).astype(np.float32)


def mk_state(n_leaves=6, leaf=64):
    return {"params": {f"w{i}": rand(leaf) for i in range(n_leaves)},
            "mu": {f"w{i}": rand(leaf) for i in range(n_leaves)},
            "nu": {f"w{i}": np.abs(rand(leaf)) for i in range(n_leaves)},
            "count": np.array(1, np.int64)}


def deep_copy_state(state):
    return {k: ({kk: np.array(vv) for kk, vv in v.items()}
                if isinstance(v, dict) else np.array(v))
            for k, v in state.items()}


def assert_state_equal(a, b, context=""):
    bleaves = dict(walk_leaves(b))
    for path, leaf in walk_leaves(a):
        np.testing.assert_array_equal(
            np.asarray(leaf), np.asarray(bleaves[path]),
            err_msg=f"{context}: leaf {path}")


def mk_patch(state, dirty, count):
    """Partial state dict updating `dirty` leaves + the Adam count."""
    upd = {"params": {}, "mu": {}, "nu": {},
           "count": np.array(count, np.int64)}
    for k in dirty:
        upd["params"][k] = rand(state["params"][k].shape)
        upd["mu"][k] = rand(state["mu"][k].shape)
        upd["nu"][k] = np.abs(rand(state["nu"][k].shape))
    return upd


# --------------------------------------------------------------------------
# patch_frame primitive
# --------------------------------------------------------------------------

def test_patch_frame_roundtrip(tmp_path):
    path = str(tmp_path / "f.ckpt")
    payload = {"a0": rand(32), "a1": rand((8, 4)), "a2": rand(16)}
    cio.save_frame_payload(path, payload)
    updates = {"a0": rand(32), "a2": rand(16)}
    n = cio.patch_frame(path, updates)
    assert n > 0
    _, leaves = cio.read_frame(path, verify=True)  # sha256s were updated
    np.testing.assert_array_equal(leaves["a0"], updates["a0"])
    np.testing.assert_array_equal(leaves["a1"], payload["a1"])
    np.testing.assert_array_equal(leaves["a2"], updates["a2"])


def test_patch_frame_rejects_layout_changes(tmp_path):
    path = str(tmp_path / "f.ckpt")
    cio.save_frame_payload(path, {"a0": rand(32)})
    with pytest.raises(ValueError, match="layout mismatch"):
        cio.patch_frame(path, {"a0": rand(16)})          # wrong shape
    with pytest.raises(ValueError, match="layout mismatch"):
        cio.patch_frame(path, {"a0": rand(32).astype(np.float64)})
    with pytest.raises(ValueError, match="no leaf"):
        cio.patch_frame(path, {"zz": rand(32)})
    _, leaves = cio.read_frame(path, verify=True)        # file untouched
    assert leaves["a0"].shape == (32,)


def test_patch_frame_rejects_npz(tmp_path):
    path = str(tmp_path / "f.npz")
    cio.save(path, {"a": rand(8)})
    with pytest.raises(cio.FrameCorruptionError, match="bad magic"):
        cio.patch_frame(path, {"a0": rand(8)})


# --------------------------------------------------------------------------
# backend patch implementations: bit-identical, format-guarded
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend,kw", [
    ("local", {}),
    ("sharded", {"shards": 3}),
    ("memory", {}),
])
def test_store_patch_chain_and_fold(tmp_path, backend, kw):
    store = make_store(str(tmp_path / backend), backend=backend, **kw)
    state = mk_state(n_leaves=4, leaf=128)
    # one large splittable leaf so the sharded backend exercises both
    # placement kinds
    state["params"]["big"] = rand((256, 64))
    state["mu"]["big"] = rand((256, 64))
    state["nu"]["big"] = np.abs(rand((256, 64)))
    base = store.save_full(2, state, record_names=True)
    expected = deep_copy_state(state)
    for step, dirty in ((3, ("w0", "big")), (4, ("w0",)), (5, ("w2",))):
        upd = mk_patch(state, dirty, step)
        store.save_patch(step, base, upd)
        merge_updates(expected, upd)
    got, step = store.load_latest_state()
    assert step == 5
    assert_state_equal(expected, got, f"{backend} chain")
    # fold in bounded slices: one frame read afterwards, still identical
    assert store.fold_sync(merge_slice=2) == 3
    assert store.manifest.get("patches", []) == []
    assert not any(k.startswith("patch_") for k in store.backend.keys())
    entry = store.latest_full()
    assert entry["state_step"] == 5
    assert_state_equal(expected, store.load_full(entry), f"{backend} fold")
    got2, step2 = store.load_latest_state()
    assert step2 == 5
    assert_state_equal(expected, got2, f"{backend} post-fold")
    assert store.backend.verify(base) is None   # header sha256s refreshed
    store.close()


def test_npz_store_rejects_incremental(tmp_path):
    store = make_store(str(tmp_path / "npz"), fmt="npz")
    key = store.save_full(1, mk_state(2))
    with pytest.raises(ValueError, match="frame"):
        store.save_patch(2, key, mk_patch(mk_state(2), ("w0",), 2))
    store.close()


def test_npz_engine_rejects_incremental(tmp_path):
    from repro.core.lowdiff_plus import LowDiffPlus
    store = make_store(str(tmp_path / "npz"), fmt="npz")
    with pytest.raises(ValueError, match="persist-mode|frame"):
        LowDiffPlus(object(), store, persist_mode="incremental")
    store.close()


def test_remote_patch_reuses_unchanged_chunks(tmp_path):
    obj = FakeObjectStore()
    be = RemoteObjectBackend(obj, chunk_bytes=4096,
                             journal_root=str(tmp_path))
    store = CheckpointStore(backend=be)
    state = mk_state(n_leaves=8, leaf=2048)   # 8 KiB leaves, 4 KiB chunks
    base = store.save_full(2, state, record_names=True)
    old_index = {c["name"] for c in be._load_index(base)["chunks"]}
    expected = deep_copy_state(state)
    upd = mk_patch(state, ("w3",), 2)
    store.save_patch(3, base, upd)
    merge_updates(expected, upd)
    assert store.fold_sync() == 1
    new_chunks = be._load_index(base)["chunks"]
    new_index = {c["name"] for c in new_chunks}
    reused = old_index & new_index
    fresh = new_index - old_index
    # only the chunks the dirty leaf's ranges (and the header) intersect
    # were re-put; the rest are referenced by their old names
    assert reused and fresh
    assert len(fresh) < len(new_chunks)
    assert_state_equal(expected, store.load_full(store.latest_full()),
                       "remote fold")
    assert be.verify(base) is None
    # orphan sweep keeps every index-referenced chunk (old gen or new)
    be.sweep_orphans(min_age_s=0.0)
    assert_state_equal(expected, store.load_full(store.latest_full()),
                       "remote fold after orphan sweep")
    store.close()


def test_memory_tier_patch_reaches_lower_tier(tmp_path):
    store = make_store(str(tmp_path / "mem"), backend="memory")
    state = mk_state(3)
    base = store.save_full(1, state, record_names=True)
    upd = mk_patch(state, ("w1",), 2)
    store.save_patch(2, base, upd)
    expected = deep_copy_state(state)
    merge_updates(expected, upd)
    assert store.fold_sync() == 1
    store.backend.flush()
    # the lower tier's file matches the RAM tier after write-back
    lower_state = store.backend.lower.get(base)
    assert_state_equal(expected, lower_state, "lower tier")
    assert store.backend.lower.verify(base) is None
    store.close()


# --------------------------------------------------------------------------
# crash injection: kill mid-pwrite / mid-header / mid-merge-slice
# --------------------------------------------------------------------------

class Killed(RuntimeError):
    pass


def build_patched_store(root, n_patches=3):
    store = make_store(root)
    state = mk_state(n_leaves=6, leaf=256)
    base = store.save_full(2, state, record_names=True)
    expected = deep_copy_state(state)
    for i in range(n_patches):
        upd = mk_patch(state, (f"w{i}", f"w{i + 1}"), 3 + i)
        store.save_patch(3 + i, base, upd)
        merge_updates(expected, upd)
    return store, base, expected


@pytest.mark.parametrize("point", ["patch:mid_data", "patch:pre_header",
                                   "patch:mid_header"])
def test_crash_inside_patch_frame_recovers_bit_identical(tmp_path, point):
    """A kill inside the in-place pwrite (some leaves written, header
    stale or torn) must not lose the last committed persist: the patch
    blobs are the write-ahead log and replay over the torn base."""
    store, base, expected = build_patched_store(str(tmp_path / "s"))

    def hook(p):
        if p == point:
            raise Killed(p)
    cio.set_patch_crash_hook(hook)
    try:
        with pytest.raises(Killed):
            store.fold_sync()
    finally:
        cio.set_patch_crash_hook(None)
    store.journal.close()

    # "restart": reload the store from disk over the torn base frame
    store2 = make_store(str(tmp_path / "s"))
    got, step = store2.load_latest_state()
    assert step == 5
    assert_state_equal(expected, got, f"after {point}")
    # the interrupted fold re-runs to completion and stays identical
    assert store2.fold_sync() == 3
    assert_state_equal(expected, store2.load_full(store2.latest_full()),
                       f"refold after {point}")
    assert store2.backend.verify(base) is None
    store2.close()


def kill_at(svc, point):
    state = {"armed": True}

    def hook(p):
        if p == point and state["armed"]:
            state["armed"] = False
            raise InjectedCrash(p)
    svc.crash_hook = hook
    return state


def wait_dead(svc, timeout=10.0):
    deadline = time.monotonic() + timeout
    while svc.running and time.monotonic() < deadline:
        time.sleep(0.005)
    assert not svc.running, "worker survived the injected crash"


@pytest.mark.parametrize("point", ["fold:planned", "fold:patched_slice",
                                   "fold:cursored", "fold:folded"])
def test_crash_at_fold_boundaries_resumes(tmp_path, point):
    """A kill at any journaled fold boundary (after the plan, after a
    merge slice, after its cursor, after the folded marker) resumes
    from the progress journal and lands bit-identical, with the patch
    chain fully retired."""
    root = str(tmp_path / "s")
    store, base, expected = build_patched_store(root)
    svc = MaintenanceService(store, merge_slice=2)
    store.attach_maintenance(svc)
    svc.start()
    kill_at(svc, point)
    svc.request_fold()
    wait_dead(svc)
    svc.stop()
    store.journal.close()

    # restart: fresh store + service; pending fold resumes on start()
    store2 = make_store(root)
    svc2 = MaintenanceService(store2, merge_slice=2)
    store2.attach_maintenance(svc2)
    svc2.start()
    svc2.drain(30.0)
    assert store2.manifest.get("patches", []) == []
    assert not any(k.startswith("patch_") for k in store2.backend.keys())
    entry = store2.latest_full()
    assert entry["state_step"] == 5
    assert_state_equal(expected, store2.load_full(entry), f"after {point}")
    assert store2.backend.verify(base) is None
    assert svc2.fold_runs >= 1
    store2.close()


def test_fold_after_superseding_full_retires_quietly(tmp_path):
    """A fold planned for a chain whose base was superseded (newer full
    + GC) retires without error and deletes nothing live."""
    root = str(tmp_path / "s")
    store, base, _ = build_patched_store(root)
    new_state = mk_state(6, 256)
    store.save_full(9, new_state, record_names=True)
    store.gc(retention_fulls=1)        # dooms old base + its patches
    assert store.manifest.get("patches", []) == []
    assert store.fold_sync() == 0      # nothing left to fold
    got, step = store.load_latest_state()
    assert step == 9
    assert_state_equal(new_state, got, "superseded")
    store.close()


def test_gc_sweeps_patch_chain_with_its_base(tmp_path):
    store, base, _ = build_patched_store(str(tmp_path / "s"))
    store.save_full(9, mk_state(6, 256))
    store.gc(retention_fulls=1)
    on_disk = set(store.backend.keys())
    refd = {store._entry_key(e) for kind in ("fulls", "diffs", "batches",
                                             "patches", "quarantined")
            for e in store.manifest.get(kind, [])}
    assert on_disk == refd             # no leak, no loss
    assert not any(k.startswith("patch_") for k in on_disk)
    assert base not in on_disk
    store.close()


# --------------------------------------------------------------------------
# dirty tracking: bytes written scale with changed leaves
# --------------------------------------------------------------------------

def make_replica(n_leaves=20, leaf=1024, track=True):
    params = {f"w{i}": rand(leaf, 0.1) for i in range(n_leaves)}
    mu = {k: np.zeros_like(v) for k, v in params.items()}
    nu = {k: np.zeros_like(v) for k, v in params.items()}
    return _NumpyAdam(params, mu, nu, 0, lr=1e-3, track_dirty=track)


def sparse_grads(rep, hot, scale=1.0):
    return {k: (rand(v.shape, scale) if k in hot else np.zeros_like(v))
            for k, v in rep.params.items()}


def test_sparse_workload_writes_5x_fewer_bytes(tmp_path):
    """The acceptance criterion at unit level: <= 20% of leaves dirty
    per interval => >= 5x fewer bytes per persist than full mode."""
    hot = {"w0", "w1", "w2"}                             # 3 of 20 leaves
    full_store = make_store(str(tmp_path / "full"))
    rep = make_replica(track=False)
    for step in range(1, 5):
        rep.apply(sparse_grads(rep, hot))
        full_store.save_full(step, rep.snapshot_full())
    full_bytes = full_store.bytes_written / 4

    incr_store = make_store(str(tmp_path / "incr"))
    rep = make_replica(track=True)
    rep.apply(sparse_grads(rep, hot))
    base = incr_store.save_full(1, rep.snapshot_full(), record_names=True)
    base_bytes = incr_store.bytes_written
    for step in range(2, 6):
        rep.apply(sparse_grads(rep, hot))
        updates, _ = rep.snapshot_dirty()
        assert set(updates["params"]) == hot              # only dirty leaves
        incr_store.save_patch(step, base, updates)
    patch_bytes = (incr_store.bytes_written - base_bytes) / 4
    assert full_bytes >= 5 * patch_bytes, (full_bytes, patch_bytes)
    # and the chain still recovers the exact replica state
    got, _ = incr_store.load_latest_state()
    assert_state_equal(rep.snapshot_full(), got, "sparse chain")
    full_store.close()
    incr_store.close()


def test_zero_grad_zero_moment_leaves_are_skipped():
    rep = make_replica(n_leaves=4)
    rep.snapshot_full()              # clean baseline (fresh = all dirty)
    rep.apply(sparse_grads(rep, {"w1"}))
    assert rep.skipped_applies == 3
    updates, _ = rep.snapshot_dirty()
    assert set(updates["params"]) == {"w1"}
    # a cold leaf's moments stay zero: bit-identical to never touching it
    np.testing.assert_array_equal(rep.mu["w0"], np.zeros(1024, np.float32))


def test_persist_threshold_defers_near_converged_leaves():
    """Adam updates are ~lr-sized per apply regardless of gradient
    magnitude, so the threshold distinguishes by *accumulated* drift:
    one apply stays under it, many applies cross it."""
    rep = make_replica(n_leaves=4)
    rep.snapshot_full()
    rep.apply(sparse_grads(rep, {"w0"}))
    rep.apply(sparse_grads(rep, {"w1"}))
    # one ~lr (1e-3) update on ~0.3-max params is below 2% relative
    updates, deferred = rep.snapshot_dirty(threshold=0.02)
    assert deferred == 2
    assert set(updates["params"]) == set()
    # the deferred leaf stays dirty and keeps accumulating drift...
    for _ in range(30):
        rep.apply(sparse_grads(rep, {"w1"}))
    updates, deferred = rep.snapshot_dirty(threshold=0.02)
    assert set(updates["params"]) == {"w1"}              # ...until it crosses
    assert deferred == 1                                 # w0 still deferred


def test_threshold_zero_is_exact(tmp_path):
    store = make_store(str(tmp_path / "s"))
    rep = make_replica(n_leaves=5, leaf=64)
    rep.apply(sparse_grads(rep, {"w0", "w3"}))
    base = store.save_full(1, rep.snapshot_full(), record_names=True)
    for step in range(2, 6):
        rep.apply(sparse_grads(rep, {f"w{step % 5}"}))
        updates, deferred = rep.snapshot_dirty(0.0)
        assert deferred == 0
        store.save_patch(step, base, updates)
    got, _ = store.load_latest_state()
    assert_state_equal(rep.snapshot_full(), got, "threshold 0")
    store.close()


def test_failed_patch_persist_remarks_leaves_dirty():
    """A patch that never became durable must ride the next persist:
    its leaves' dirty bits were cleared at snapshot time, so a lost
    patch re-dirties them (with infinite drift, defeating any
    threshold) — otherwise every later recovery silently restores
    stale values for exactly those leaves."""
    rep = make_replica(n_leaves=4)
    rep.snapshot_full()
    rep.apply(sparse_grads(rep, {"w2"}))
    updates, _ = rep.snapshot_dirty()
    assert set(updates["params"]) == {"w2"}
    # persist "failed": nothing is dirty right now...
    assert set(rep.snapshot_dirty()[0]["params"]) == set()
    rep.remark_dirty(updates)
    got, deferred = rep.snapshot_dirty(threshold=1e9)   # beats any filter
    assert deferred == 0
    assert set(got["params"]) == {"w2"}


def test_fold_commit_entry_rewrite_is_atomic(tmp_path):
    """The fold's state_step advance is ONE journal record (op
    "replace"), written before any patch record is deleted: a crash
    that tears it off the log leaves the old full entry *and* the whole
    patch chain intact — there is no window in which the manifest has
    zero fulls (the old del-then-add pair had exactly that window)."""
    store, base, expected = build_patched_store(str(tmp_path / "s"))
    # fold the data in (all slices), but crash on the commit's first
    # journal write: the replace record never becomes durable
    updates = store.fold_updates(base, [f"patch_{s:08d}" for s in (3, 4, 5)])
    store.fold_slice(base, updates)
    log = os.path.join(str(tmp_path / "s"), "manifest.log")
    before = os.path.getsize(log)
    store.fold_commit(base, [f"patch_{s:08d}" for s in (3, 4, 5)], 5)
    store.journal.close()
    with open(log, "r+b") as f:        # tear the commit's records off
        f.truncate(before)
    # blobs deleted by the torn commit are restored as a real crash
    # would leave them only if their del record was also lost — the
    # journaled del always precedes each blob delete, so the worst
    # legal tear is: replace lost, zero patch records deleted
    store2 = make_store(str(tmp_path / "s"))
    entry = store2.latest_full()
    assert entry is not None                      # never zero fulls
    assert "state_step" not in entry              # old entry, intact
    # surviving chain entries replay idempotently over the folded base
    got, step = store2.load_latest_state()
    assert_state_equal(expected, got, "torn fold commit")
    store2.close()


def test_fold_plan_reaches_orphaned_older_chain(tmp_path):
    """A restart cuts a fresh base full; the previous base's patch
    chain must still fold (it stays the recovery fallback and must
    stay bounded) instead of lingering forever."""
    store, base, expected = build_patched_store(str(tmp_path / "s"))
    store.save_full(9, mk_state(6, 256), record_names=True)   # new base
    plan = store.fold_plan()
    assert plan is not None and plan[0] == base
    assert store.fold_sync() == 3
    assert store.manifest.get("patches", []) == []
    old_entry = next(e for e in store.manifest["fulls"]
                     if store._entry_key(e) == base)
    assert old_entry["state_step"] == 5
    assert_state_equal(expected, store.load_full(old_entry), "old chain")
    store.close()


# --------------------------------------------------------------------------
# windowed parallel replay (satellite: bounded recovery memory)
# --------------------------------------------------------------------------

def test_replay_parallel_windowed_matches_unwindowed():
    import jax
    from repro.core import recovery as rec
    from repro.optim.adam import AdamState
    rng = np.random.default_rng(3)
    params = {"w": rng.standard_normal((16, 8)).astype(np.float32),
              "b": rng.standard_normal(8).astype(np.float32)}
    opt = AdamState(
        {k: np.zeros_like(v) for k, v in params.items()},
        {k: np.zeros_like(v) for k, v in params.items()},
        np.int32(0))
    diffs = [(i + 1, {k: rng.standard_normal(v.shape).astype(np.float32)
                      for k, v in params.items()}) for i in range(7)]
    p_one, o_one, n_one = rec.replay_parallel(params, opt, diffs, lr=1e-3)
    p_ser, o_ser = rec.replay_serial(params, opt, diffs, lr=1e-3)
    assert n_one == len(diffs)
    for w in (1, 3, 7, 100):
        p_w, o_w, n_w = rec.replay_parallel(params, opt, diffs, lr=1e-3,
                                            window=w)
        assert n_w == len(diffs)
        assert int(o_w.count) == int(o_one.count)
        for a, b in zip(jax.tree.leaves(p_one), jax.tree.leaves(p_w)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6, rtol=1e-5)
        for a, b in zip(jax.tree.leaves(o_ser.mu), jax.tree.leaves(o_w.mu)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6, rtol=1e-5)


# --------------------------------------------------------------------------
# payload-name mapping
# --------------------------------------------------------------------------

def test_payload_names_align_with_frame_leaves(tmp_path):
    state = mk_state(3, 16)
    names = payload_names(state)
    path = str(tmp_path / "f.ckpt")
    cio.save_frame(path, state)
    _, leaves = cio.read_frame(path)
    for p, leaf in walk_leaves(state):
        assert p in names, p
        np.testing.assert_array_equal(np.asarray(leaves[names[p]]),
                                      np.asarray(leaf))
