"""Guard: the train.py flag surface and the declarative config surface
cannot drift apart.

Every ``--flag`` in :func:`repro.launch.train.build_parser` must either
be a runtime input (``RUNTIME_FLAGS``) or map to a real config field via
:data:`repro.core.engine.FLAG_MAP` — and every config field must have a
flag unless it is on the explicit no-flag allowlist below. Adding a flag
without a config field (or vice versa) fails this module.
"""
from __future__ import annotations

import dataclasses

import pytest

from repro.checkpoint.config import _TIER_FIELDS, StoreConfig, TierSpec
from repro.core.engine import FLAG_MAP, RUNTIME_FLAGS, EngineConfig
from repro.launch.train import build_parser

ENGINE_FIELDS = {f.name for f in dataclasses.fields(EngineConfig)}
STORE_FIELDS = {f.name for f in dataclasses.fields(StoreConfig)}
TIER_FIELDS = {f.name for f in dataclasses.fields(TierSpec)}

#: config fields deliberately without a CLI flag. Grow this list only
#: with a reason — anything else missing a flag is a sync failure.
NO_FLAG_STORE = {
    "compact_every",      # journal tuning; config-file / API only
}
NO_FLAG_TIER = {
    "kind",               # implied by the flag itself (--backend/--peers)
    "node_id",            # derived from --host-id in from_legacy
    "latency_s_per_mb",   # simulation-only knob (benchmarks/tests)
    "simulate_peers",     # set by from_args for single-process runs
}


def parser_dests():
    return {a.dest for a in build_parser()._actions if a.dest != "help"}


def test_every_flag_is_mapped_or_runtime():
    unmapped = parser_dests() - RUNTIME_FLAGS - set(FLAG_MAP)
    assert not unmapped, (
        f"train.py flags with no FLAG_MAP entry — add them to "
        f"repro.core.engine.FLAG_MAP (config knob) or RUNTIME_FLAGS "
        f"(runtime input): {sorted(unmapped)}")


def test_every_mapping_has_a_flag():
    missing = set(FLAG_MAP) - parser_dests()
    assert not missing, (
        f"FLAG_MAP entries with no matching train.py flag: "
        f"{sorted(missing)}")


def test_runtime_flags_do_not_overlap_flag_map():
    both = RUNTIME_FLAGS & set(FLAG_MAP)
    assert not both, f"flags claimed as both runtime and config: {both}"


@pytest.mark.parametrize(
    "dest,scope,field",
    [(d, s, f) for d, (s, f) in sorted(FLAG_MAP.items())])
def test_mapping_targets_a_real_config_field(dest, scope, field):
    if scope == "engine":
        assert field in ENGINE_FIELDS, (
            f"--{dest}: EngineConfig has no field {field!r}")
    elif scope == "store":
        assert field in STORE_FIELDS, (
            f"--{dest}: StoreConfig has no field {field!r}")
    elif scope.startswith("tier:"):
        kind = scope.split(":", 1)[1]
        assert kind in _TIER_FIELDS, f"--{dest}: unknown tier kind {kind!r}"
        assert field in TIER_FIELDS, (
            f"--{dest}: TierSpec has no field {field!r}")
        assert field in _TIER_FIELDS[kind], (
            f"--{dest}: {field!r} is not a valid knob of tier kind "
            f"{kind!r}")
    else:
        pytest.fail(f"--{dest}: unknown FLAG_MAP scope {scope!r}")


def test_every_engine_field_has_a_flag():
    covered = {f for s, f in FLAG_MAP.values() if s == "engine"}
    missing = ENGINE_FIELDS - covered - {"store"}
    assert not missing, (
        f"EngineConfig fields with no train.py flag: {sorted(missing)}")


def test_every_store_field_has_a_flag():
    covered = {f for s, f in FLAG_MAP.values() if s == "store"}
    missing = STORE_FIELDS - covered - NO_FLAG_STORE
    assert not missing, (
        f"StoreConfig fields with no train.py flag (add a flag or "
        f"extend NO_FLAG_STORE with a reason): {sorted(missing)}")


def test_every_tier_field_has_a_flag():
    covered = {f for s, f in FLAG_MAP.values() if s.startswith("tier:")}
    missing = TIER_FIELDS - covered - NO_FLAG_TIER
    assert not missing, (
        f"TierSpec fields with no train.py flag (add a flag or extend "
        f"NO_FLAG_TIER with a reason): {sorted(missing)}")


def test_from_args_respects_the_map():
    """End-to-end: parsed flags land on the mapped config fields."""
    ns = build_parser().parse_args(
        ["--strategy", "lowdiff_plus", "--rho", "0.05", "--lr", "0.002",
         "--ckpt-dir", "/tmp/flagsync", "--backend", "memory",
         "--memory-capacity-mb", "64", "--eviction", "lru",
         "--peers", "2", "--peer-domain", "rack1", "--peer-window", "4",
         "--retention", "3", "--format", "npz", "--maintenance", "on",
         "--host-id", "hostA"])
    cfg = EngineConfig.from_args(ns)
    assert cfg.strategy == "lowdiff_plus"
    assert cfg.rho == 0.05 and cfg.lr == 0.002
    assert cfg.maintenance is True
    sc = cfg.store
    assert sc.root == "/tmp/flagsync"
    assert sc.retention_fulls == 3 and sc.fmt == "npz"
    assert sc.host_id == "hostA"
    assert [t.kind for t in sc.tiers] == ["peer", "memory", "local"]
    peer, mem, _ = sc.tiers
    assert peer.replicas == 2 and peer.domain == "rack1"
    assert peer.window == 4 and peer.simulate_peers
    assert mem.capacity_mb == 64 and mem.eviction == "lru"


def test_from_args_tolerates_partial_namespace():
    """Callers with hand-built Namespaces (examples) get defaults for
    any flag they do not set."""
    import argparse
    cfg = EngineConfig.from_args(argparse.Namespace(strategy="lowdiff"))
    assert cfg.strategy == "lowdiff"
    assert cfg.store is None
    assert cfg.full_interval == EngineConfig().full_interval
