"""Roofline machinery validation.

The segment-composed cost (per-layer lowering x trip counts) must agree
with a fully-unrolled whole-step lowering — on a single-device mesh where
both are cheap to compile. Also validates the HLO collective-byte parser
on a known collective pattern.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_stats import collective_bytes
from repro.analysis.segments import compose, normalize_cost_analysis
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.steps import make_train_step
from repro.distributed import sharding as shd
from repro.distributed.step_builder import make_sharded_train_step
from repro.launch.mesh import make_local_mesh
from repro.models import ops
from repro.models.registry import build_model


@pytest.fixture()
def small_setup():
    cfg = get_config("qwen2-1.5b").reduced().replace(
        n_layers=2, loss_chunk=64)
    model = build_model(cfg)
    shape = ShapeConfig("t", 128, 4, "train")
    mesh = make_local_mesh(1, 1)
    return model, shape, mesh


def test_composed_matches_full_unroll(small_setup):
    model, shape, mesh = small_setup
    with shd.use_mesh(mesh):
        comp = compose(model, shape)
        ops.set_analysis_unroll(True)
        try:
            step, ast, ab = make_sharded_train_step(
                model, shape, mode="lowdiff_sharded", donate=False)
            full = step.lower(ast, ab).compile().cost_analysis()
        finally:
            ops.set_analysis_unroll(False)
    composed = comp["total"]["flops"]
    full_flops = float(normalize_cost_analysis(full)["flops"])
    # the full step additionally carries the final norm + masking glue;
    # the composition carries tiny reduction probes. Require ~15%.
    assert abs(composed - full_flops) / full_flops < 0.15, (
        composed, full_flops)


def test_composed_segments_cover_step(small_setup):
    model, shape, mesh = small_setup
    with shd.use_mesh(mesh):
        comp = compose(model, shape)
    names = {s["segment"] for s in comp["segments"]}
    assert {"embed", "loss_head", "optimizer", "compress"} <= names
    assert any(n.startswith("layer") for n in names)
    assert comp["total"]["flops"] > 0
    assert comp["total"]["bytes"] > 0


def test_collective_parser_counts_allreduce():
    mesh = make_local_mesh(1, 1)  # single device: no collectives expected
    with shd.use_mesh(mesh):
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        compiled = jax.jit(lambda a: a @ a).lower(x).compile()
        stats = collective_bytes(compiled.as_text())
    assert stats.get("total", 0) == 0

    # synthetic HLO lines exercise the parser directly
    text = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
  %ag = (bf16[64]{0}, bf16[64]{0}) all-gather(bf16[32]{0} %a, bf16[32]{0} %b), dimensions={0}
  %done = f32[4]{0} all-reduce-done(f32[4]{0} %s)
"""
    stats = collective_bytes(text)
    assert stats["all-reduce"] == 128 * 256 * 4
    assert stats["all-gather"] == 64 * 2 * 2
    assert stats["total"] == stats["all-reduce"] + stats["all-gather"]


def test_decode_and_prefill_compose(small_setup):
    model, _, mesh = small_setup
    with shd.use_mesh(mesh):
        for kind, B, S in [("decode", 4, 256), ("prefill", 2, 256)]:
            comp = compose(model, ShapeConfig("x", S, B, kind))
            assert comp["total"]["flops"] > 0


def test_model_flops_ratio_sane(small_setup):
    """Useful-FLOPs ratio must be in (0, 1] for the train shape."""
    from repro.analysis.roofline import model_flops
    model, shape, mesh = small_setup
    with shd.use_mesh(mesh):
        comp = compose(model, shape)
    mf = model_flops(model.cfg, shape) / 1  # single chip
    ratio = mf / comp["total"]["flops"]
    assert 0 < ratio <= 1.2  # small models: embed/loss dominate 6ND slightly
