"""Storage-engine tests: backend parity, journal, GC, sharded recovery.

Covers the acceptance criteria of the pluggable storage subsystem:
  * save/load round-trip parity across LocalFS / MemoryTier / Sharded
  * the manifest journal appends O(1) bytes per write, compacts, and
    survives torn tails (crash mid-append)
  * chain-aware GC never deletes a blob still needed to replay the
    latest chain
  * a LowDiff run persisted through ShardedBackend recovers params/opt
    bit-identical to the same run through LocalFSBackend
"""
import json
import os

import jax
import numpy as np
import pytest

import ml_dtypes

from repro.checkpoint import make_store
from repro.checkpoint.backends import (LocalFSBackend, MemoryTierBackend,
                                       ShardedBackend, make_pspec_splitter)
from repro.checkpoint.store import CheckpointStore
from repro.compression.sparse import SparseGrad
from repro.configs import get_config
from repro.core.lowdiff import LowDiff
from repro.core.steps import init_state
from repro.data.synthetic import make_batch
from repro.models.registry import build_model

SEQ, BATCH = 32, 2


def sample_tree(seed: int = 0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.normal(size=(48, 260)).astype(np.float32),
        "bf16": rng.normal(size=(1024,)).astype(ml_dtypes.bfloat16),
        "ints": np.arange(11, dtype=np.int32),
        "sparse": SparseGrad(
            values=np.float32(rng.normal(size=(4, 10))),
            indices=np.int32(rng.integers(0, 1024, size=(4, 10))),
            shape=(4096,), block=1024),
        "nested": {"a": [np.float32(1.5), (2, 3)], "b": None,
                   "c": "label", "d": True},
    }


def assert_tree_identical(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if isinstance(x, (np.ndarray, jax.Array)) or hasattr(x, "dtype"):
            x, y = np.asarray(x), np.asarray(y)
            assert x.dtype == y.dtype
            np.testing.assert_array_equal(x, y)
        else:
            assert x == y


def make_backend_for(tmp_path, name):
    root = str(tmp_path / name)
    if name == "local":
        return LocalFSBackend(root)
    if name == "memory":
        return MemoryTierBackend()  # pure RAM tier
    if name == "memory_spill":
        return MemoryTierBackend(LocalFSBackend(root))
    if name == "sharded":
        return ShardedBackend(root, num_shards=3)
    raise ValueError(name)


# --------------------------------------------------------------------------
# backend round-trip parity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["local", "memory", "memory_spill",
                                  "sharded"])
def test_backend_roundtrip_parity(tmp_path, name):
    be = make_backend_for(tmp_path, name)
    tree = sample_tree()
    n = be.put("full_00000001", tree)
    assert n > 0
    be.flush()
    assert be.exists("full_00000001")
    assert "full_00000001" in be.keys()
    assert_tree_identical(tree, be.get("full_00000001"))
    be.delete("full_00000001")
    assert not be.exists("full_00000001")
    be.close()


def test_sharded_splits_across_shard_dirs(tmp_path):
    root = str(tmp_path / "sh")
    be = ShardedBackend(root, num_shards=3, split_threshold_bytes=1024)
    tree = sample_tree()
    be.put("full_00000007", tree)
    shard_files = [os.path.join(root, d, "full_00000007.ckpt")
                   for d in sorted(os.listdir(root)) if d.startswith("shard_")]
    present = [p for p in shard_files if os.path.exists(p)]
    assert len(present) >= 2          # leaves genuinely spread over shards
    # large leaves are split: the 48x260 f32 leaf exceeds the threshold
    meta = json.load(open(os.path.join(root, "full_00000007.meta.json")))
    kinds = {p["kind"] for p in meta["placements"]}
    assert "split" in kinds and "whole" in kinds
    assert_tree_identical(tree, be.get("full_00000007"))
    be.close()


def test_memory_tier_capacity_requires_lower():
    """A byte-capacity without a spill target would silently drop
    checkpoints the manifest still references — rejected up front."""
    with pytest.raises(ValueError, match="lower backend"):
        MemoryTierBackend(capacity_bytes=1024)


def test_memory_tier_owns_its_bytes(tmp_path):
    """put() snapshots: mutating the caller's leaves afterwards must not
    alter the RAM copy, the spilled disk copy, or a previously-returned
    get() tree (snapshot semantics on both ends)."""
    root = str(tmp_path / "own")
    be = MemoryTierBackend(LocalFSBackend(root))
    a = np.arange(4, dtype=np.float32)
    be.put("k", {"p": a})
    be.flush()
    a += 100.0                         # caller mutates after the put
    got = be.get("k")
    np.testing.assert_array_equal(got["p"], np.arange(4, dtype=np.float32))
    got["p"] += 7.0                    # caller mutates a recovered tree
    np.testing.assert_array_equal(be.get("k")["p"],
                                  np.arange(4, dtype=np.float32))
    np.testing.assert_array_equal(    # disk copy matches the RAM copy
        LocalFSBackend(root).get("k")["p"], np.arange(4, dtype=np.float32))
    be.close()


def test_memory_tier_spill_evict_and_reload(tmp_path):
    root = str(tmp_path / "mt")
    cap = 64 * 1024
    be = MemoryTierBackend(LocalFSBackend(root), capacity_bytes=cap)
    trees = {f"full_{i:08d}": sample_tree(seed=i) for i in range(6)}
    for k, t in trees.items():
        be.put(k, t)
    be.flush()
    assert be.evictions > 0            # capacity forced spills out of RAM
    st = be.stats()
    assert st["resident_bytes"] <= cap
    for k, t in trees.items():         # evicted blobs come back from lower
        assert_tree_identical(t, be.get(k))
    be.close()
    # a fresh LocalFS store over the same root sees every spilled blob
    reload_be = LocalFSBackend(root)
    for k, t in trees.items():
        assert_tree_identical(t, reload_be.get(k))


# --------------------------------------------------------------------------
# manifest journal
# --------------------------------------------------------------------------

def test_journal_appends_o1_bytes_per_write(tmp_path):
    store = make_store(str(tmp_path / "j"), compact_every=10_000)
    payload = {"g": np.zeros(64, np.float32)}
    sizes = []
    for step in range(10, 60):
        store.save_diff(step, payload)
        sizes.append(store.journal.log_bytes())
    deltas = np.diff([0] + sizes)
    # O(1) appended bytes per write: every delta is a single bounded
    # journal line, independent of how many records precede it.
    assert deltas.max() <= deltas.min() + 16
    assert deltas.max() < 400
    # and therefore total growth is linear, not quadratic
    assert sizes[-1] <= deltas.max() * len(sizes)
    store.close()


def test_journal_compaction_and_reload(tmp_path):
    root = str(tmp_path / "c")
    store = make_store(root, compact_every=8)
    for step in range(1, 21):
        store.save_diff(step, {"g": np.zeros(8, np.float32)})
    store.save_full(20, sample_tree())
    assert store.journal.stats()["compactions"] >= 2
    manifest_before = json.loads(json.dumps(store.manifest))
    store.close()
    snap = json.load(open(os.path.join(root, "manifest.json")))
    assert "__seq__" in snap
    reopened = CheckpointStore(root)
    assert reopened.manifest == manifest_before
    assert reopened.latest_full()["step"] == 20
    reopened.close()


def test_journal_torn_write_recovery(tmp_path):
    root = str(tmp_path / "t")
    store = make_store(root, compact_every=10_000)
    tree = sample_tree()
    store.save_full(4, tree)
    for step in (5, 6, 7):
        store.save_diff(step, {"g": np.zeros(8, np.float32)})
    store.close()
    # crash mid-append: the last journal line is torn
    with open(os.path.join(root, "manifest.log"), "a") as f:
        f.write('{"seq": 99, "op": "add", "kind": "diffs", "en')
    reopened = CheckpointStore(root)
    assert [e["step"] for e in reopened.manifest["diffs"]] == [5, 6, 7]
    assert reopened.latest_full()["step"] == 4
    assert_tree_identical(tree, reopened.load_full(reopened.latest_full()))
    # the store keeps working after recovery (journal seq resumes safely)
    reopened.save_diff(8, {"g": np.zeros(8, np.float32)})
    assert [s for s, _ in reopened.diffs_after(7)] == [8]
    reopened.close()
    # second restart: the torn fragment must not have merged with the
    # post-recovery append — every record survives another reload
    again = CheckpointStore(root)
    assert [e["step"] for e in again.manifest["diffs"]] == [5, 6, 7, 8]
    assert again.latest_full()["step"] == 4
    again.close()


# --------------------------------------------------------------------------
# garbage collection
# --------------------------------------------------------------------------

def test_gc_keeps_latest_chain_replayable(tmp_path):
    store = make_store(str(tmp_path / "g"))
    pay = lambda s: {"g": np.full(8, float(s), np.float32)}  # noqa: E731
    # chain: full@3, batch[4..6] straddling full@5, diffs 7,8, full@8
    store.save_full(3, sample_tree(1))
    store.save_batch(4, 6, [pay(4), pay(5), pay(6)])
    store.save_full(5, sample_tree(2))
    store.save_diff(7, pay(7))
    store.save_diff(8, pay(8))
    store.save_full(8, sample_tree(3))
    removed = store.gc(retention_fulls=2)
    # cutoff is full@5: full@3 goes; the batch STRADDLES the cutoff
    # (last=6 > 5) so it must survive; diffs 7,8 survive.
    assert removed == {"fulls": 1, "diffs": 0, "batches": 0}
    assert [e["step"] for e in store.manifest["fulls"]] == [5, 8]
    replay = store.diffs_after(5)
    assert [s for s, _ in replay] == [6, 7, 8]
    for s, p in replay:
        np.testing.assert_array_equal(p["g"], pay(s)["g"])
    # retention=1: chain from full@8 needs nothing older
    removed = store.gc(retention_fulls=1)
    assert removed["fulls"] == 1 and removed["batches"] == 1
    assert removed["diffs"] == 2
    assert store.diffs_after(8) == []
    assert store.latest_full()["step"] == 8
    store.close()


def test_auto_gc_on_save_full(tmp_path):
    store = make_store(str(tmp_path / "ag"), retention_fulls=2)
    for step in (4, 8, 12, 16):
        for d in range(step - 3, step):
            store.save_diff(d, {"g": np.zeros(4, np.float32)})
        store.save_full(step, sample_tree())
    assert [e["step"] for e in store.manifest["fulls"]] == [12, 16]
    # every blob the manifest references still exists on the backend
    for kind in ("fulls", "diffs", "batches"):
        for e in store.manifest[kind]:
            assert store.backend.exists(e["key"])
    store.close()


def test_gc_explicit_zero_disables_collection(tmp_path):
    store = make_store(str(tmp_path / "g0"), retention_fulls=2)
    store.retention_fulls = 0  # no auto-GC while seeding
    for step in (2, 4, 6):
        store.save_full(step, sample_tree())
    assert store.gc(retention_fulls=0) == {}     # explicit 0 = never collect
    assert len(store.manifest["fulls"]) == 3
    store.close()


def test_sharded_delete_survives_shard_count_change(tmp_path):
    root = str(tmp_path / "sc")
    be = ShardedBackend(root, num_shards=4, split_threshold_bytes=1024)
    be.put("full_00000001", sample_tree())
    be.close()
    be2 = ShardedBackend(root, num_shards=2)
    be2.delete("full_00000001")
    leftovers = [os.path.join(d, f) for d in os.listdir(root)
                 if d.startswith("shard_")
                 for f in os.listdir(os.path.join(root, d))]
    assert leftovers == []            # no orphaned pieces in shard_002/003
    be2.close()


def test_reopen_prunes_blobs_lost_before_writeback(tmp_path):
    """Crash between the journal append and an async tier's write-back:
    the reopened store must fall back to the previous durable full."""
    root = str(tmp_path / "pw")
    store = make_store(root)
    tree = sample_tree(1)
    store.save_full(4, tree)
    store.save_full(8, sample_tree(2))
    store.save_diff(9, {"g": np.zeros(4, np.float32)})
    store.close()
    # simulate the suffix of writes never landing on disk
    os.unlink(os.path.join(root, "full_00000008.ckpt"))
    os.unlink(os.path.join(root, "diff_00000009.ckpt"))
    reopened = make_store(root)
    assert reopened.latest_full()["step"] == 4
    assert_tree_identical(tree, reopened.load_full(reopened.latest_full()))
    assert reopened.diffs_after(4) == []
    reopened.close()


def test_gc_removes_legacy_path_only_entries(tmp_path):
    """Seed-format manifests carry 'path' but no 'key'; GC must still be
    able to delete those entries (journal matches by derived key)."""
    root = str(tmp_path / "legacy")
    be = LocalFSBackend(root)
    for step in (2, 6):
        be.put(f"full_{step:08d}", sample_tree(step))
    legacy = {"fulls": [{"step": s, "path": os.path.join(
        root, f"full_{s:08d}.npz"), "bytes": 1} for s in (2, 6)],
        "diffs": [], "batches": []}
    with open(os.path.join(root, "manifest.json"), "w") as f:
        json.dump(legacy, f)
    store = CheckpointStore(root)
    assert [e["step"] for e in store.manifest["fulls"]] == [2, 6]
    removed = store.gc(retention_fulls=1)
    assert removed["fulls"] == 1
    assert [e["step"] for e in store.manifest["fulls"]] == [6]
    assert not store.backend.exists("full_00000002")
    store.close()


def test_pspec_splitter_follows_mesh(tmp_path):
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_local_mesh
    splitter = make_pspec_splitter({(8, 64): ("embed", "mlp")})
    with shd.use_mesh(make_local_mesh(1, 1)):
        # 'mlp' maps to the physical 'model' axis -> split axis 1, even
        # though axis 1 is the larger dim anyway; check against a shape
        # where spec and largest-dim disagree:
        splitter2 = make_pspec_splitter({(128, 16): (None, "mlp")})
        assert splitter2(np.zeros((128, 16), np.float32)) == 1
        assert splitter(np.zeros((8, 64), np.float32)) == 1
    # without a mesh: falls back to the largest dimension
    assert splitter2(np.zeros((128, 16), np.float32)) == 0


# --------------------------------------------------------------------------
# durability: atomic_write fsyncs the parent directory
# --------------------------------------------------------------------------

def test_atomic_write_fsyncs_parent_dir(tmp_path, monkeypatch):
    """os.replace only becomes durable once the parent directory entry
    is fsynced; a crash right after the rename must not lose it."""
    import stat

    from repro.checkpoint import io as cio
    real_fsync = os.fsync
    dir_fsyncs = []

    def spy(fd):
        if stat.S_ISDIR(os.fstat(fd).st_mode):
            dir_fsyncs.append(fd)
        return real_fsync(fd)

    monkeypatch.setattr(os, "fsync", spy)
    target = str(tmp_path / "sub" / "blob.bin")
    cio.atomic_write(target, lambda f: f.write(b"payload"))
    assert dir_fsyncs, "parent directory was not fsynced after os.replace"
    assert open(target, "rb").read() == b"payload"


# --------------------------------------------------------------------------
# chain-aware memory-tier eviction (satellite: newest chain stays in RAM)
# --------------------------------------------------------------------------

def test_memory_tier_never_evicts_newest_chain(tmp_path):
    """FIFO eviction must skip every blob of the newest full's replay
    chain: with the tier full well past capacity, recovery of the
    latest chain still runs entirely from RAM (proven by deleting the
    lower tier's blob files before recovering)."""
    from repro.core import recovery as recmod
    low_root = str(tmp_path / "low")
    be = MemoryTierBackend(LocalFSBackend(low_root),
                           capacity_bytes=48 * 1024)
    store = CheckpointStore(backend=be)
    pay = lambda s: {"g": np.full(4096, float(s), np.float32)}  # noqa: E731
    # old chain (evictable) then the newest chain, ~16KB per blob:
    # 5 protected blobs > 48KB capacity, so only old blobs may go
    store.save_full(2, {"params": pay(2), "step": np.int32(2)})
    for s in (3, 4):
        store.save_diff(s, pay(s))
    store.save_full(5, {"params": pay(5), "step": np.int32(5)})
    for s in (6, 7, 8, 9):
        store.save_diff(s, pay(s))
    store.flush()
    chain = {"full_00000005"} | {f"diff_{s:08d}" for s in (6, 7, 8, 9)}
    with be._lock:
        resident = set(be._mem)
    assert chain <= resident, f"chain blob evicted: {chain - resident}"
    assert be.evictions > 0            # old-chain blobs did get evicted
    assert be.stats()["evictions_skipped"] >= 0
    # recovery survives a full memory tier: even with every lower-tier
    # blob file gone, the protected chain is served from RAM
    for f in os.listdir(low_root):
        if f.endswith((".ckpt", ".npz")):
            os.unlink(os.path.join(low_root, f))
    state, diffs = recmod.load_latest_chain(store)
    assert int(state["step"]) == 5
    assert [s for s, _ in diffs] == [6, 7, 8, 9]
    for s, p in diffs:
        np.testing.assert_array_equal(p["g"], pay(s)["g"])
    store.close()


def test_memory_tier_protect_is_advisory_for_capacity(tmp_path):
    """Protected blobs may push the tier over its soft capacity, but
    unprotected blobs are still evicted down to the bound."""
    be = MemoryTierBackend(LocalFSBackend(str(tmp_path / "l")),
                           capacity_bytes=8 * 1024)
    store = CheckpointStore(backend=be)
    store.save_full(1, {"params": np.zeros(4096, np.float32)})  # 16KB > cap
    store.save_full(2, {"params": np.zeros(4096, np.float32)})
    store.flush()
    with be._lock:
        resident = set(be._mem)
    # newest full protected even though it alone exceeds capacity;
    # the superseded full was evicted to honor the bound
    assert "full_00000002" in resident
    assert "full_00000001" not in resident
    store.close()


# --------------------------------------------------------------------------
# diffs_after efficiency (satellite: skip non-overlapping batches)
# --------------------------------------------------------------------------

class CountingBackend(LocalFSBackend):
    def __init__(self, root):
        super().__init__(root)
        self.gets = 0

    def get(self, key):
        self.gets += 1
        return super().get(key)


def test_diffs_after_skips_nonoverlapping_batches(tmp_path):
    be = CountingBackend(str(tmp_path / "cb"))
    store = CheckpointStore(backend=be)
    pay = {"g": np.zeros(4, np.float32)}
    store.save_batch(1, 4, [pay] * 4)
    store.save_batch(5, 8, [pay] * 4)
    store.save_batch(9, 12, [pay] * 4)
    be.gets = 0
    out = store.diffs_after(8)
    assert [s for s, _ in out] == [9, 10, 11, 12]
    assert be.gets == 1                # only the overlapping batch loaded
    store.close()


# --------------------------------------------------------------------------
# LowDiff end-to-end across backends: bit-identical recovery
# --------------------------------------------------------------------------

def run_lowdiff(store):
    model = build_model(get_config("qwen2-1.5b").reduced())
    ld = LowDiff(model, store, rho=0.05, lr=1e-3, full_interval=4,
                 batch_size=2, parallel_recovery=False)
    state = init_state(model, jax.random.PRNGKey(0), mode="lowdiff")
    for t in range(9):
        state, _ = ld.train_step(state, make_batch(model.cfg, SEQ, BATCH,
                                                   step=t))
    ld.flush()
    rec, n = ld.recover()
    ld.close()
    return state, rec, n


@pytest.mark.parametrize("name", ["memory_spill", "sharded"])
def test_lowdiff_backend_recovery_bit_identical_to_local(tmp_path, name):
    """The same deterministic run persisted through another backend must
    recover the exact bytes LocalFS recovers (acceptance criterion)."""
    local_store = CheckpointStore(
        backend=LocalFSBackend(str(tmp_path / "ld_local")))
    live_a, rec_a, n_a = run_lowdiff(local_store)
    other_store = CheckpointStore(
        backend=make_backend_for(tmp_path / "ld", name))
    live_b, rec_b, n_b = run_lowdiff(other_store)
    assert n_a == n_b
    assert int(rec_a["step"]) == int(rec_b["step"]) == 9
    assert_tree_identical(live_a["params"], live_b["params"])
    assert_tree_identical(rec_a["params"], rec_b["params"])
    assert_tree_identical(rec_a["opt"].mu, rec_b["opt"].mu)
    assert_tree_identical(rec_a["opt"].nu, rec_b["opt"].nu)
