"""Remote object-store tier tests.

Covers the acceptance criteria of the remote subsystem:
  * chunked put/get round-trip (bit-identical, multi-chunk)
  * per-chunk checksum verification with re-fetch of corrupted chunks
  * bounded retry with exhaustion raising instead of looping
  * commit-point semantics: crash before write-back leaves no index and
    the reopened store prunes the dangling manifest entry
  * a LowDiff run through MemoryTierBackend(RemoteObjectBackend(...))
    with injected transient faults recovers params/opt bit-identical to
    the same run through LocalFSBackend
"""
import os
import time

import jax
import numpy as np
import pytest

import ml_dtypes

from repro.checkpoint import make_store
from repro.checkpoint.backends import (LocalFSBackend, MemoryTierBackend,
                                       make_backend)
from repro.checkpoint.remote import (ChecksumError, FakeObjectStore,
                                     FaultInjector, FilesystemObjectStore,
                                     RemoteObjectBackend,
                                     RetryExhaustedError, TransientStoreError,
                                     _FAKE_BUCKETS, make_remote_backend)
from repro.checkpoint.store import CheckpointStore
from repro.compression.sparse import SparseGrad
from repro.configs import get_config
from repro.core.lowdiff import LowDiff
from repro.core.recovery import load_latest_chain
from repro.core.steps import init_state
from repro.data.synthetic import make_batch
from repro.models.registry import build_model

SEQ, BATCH = 32, 2


def sample_tree(seed: int = 0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.normal(size=(48, 260)).astype(np.float32),
        "bf16": rng.normal(size=(1024,)).astype(ml_dtypes.bfloat16),
        "ints": np.arange(11, dtype=np.int32),
        "sparse": SparseGrad(
            values=np.float32(rng.normal(size=(4, 10))),
            indices=np.int32(rng.integers(0, 1024, size=(4, 10))),
            shape=(4096,), block=1024),
        "nested": {"a": [np.float32(1.5), (2, 3)], "b": None,
                   "c": "label", "d": True},
    }


def assert_tree_identical(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if isinstance(x, (np.ndarray, jax.Array)) or hasattr(x, "dtype"):
            x, y = np.asarray(x), np.asarray(y)
            assert x.dtype == y.dtype
            np.testing.assert_array_equal(x, y)
        else:
            assert x == y


def fast_backend(store, **kw):
    kw.setdefault("backoff_s", 1e-4)
    return RemoteObjectBackend(store, **kw)


# --------------------------------------------------------------------------
# chunk round-trip
# --------------------------------------------------------------------------

@pytest.mark.parametrize("chunk_bytes", [1 << 10, 1 << 22])
def test_remote_chunked_roundtrip(chunk_bytes):
    be = fast_backend(FakeObjectStore(), chunk_bytes=chunk_bytes)
    tree = sample_tree()
    n = be.put("full_00000001", tree)
    assert n > 0
    n_chunks = sum(1 for o in be.store.list_objects()
                   if o.endswith(".chunk"))
    if chunk_bytes == 1 << 10:
        assert n_chunks > 1            # genuinely split into chunks
    else:
        assert n_chunks == 1
    assert be.exists("full_00000001")
    assert be.keys() == ["full_00000001"]
    assert_tree_identical(tree, be.get("full_00000001"))
    be.delete("full_00000001")
    assert not be.exists("full_00000001")
    assert be.store.list_objects() == []   # chunks swept with the index


def test_filesystem_object_store_roundtrip(tmp_path):
    be = fast_backend(FilesystemObjectStore(str(tmp_path / "bucket")),
                      chunk_bytes=2048)
    tree = sample_tree()
    be.put("full_00000003", tree)
    assert_tree_identical(tree, be.get("full_00000003"))
    # a second client over the same directory sees the same objects
    be2 = fast_backend(FilesystemObjectStore(str(tmp_path / "bucket")))
    assert be2.keys() == ["full_00000003"]
    assert_tree_identical(tree, be2.get("full_00000003"))


# --------------------------------------------------------------------------
# checksums and retries
# --------------------------------------------------------------------------

def test_checksum_mismatch_refetches():
    """A chunk corrupted in flight fails sha256 verification and is
    re-fetched; the caller sees clean bytes."""
    store = FakeObjectStore(FaultInjector(flip_gets=3))
    be = fast_backend(store, chunk_bytes=512)
    tree = sample_tree()
    be.put("k", tree)
    assert_tree_identical(tree, be.get("k"))
    assert be.checksum_failures >= 1
    assert be.retries >= 1


def test_transient_put_drops_are_retried():
    store = FakeObjectStore(FaultInjector(drop_puts=2))
    be = fast_backend(store, chunk_bytes=1 << 20)
    tree = sample_tree()
    be.put("k", tree)
    assert be.retries == 2
    assert_tree_identical(tree, be.get("k"))


def test_retry_exhaustion_raises():
    store = FakeObjectStore()
    be = fast_backend(store, max_retries=2)
    be.put("k", sample_tree())
    store.faults = FaultInjector(drop_gets=50)
    with pytest.raises(RetryExhaustedError):
        be.get("k")
    store.faults = FaultInjector(drop_puts=50)
    with pytest.raises(RetryExhaustedError):
        be.put("k2", sample_tree())


def test_checksum_error_is_transient():
    """ChecksumError must be caught by the retry loop (it subclasses
    TransientStoreError), and surface as RetryExhaustedError only when
    every re-fetch stays corrupt."""
    store = FakeObjectStore()
    be = fast_backend(store, max_retries=1, chunk_bytes=1 << 20)
    be.put("k", sample_tree())
    store.faults = FaultInjector(flip_gets=50)   # every fetch corrupt
    with pytest.raises(RetryExhaustedError) as ei:
        be.get("k")
    assert isinstance(ei.value.__cause__, ChecksumError)


def test_missing_key_is_not_retried():
    be = fast_backend(FakeObjectStore(), max_retries=5)
    with pytest.raises(FileNotFoundError):
        be.get("absent")
    assert be.retries == 0             # absence is permanent, not transient


def test_exists_retries_transient_faults():
    """exists() must retry a flaky wire rather than mis-report a
    reachable blob as missing — _prune_missing would otherwise drop
    live chain entries on reopen."""
    class FlakyHead(FakeObjectStore):
        def __init__(self):
            super().__init__()
            self.head_faults = 2

        def has_object(self, name):
            if self.head_faults > 0:
                self.head_faults -= 1
                raise TransientStoreError("head dropped")
            return super().has_object(name)

    store = FlakyHead()
    be = fast_backend(store)
    be.put("k", sample_tree())
    store.head_faults = 2
    assert be.exists("k") is True      # survived the two dropped HEADs
    store.head_faults = 2
    assert be.exists("absent") is False


# --------------------------------------------------------------------------
# factory / URL wiring
# --------------------------------------------------------------------------

def test_make_backend_remote_layers_memory_tier(tmp_path):
    be = make_backend("remote", str(tmp_path / "r"),
                      remote_url="fake://wiring-test", chunk_mb=0.01)
    assert isinstance(be, MemoryTierBackend)
    assert isinstance(be.lower, RemoteObjectBackend)
    tree = sample_tree()
    be.put("full_00000001", tree)
    be.flush()
    # the blob landed on the remote tier, not just in RAM
    assert be.lower.exists("full_00000001")
    assert_tree_identical(tree, be.lower.get("full_00000001"))
    be.close()
    _FAKE_BUCKETS.pop("wiring-test", None)


def test_make_remote_backend_rejects_unknown_scheme():
    with pytest.raises(ValueError, match="scheme"):
        make_remote_backend("s3://bucket")
    with pytest.raises(ValueError, match="scheme"):
        make_remote_backend("not-a-url")


def test_fake_buckets_shared_within_process():
    a = make_remote_backend("fake://shared-bucket")
    b = make_remote_backend("fake://shared-bucket")
    a.put("k", {"x": np.arange(3)})
    assert b.exists("k")
    _FAKE_BUCKETS.pop("shared-bucket", None)


def test_fake_bucket_fault_config_not_stale():
    """A cached fake bucket must take the *latest* caller's fault
    configuration: first use without faults, then with, then without."""
    a = make_remote_backend("fake://fault-cfg")           # no faults
    assert a.store.faults is None
    b = make_remote_backend("fake://fault-cfg", fault_rate=1.0)
    assert a.store is b.store and b.store.faults is not None
    c = make_remote_backend("fake://fault-cfg")           # detaches again
    assert c.store.faults is None
    _FAKE_BUCKETS.pop("fault-cfg", None)


# --------------------------------------------------------------------------
# commit point + crash recovery
# --------------------------------------------------------------------------

def test_crash_before_writeback_pruned_on_reopen(tmp_path):
    """Journal records a full whose async write-back never landed on the
    object store (crash): the reopened store must fall back to the
    previous durable full via _prune_missing."""
    root = str(tmp_path / "crash")
    store = make_store(root, backend="remote", chunk_mb=0.01)
    tree = sample_tree(1)
    store.save_full(4, tree)
    store.save_full(8, sample_tree(2))
    store.save_diff(9, {"g": np.zeros(4, np.float32)})
    store.flush()
    store.journal.close()   # journal survives; skip close() (= flush)
    # simulate the write-back suffix never landing: remove the remote
    # objects for full@8 and diff@9 (index first = commit point gone)
    remote = store.backend.lower
    remote.delete("full_00000008")
    remote.delete("diff_00000009")
    reopened = make_store(root, backend="remote", chunk_mb=0.01)
    assert reopened.latest_full()["step"] == 4
    assert_tree_identical(tree, reopened.load_full(reopened.latest_full()))
    assert reopened.diffs_after(4) == []
    reopened.close()


def test_reput_crash_preserves_previous_version(tmp_path):
    """Chunks are generation-prefixed: a re-put that crashes before its
    index commit must leave the previously committed version fully
    readable (the old failure mode: overwritten chunks under the old
    index -> permanent ChecksumError)."""
    fake = FakeObjectStore()
    be = fast_backend(fake, chunk_bytes=512)
    tree1 = sample_tree(1)
    be.put("k", tree1)

    orig = fake.put_object

    def crash_on_index(name, data):
        if name.endswith("index.json"):
            raise KeyboardInterrupt()  # hard crash mid-re-put
        orig(name, data)

    fake.put_object = crash_on_index
    with pytest.raises(KeyboardInterrupt):
        be.put("k", sample_tree(2))
    fake.put_object = orig
    assert_tree_identical(tree1, be.get("k"))   # old version intact

    # a successful re-put supersedes AND sweeps the stale generation
    tree3 = sample_tree(3)
    be.put("k", tree3)
    assert_tree_identical(tree3, be.get("k"))
    gens = {n.split("/")[1].split(".")[0]
            for n in fake.list_objects("k/") if n.endswith(".chunk")}
    assert len(gens) == 1              # only the live generation remains


def test_memory_tier_flush_surfaces_writeback_failure(tmp_path):
    """A failed async write-back must raise from flush() even after
    _prune_done has reaped the future — silently dropping it would
    leave a hole in the middle of the journal-referenced chain."""
    class FailingLower(LocalFSBackend):
        fail_keys = frozenset()

        def put(self, key, obj):
            if key in self.fail_keys:
                raise RetryExhaustedError(f"remote down for {key}")
            return super().put(key, obj)

    lower = FailingLower(str(tmp_path / "low"))
    lower.fail_keys = frozenset({"k1"})
    be = MemoryTierBackend(lower)
    be.put("k1", sample_tree(1))
    deadline = time.monotonic() + 10.0
    while be._inflight["k1"].done() is False:   # let the spill fail
        assert time.monotonic() < deadline
        time.sleep(0.01)
    be.put("k2", sample_tree(2))       # reaps k1's future via _prune_done
    with pytest.raises(RuntimeError, match="write-back"):
        be.flush()
    assert be.stats()["writeback_errors"] == 1


def test_interrupted_upload_leaves_no_index(tmp_path):
    """A crash mid-upload (chunks written, index not) must leave the key
    invisible: exists() false, keys() empty, get() FileNotFoundError."""
    fs = FilesystemObjectStore(str(tmp_path / "b"))
    be = fast_backend(fs, chunk_bytes=256)

    class Boom(Exception):
        pass

    orig = fs.put_object
    calls = []

    def failing_put(name, data):
        if name.endswith("index.json"):
            raise Boom()               # die right before the commit point
        calls.append(name)
        orig(name, data)

    fs.put_object = failing_put
    with pytest.raises(Boom):          # non-transient: propagates as-is
        be.put("full_00000001", sample_tree())
    fs.put_object = orig
    assert len(calls) >= 1             # chunks did land
    assert not be.exists("full_00000001")
    assert be.keys() == []
    with pytest.raises(FileNotFoundError):
        be.get("full_00000001")


def test_load_latest_chain_falls_back_to_older_full(tmp_path):
    """A newest full whose remote blob is gone must not abort recovery:
    the chain loader falls back to the previous full."""
    fake = FakeObjectStore()
    be = MemoryTierBackend(fast_backend(fake, chunk_bytes=4096))
    store = CheckpointStore(backend=be)
    tree = sample_tree(3)
    store.save_full(4, tree)
    store.save_diff(5, {"g": np.full(4, 5.0, np.float32)})
    store.save_full(6, sample_tree(4))
    store.flush()
    # newest full vanishes from the bucket AND from the RAM tier
    be.delete("full_00000006")
    state, diffs = load_latest_chain(store)
    assert_tree_identical(tree, state)
    assert [s for s, _ in diffs] == [5]
    store.close()


# --------------------------------------------------------------------------
# acceptance: faulted remote run bit-identical to LocalFS
# --------------------------------------------------------------------------

def run_lowdiff(store):
    model = build_model(get_config("qwen2-1.5b").reduced())
    ld = LowDiff(model, store, rho=0.05, lr=1e-3, full_interval=4,
                 batch_size=2, parallel_recovery=False)
    state = init_state(model, jax.random.PRNGKey(0), mode="lowdiff")
    for t in range(9):
        state, _ = ld.train_step(state, make_batch(model.cfg, SEQ, BATCH,
                                                   step=t))
    ld.flush()
    rec, n = ld.recover()
    ld.close()
    return state, rec, n


def test_lowdiff_faulted_remote_recovery_bit_identical(tmp_path):
    """The acceptance criterion: LowDiff through
    MemoryTierBackend(RemoteObjectBackend(...)) with injected transient
    faults (dropped chunks on both directions, checksum flips) recovers
    params/opt bit-identical to a LocalFSBackend run."""
    local_store = CheckpointStore(
        backend=LocalFSBackend(str(tmp_path / "local")))
    live_a, rec_a, n_a = run_lowdiff(local_store)

    faults = FaultInjector(drop_puts=3, drop_gets=3, flip_gets=3, rate=0.02,
                           seed=11)
    remote = fast_backend(FakeObjectStore(faults), chunk_bytes=1 << 16)
    remote_store = CheckpointStore(backend=MemoryTierBackend(remote))
    live_b, rec_b, n_b = run_lowdiff(remote_store)

    assert faults.injected > 0         # the run really was faulted
    assert remote.retries > 0          # and the backend really retried
    assert n_a == n_b
    assert int(rec_a["step"]) == int(rec_b["step"]) == 9
    assert_tree_identical(live_a["params"], live_b["params"])
    assert_tree_identical(rec_a["params"], rec_b["params"])
    assert_tree_identical(rec_a["opt"].mu, rec_b["opt"].mu)
    assert_tree_identical(rec_a["opt"].nu, rec_b["opt"].nu)
