"""End-to-end tests of the LowDiff / LowDiff+ core (the paper's system)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional property-testing dep; never hard-fail collection
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.checkpoint.store import CheckpointStore
from repro.configs import get_config
from repro.core import config_opt as co
from repro.core.baselines import CheckFreq, FullSync, Gemini, NaiveDC
from repro.core.lowdiff import LowDiff
from repro.core.lowdiff_plus import LowDiffPlus
from repro.core.reusing_queue import ReusingQueue
from repro.core.steps import init_state, make_train_step
from repro.data.synthetic import make_batch
from repro.models.registry import build_model

SEQ, BATCH = 32, 2


def tiny_model():
    return build_model(get_config("qwen2-1.5b").reduced())


def assert_trees_close(a, b, atol=0.0, rtol=0.0):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32),
                                   atol=atol, rtol=rtol)


# --------------------------------------------------------------------------
# configuration optimization (Eq. 8-10, Table I)
# --------------------------------------------------------------------------

def test_closed_form_matches_grid():
    p = co.SystemParams(N=8, M=1800, W=5e9, S=8.7e9, T=1e5, R_F=5, R_D=0.4)
    f_star, b_star = co.optimal_config(p)
    f_g, b_g, _ = co.grid_verify(p)
    assert abs(np.log(f_star / f_g)) < 0.05
    assert abs(np.log(b_star / b_g)) < 0.05


def _stationary_body(M, W, S, R_D):
    """(f*, b*) zeroes both partial derivatives of Eq. (8)."""
    p = co.SystemParams(M=M, W=W, S=S, R_D=R_D)
    f, b = co.optimal_config(p)
    epsf, epsb = f * 1e-4, b * 1e-4
    dfd = (co.wasted_time(f + epsf, b, p) - co.wasted_time(f - epsf, b, p))
    dbd = (co.wasted_time(f, b + epsb, p) - co.wasted_time(f, b - epsb, p))
    w0 = co.wasted_time(f, b, p)
    assert abs(dfd) / w0 < 1e-4
    assert abs(dbd) / w0 < 1e-4


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(M=st.floats(100, 1e5), W=st.floats(1e8, 1e11),
           S=st.floats(1e7, 1e11), R_D=st.floats(0.01, 10))
    def test_closed_form_is_stationary(M, W, S, R_D):
        _stationary_body(M, W, S, R_D)
else:
    @pytest.mark.parametrize("M,W,S,R_D", [
        (1800.0, 5e9, 8.7e9, 0.4), (3600.0, 1e10, 1.4e9, 0.3),
        (500.0, 2e8, 5e7, 2.0)])
    def test_closed_form_is_stationary(M, W, S, R_D):
        _stationary_body(M, W, S, R_D)


def test_table1_shape():
    """Wasted time is U-shaped in both FCF and BS (paper Table I)."""
    p = co.SystemParams(N=8, M=3600, W=5e9, S=1.4e9, T=1e5, R_F=4, R_D=0.3)
    f_star, b_star = co.optimal_config(p)
    fs = [f_star / 8, f_star, f_star * 8]
    ws = [co.wasted_time(f, b_star, p) for f in fs]
    assert ws[1] < ws[0] and ws[1] < ws[2]
    bs = [max(b_star / 8, 1e-3), b_star, b_star * 8]
    ws = [co.wasted_time(f_star, b, p) for b in bs]
    assert ws[1] < ws[0] and ws[1] < ws[2]


# --------------------------------------------------------------------------
# reusing queue
# --------------------------------------------------------------------------

def test_queue_fifo_order():
    q = ReusingQueue(maxsize=16)
    for i in range(10):
        q.put(i, {"g": i})
    got = [q.get()[0] for _ in range(10)]
    assert got == list(range(10))
    assert q.stats()["enqueued"] == 10


# --------------------------------------------------------------------------
# LowDiff end-to-end: train -> crash -> recover == live state
# --------------------------------------------------------------------------

@pytest.fixture()
def trained_lowdiff(tmp_path):
    model = tiny_model()
    store = CheckpointStore(str(tmp_path / "ckpt"))
    ld = LowDiff(model, store, rho=0.05, lr=1e-3, full_interval=5,
                 batch_size=2, parallel_recovery=False)
    state = init_state(model, jax.random.PRNGKey(0), mode="lowdiff")
    for t in range(12):
        batch = make_batch(model.cfg, SEQ, BATCH, step=t)
        state, metrics = ld.train_step(state, batch)
    ld.flush()
    return model, store, ld, state


def test_lowdiff_store_layout(trained_lowdiff):
    _, store, ld, _ = trained_lowdiff
    s = store.stats()
    assert s["fulls"] == 2           # steps 5, 10
    assert s["batches"] >= 5         # 12 diffs in batches of 2
    assert ld.queue.stats()["enqueued"] == 12


def test_lowdiff_recovery_exact_serial(trained_lowdiff):
    model, store, ld, live = trained_lowdiff
    rec_state, n = ld.recover()
    assert n == 2                    # full@10 + diffs 11,12
    assert int(rec_state["step"]) == 12
    # identical math; tolerances only for jit-vs-eager fusion rounding
    assert_trees_close(rec_state["params"], live["params"],
                       atol=1e-8, rtol=1e-4)
    assert_trees_close(rec_state["opt"].mu, live["opt"].mu,
                       atol=1e-8, rtol=1e-4)
    assert_trees_close(rec_state["opt"].nu, live["opt"].nu,
                       atol=1e-10, rtol=1e-4)


def test_lowdiff_recovery_parallel_matches_serial(trained_lowdiff):
    model, store, ld, live = trained_lowdiff
    ld.parallel_recovery = True
    rec_state, n = ld.recover()
    assert_trees_close(rec_state["params"], live["params"],
                       atol=1e-6, rtol=1e-5)
    assert_trees_close(rec_state["opt"].mu, live["opt"].mu,
                       atol=1e-6, rtol=1e-5)


def test_lowdiff_diffs_much_smaller_than_full(trained_lowdiff):
    """Finding 2: compressed-gradient diffs << full checkpoints."""
    _, store, _, _ = trained_lowdiff
    full_bytes = store.manifest["fulls"][0]["bytes"]
    batch_bytes = np.mean([e["bytes"] for e in store.manifest["batches"]])
    per_diff = batch_bytes / 2
    assert per_diff < full_bytes / 10


# --------------------------------------------------------------------------
# LowDiff+ (non-compression mode)
# --------------------------------------------------------------------------

def test_lowdiff_plus_software_recovery(tmp_path):
    model = tiny_model()
    store = CheckpointStore(str(tmp_path / "ckpt"))
    ldp = LowDiffPlus(model, store, lr=1e-3, persist_interval=4)
    state = init_state(model, jax.random.PRNGKey(0), mode="lowdiff_plus")
    for t in range(9):
        state, _ = ldp.train_step(state, make_batch(model.cfg, SEQ, BATCH,
                                                    step=t))
    ldp.flush()
    rec = ldp.recover_software(state)
    # CPU replica applied the same dense gradients through the same Adam
    assert int(rec["step"]) == 9
    assert_trees_close(rec["params"], state["params"], atol=2e-6, rtol=1e-5)
    assert_trees_close(rec["opt"].mu, state["opt"].mu, atol=2e-6, rtol=1e-5)
    # hardware recovery: last persisted step (8)
    rec_h = ldp.recover_hardware(state)
    assert int(rec_h["step"]) == 8
    ldp.close()


# --------------------------------------------------------------------------
# baselines
# --------------------------------------------------------------------------

@pytest.mark.parametrize("cls,kw", [
    (FullSync, {"interval": 4}),
    (CheckFreq, {"interval": 5}),
    (Gemini, {"interval": 1, "persist_interval": 8}),
])
def test_baseline_roundtrip(tmp_path, cls, kw):
    model = tiny_model()
    store = CheckpointStore(str(tmp_path / cls.__name__))
    strat = cls(model, store, lr=1e-3, **kw)
    state = init_state(model, jax.random.PRNGKey(0), mode="dense")
    saved_states = {}
    for t in range(8):
        state, _ = strat.train_step(state, make_batch(model.cfg, SEQ, BATCH,
                                                      step=t))
        saved_states[int(state["step"])] = jax.tree.map(np.asarray, state)
    strat.flush()
    rec, _ = strat.recover()
    step = int(rec["step"])
    assert step in saved_states
    assert_trees_close(rec["params"], saved_states[step]["params"], atol=0)
    strat.close()


def test_naive_dc_exact_when_lossless(tmp_path):
    """With rho=1.0 (no information loss) Naive DC recovery is exact."""
    model = tiny_model()
    store = CheckpointStore(str(tmp_path / "ndc"))
    strat = NaiveDC(model, store, lr=1e-3, rho=1.0, full_interval=50)
    state = init_state(model, jax.random.PRNGKey(0), mode="dense")
    # force an initial full checkpoint to anchor the diff chain
    store.save_full(0, jax.tree.map(np.asarray, state))
    for t in range(6):
        state, _ = strat.train_step(state, make_batch(model.cfg, SEQ, BATCH,
                                                      step=t))
    strat.flush()
    rec, n = strat.recover()
    assert n == 6
    assert_trees_close(rec["params"], state["params"], atol=1e-5, rtol=1e-5)
    strat.close()


def test_lowdiff_quant8_compressor_roundtrip(tmp_path):
    """LowDiff with the int8-quantization compression family (§II-C):
    recovery still reconstructs the live state exactly (the model update
    uses the dequantized gradient, so Finding 1 remains an identity)."""
    model = tiny_model()
    store = CheckpointStore(str(tmp_path / "q8"))
    ld = LowDiff(model, store, lr=1e-3, full_interval=4, batch_size=2,
                 compressor="quant8", parallel_recovery=False)
    state = init_state(model, jax.random.PRNGKey(0), mode="dense")
    for t in range(7):
        state, _ = ld.train_step(state, make_batch(model.cfg, SEQ, BATCH,
                                                   step=t))
    ld.flush()
    rec, n = ld.recover()
    assert n == 3   # full@4 + diffs 5,6,7
    assert_trees_close(rec["params"], state["params"], atol=1e-8, rtol=1e-4)
    assert_trees_close(rec["opt"].mu, state["opt"].mu, atol=1e-8, rtol=1e-4)
    # int8 differentials are ~4x smaller than dense f32
    diff_bytes = np.mean([e["bytes"] for e in store.manifest["batches"]]) / 2
    full_bytes = store.manifest["fulls"][0]["bytes"]
    assert diff_bytes < full_bytes / 8
    ld.close()


def test_naive_dc_lossy_storage_smaller(tmp_path):
    model = tiny_model()
    store = CheckpointStore(str(tmp_path / "ndc2"))
    strat = NaiveDC(model, store, lr=1e-3, rho=0.01, full_interval=50)
    state = init_state(model, jax.random.PRNGKey(0), mode="dense")
    store.save_full(0, jax.tree.map(np.asarray, state))
    for t in range(3):
        state, _ = strat.train_step(state, make_batch(model.cfg, SEQ, BATCH,
                                                      step=t))
    strat.flush()
    full_b = store.manifest["fulls"][0]["bytes"]
    diff_b = store.manifest["diffs"][0]["bytes"]
    assert diff_b < full_b / 5
    strat.close()
