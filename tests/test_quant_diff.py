"""Quantized row differential tests (--diff-quant int8/int4).

Covers the quantized-wire acceptance criteria:
  * the pure-numpy host codec, the jnp oracle and the Pallas
    interpret-mode kernels produce bit-identical wire bytes, scales and
    dequantized rows (odd columns, 1-D tails, both bit widths)
  * :class:`QuantSpan` survives the frame codec round trip with its
    wire bytes verbatim (no backend re-encodes or re-quantizes)
  * int8 and int4 chains recover bit-identical to their dequantized
    overlay on the host path (``load_latest_state``) AND the device
    replay path (``recovery.load_state_device``) across all five
    backends, including mixed raw + int8 + int4 chains, replayed and
    folded
  * a crash injected at ``patch:mid_span`` while folding a quantized
    payload leaves a recoverable store
  * error feedback: quantization error re-marks rows dirty at most once
    per quantized persist (no static-row persist loop), residuals reset
    on full snapshots and on failed persists
  * ``chain_amplification`` measures *stored* (post-quantization) chain
    bytes; the logical span size is journaled separately
  * config plumbing: flag validation in LowDiffPlus and EngineConfig
"""
import os

import numpy as np
import pytest

from repro.checkpoint import StoreConfig, make_store
from repro.checkpoint import io as cio
from repro.checkpoint.patchset import Span, row_update_from_spans
from repro.checkpoint.remote import FakeObjectStore, RemoteObjectBackend
from repro.checkpoint.store import (CheckpointStore, merge_updates,
                                    walk_leaves)
from repro.compression.quant_span import (QUANT_METER, QuantSpan,
                                          decode_rows, encode_rows)
from repro.core import recovery
from repro.core.engine import EngineConfig
from repro.core.lowdiff_plus import LowDiffPlus, _NumpyAdam
from repro.kernels import ops

RNG = np.random.default_rng(23)


def rand(shape, scale=1.0, rng=None):
    return (scale * (rng or RNG).standard_normal(shape)).astype(np.float32)


def assert_state_equal(a, b, context=""):
    bleaves = dict(walk_leaves(b))
    for path, leaf in walk_leaves(a):
        np.testing.assert_array_equal(
            np.asarray(leaf), np.asarray(bleaves[path]),
            err_msg=f"{context}: leaf {path}")


# --------------------------------------------------------------------------
# codec parity: numpy host codec == jnp oracle == Pallas interpret mode
# --------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("shape", [(8, 32), (3, 7), (1, 1), (11, 48),
                                   (5, 1), (16,)])
def test_codec_three_way_bit_parity(bits, shape):
    """Wire bytes, scales and dequantized values are bit-identical
    across the numpy codec, the jnp oracle (use_pallas=False) and the
    Pallas interpret kernel (use_pallas=True) — including odd column
    counts (int4 pads to even) and 1-D rows."""
    rng = np.random.default_rng(bits * 100 + sum(shape))
    x = (rng.standard_normal(shape) * rng.uniform(1e-3, 10)).astype(
        np.float32)
    x2 = x.reshape(x.shape[0], -1) if x.ndim > 1 else x.reshape(-1, 1)
    qn, sn = encode_rows(x2, bits)
    for up in (False, True):
        q, s = ops.quant_span_encode(np.asarray(x2), bits=bits,
                                     use_pallas=up)
        np.testing.assert_array_equal(qn, np.asarray(q),
                                      err_msg=f"q use_pallas={up}")
        np.testing.assert_array_equal(sn, np.asarray(s),
                                      err_msg=f"scale use_pallas={up}")
        d = ops.quant_span_decode(q, s, cols=x2.shape[1], bits=bits,
                                  use_pallas=up)
        np.testing.assert_array_equal(
            decode_rows(qn, sn, x2.shape[1], bits), np.asarray(d),
            err_msg=f"decode use_pallas={up}")


@pytest.mark.parametrize("bits", [8, 4])
def test_fused_span_apply_matches_host_overlay(bits):
    """The device scatter (dequantize + dynamic_update_slice) lands the
    exact bytes the host overlay writes."""
    base = rand((32, 3, 4))
    block = rand((5, 3, 4), scale=3.0)
    q, s = encode_rows(block.reshape(5, -1), bits)
    expect = np.array(base)
    expect[7:12] = decode_rows(q, s, 12, bits).reshape(5, 3, 4)
    for up in (False, True):
        got = ops.fused_span_apply(np.asarray(base), 7, np.asarray(q),
                                   np.asarray(s), bits=bits, use_pallas=up)
        np.testing.assert_array_equal(expect, np.asarray(got),
                                      err_msg=f"use_pallas={up}")


@pytest.mark.parametrize("bits", [8, 4])
def test_codec_bounds_and_error(bits):
    qmax = 127 if bits == 8 else 7
    x = rand((16, 24), scale=5.0)
    q, s = encode_rows(x, bits)
    d = decode_rows(q, s, 24, bits)
    if bits == 8:
        assert np.abs(q.astype(np.int32)).max() <= qmax
    # reconstruction error bounded by half a quantization step per row
    err = np.abs(d - x)
    assert np.all(err <= 0.5 * s + 1e-7)
    # zero rows quantize to zero exactly (scale floors at 1e-12)
    qz, sz = encode_rows(np.zeros((4, 6), np.float32), bits)
    np.testing.assert_array_equal(
        decode_rows(qz, sz, 6, bits), np.zeros((4, 6), np.float32))


# --------------------------------------------------------------------------
# QuantSpan container + frame codec
# --------------------------------------------------------------------------

def test_quant_span_geometry_and_sizes():
    blocks = [rand((3, 8)), rand((2, 8))]
    qs = QuantSpan.from_rows([2, 10], blocks, (16, 8), 4)
    assert qs.extents() == [(2, 5), (10, 12)]
    assert qs.rows == 5 and qs.cols == 8
    assert qs.logical_nbytes == 5 * 8 * 4
    # int4: 4 packed bytes + 4 scale bytes per 8-col row
    assert qs.nbytes == 5 * (4 + 4)
    assert qs.nbytes < qs.logical_nbytes
    spans = qs.spans()
    assert [sp.start for sp in spans] == [2, 10]
    np.testing.assert_array_equal(
        spans[0].data,
        decode_rows(qs.qs[0], qs.scales[0], 8, 4).reshape(3, 8))


@pytest.mark.parametrize("bits", [8, 4])
def test_quant_span_frame_roundtrip_carries_wire_bytes_verbatim(bits):
    ru = row_update_from_spans(
        [Span(1, rand((2, 6))), Span(9, rand((3, 6)))], (16, 6))
    qs = QuantSpan.from_row_update(ru, bits)
    upd = {"params": {"w": qs}, "count": np.array(7, np.int64)}
    rt = cio.loads_any(cio.dumps(upd))
    got = rt["params"]["w"]
    assert isinstance(got, QuantSpan)
    assert got.bits == bits and got.shape == (16, 6)
    assert got.starts == qs.starts and got.dtype == qs.dtype
    for a, b in zip(qs.qs, got.qs):
        np.testing.assert_array_equal(a, b)
        assert a.dtype == b.dtype
    for a, b in zip(qs.scales, got.scales):
        np.testing.assert_array_equal(a, b)
    # walk_leaves treats the container as one leaf, like RowUpdate
    assert dict(walk_leaves(upd))["params/w"] is got or \
        isinstance(dict(walk_leaves(upd))["params/w"], QuantSpan)


# --------------------------------------------------------------------------
# replica: quantized snapshots + error feedback
# --------------------------------------------------------------------------

def mk_replica(diff_quant, rows=64, cols=24, seed=0):
    rng = np.random.default_rng(seed)
    p = {"table": rand((rows, cols), scale=0.1, rng=rng),
         "b": np.zeros(cols, np.float32)}
    mu = {k: np.zeros_like(v) for k, v in p.items()}
    nu = {k: np.zeros_like(v) for k, v in p.items()}
    return _NumpyAdam(p, mu, nu, 0, lr=1e-2, track_dirty=True,
                      dirty_granularity="row", diff_quant=diff_quant)


def sparse_grads(rep, touch, cols=24):
    g = np.zeros_like(rep.params["table"])
    rng = np.random.default_rng(hash(tuple(touch)) % (2 ** 31))
    for r in touch:
        g[r] = rand(cols, rng=rng)
    return {"table": g, "b": np.zeros_like(rep.params["b"])}


@pytest.mark.parametrize("dq", ["int8", "int4"])
def test_snapshot_emits_quant_spans_with_residuals(dq):
    rep = mk_replica(dq)
    rep.snapshot_full()
    rep.apply(sparse_grads(rep, [3, 4, 20]))
    upd, deferred = rep.snapshot_dirty()
    assert deferred == 0
    qs = upd["params"]["table"]
    assert isinstance(qs, QuantSpan)
    assert qs.bits == (8 if dq == "int8" else 4)
    assert qs.extents() == [(3, 5), (20, 21)]
    for comp in ("mu", "nu"):
        assert isinstance(upd[comp]["table"], QuantSpan)
        # Adam moments floor at 8 bits: 4-bit moment error is amplified
        # by 1/sqrt(nu) on resume and diverges
        assert upd[comp]["table"].bits == 8
    # residual == persisted value - dequantized value on touched rows
    res = rep._row_resid[("params", "table")]
    span = qs.spans()[0]
    np.testing.assert_allclose(
        res[3:5], rep.params["table"][3:5] - span.data, atol=0, rtol=0)
    # untouched rows carry no residual
    assert np.all(res[6:20] == 0)


def test_error_feedback_keeps_residuals_bounded():
    """Quantizing corrected = raw + residual keeps the deferred error
    bounded by half a quantization step on every persist — it never
    random-walks or compounds down a long chain of re-persists of the
    same rows (the Check-N-Run §4 argument)."""
    rep = mk_replica("int4")
    rep.snapshot_full()
    for it in range(20):
        rep.apply(sparse_grads(rep, [5, 6]))
        upd, _ = rep.snapshot_dirty()
        qs = upd["params"]["table"]
        assert isinstance(qs, QuantSpan)
        # the persisted bytes are quantize(raw + residual): the new
        # residual (raw - dequant) is at most half a step per row
        res = rep._row_resid[("params", "table")][5:7]
        step_half = 0.5 * np.concatenate(
            [s for s in qs.scales]).max() + 1e-7
        assert np.abs(res).max() <= step_half, f"persist {it}"


def test_ef_remarks_row_at_most_once_per_persist():
    """threshold > 0: a quantized persist re-marks rows whose residual
    beats the threshold — but a re-marked row that re-persists without a
    fresh gradient is NOT re-marked again (no static-row ping-pong)."""
    rep = mk_replica("int4")
    rep.snapshot_full()
    rep.apply(sparse_grads(rep, [7]))
    upd, _ = rep.snapshot_dirty(threshold=1e-9)   # any residual re-marks
    assert isinstance(upd["params"]["table"], QuantSpan)
    assert rep._row_dirty["table"][7]             # corrective pass queued
    upd2, _ = rep.snapshot_dirty(threshold=1e-9)  # corrective persist
    assert upd2["params"]["table"].extents() == [(7, 8)]
    # residual still nonzero, but qpending blocks a third pass
    assert not rep._row_dirty["table"][7]
    upd3, _ = rep.snapshot_dirty(threshold=1e-9)
    assert upd3["params"] == {}


def test_ef_threshold_zero_never_remarks():
    rep = mk_replica("int8")
    rep.snapshot_full()
    rep.apply(sparse_grads(rep, [2, 9]))
    rep.snapshot_dirty()                          # threshold == 0
    assert not rep._row_dirty["table"].any()
    assert rep.snapshot_dirty()[0]["params"] == {}


def test_full_snapshot_resets_residuals():
    rep = mk_replica("int4")
    rep.snapshot_full()
    rep.apply(sparse_grads(rep, [1]))
    rep.snapshot_dirty()
    assert np.any(rep._row_resid[("params", "table")] != 0)
    rep.snapshot_full()                           # raw persist: no error
    assert not np.any(rep._row_resid[("params", "table")] != 0)


def test_remark_dirty_zeroes_stale_residuals():
    """A failed quantized persist re-marks its spans AND drops their
    residuals: the correction belonged to bytes that never landed."""
    rep = mk_replica("int8")
    rep.snapshot_full()
    rep.apply(sparse_grads(rep, [4, 5]))
    upd, _ = rep.snapshot_dirty()
    assert np.any(rep._row_resid[("params", "table")][4:6] != 0)
    rep.remark_dirty(upd)
    assert not np.any(rep._row_resid[("params", "table")][4:6] != 0)
    again, _ = rep.snapshot_dirty()
    assert again["params"]["table"].extents() == upd["params"]["table"] \
        .extents()


# --------------------------------------------------------------------------
# recovery: quantized + mixed chains, all five backends, host and device
# --------------------------------------------------------------------------

def mk_backend_store(tmp_path, kind):
    root = str(tmp_path / kind)
    if kind == "local":
        return make_store(root)
    if kind == "sharded":
        return make_store(root, backend="sharded", shards=3)
    if kind == "memory":
        return make_store(root, backend="memory")
    if kind == "remote":
        be = RemoteObjectBackend(FakeObjectStore(), chunk_bytes=4096,
                                 journal_root=root)
        return CheckpointStore(backend=be)
    if kind == "peer":
        cfg = StoreConfig.from_legacy(
            root, peers=2, peer_hub=f"qd_{os.path.basename(str(tmp_path))}",
            simulate_peers=True)
        return cfg.build()
    raise AssertionError(kind)


def drive_quant_chain(store, dq, persists=5):
    rep = mk_replica(dq, rows=96, seed=3)
    base = store.save_full(1, rep.snapshot_full(), record_names=True)
    expected = {k: ({kk: np.array(vv) for kk, vv in v.items()}
                    if isinstance(v, dict) else np.array(v))
                for k, v in rep.snapshot_full().items()}
    rng = np.random.default_rng(17)
    for step in range(2, 2 + persists):
        touch = rng.choice(96, size=6, replace=False)
        rep.apply(sparse_grads(rep, sorted(int(r) for r in touch)))
        updates, _ = rep.snapshot_dirty()
        store.save_patch(step, base, updates)
        merge_updates(expected, updates)
    return base, expected, 1 + persists


@pytest.mark.parametrize("kind", ["local", "sharded", "memory",
                                  "remote", "peer"])
@pytest.mark.parametrize("dq", ["int8", "int4"])
def test_quant_chain_recovers_bit_identical_host_and_device(tmp_path,
                                                            kind, dq):
    """The acceptance bar: a quantized chain recovers bit-identical to
    its dequantized overlay on the host path and the device replay
    path, on every backend."""
    store = mk_backend_store(tmp_path, kind)
    try:
        base, expected, last = drive_quant_chain(store, dq)
        got, step = store.load_latest_state()
        assert step == last
        assert_state_equal(expected, got, f"{kind}/{dq} host")
        dgot, dstep = recovery.load_state_device(store)
        assert dstep == last
        assert_state_equal(expected, dgot, f"{kind}/{dq} device")
    finally:
        store.close()


@pytest.mark.parametrize("kind", ["local", "sharded", "memory",
                                  "remote", "peer"])
def test_mixed_chain_replays_and_folds_on_every_backend(tmp_path, kind):
    """raw-span base + int8 patch + int4 patch: the chain replays
    newest-wins and folds bit-identical-after-dequant — fold writes raw
    dequantized rows, never quantize-of-quantize."""
    store = mk_backend_store(tmp_path, kind)
    try:
        w = rand((48, 8))
        state = {"params": {"w": w}, "count": np.array(0, np.int64)}
        base = store.save_full(1, state, record_names=True)
        expected = {"params": {"w": np.array(w)},
                    "count": np.array(0, np.int64)}
        # raw row-span patch
        raw = {"params": {"w": row_update_from_spans(
            [Span(2, rand((3, 8))), Span(30, rand((2, 8)))], (48, 8))},
            "count": np.array(1, np.int64)}
        store.save_patch(2, base, raw)
        merge_updates(expected, raw)
        # int8 patch overlapping the raw spans (newest wins)
        q8 = {"params": {"w": QuantSpan.from_rows(
            [3, 40], [rand((2, 8)), rand((4, 8))], (48, 8), 8)},
            "count": np.array(2, np.int64)}
        store.save_patch(3, base, q8)
        merge_updates(expected, q8)
        # int4 patch overlapping both
        q4 = {"params": {"w": QuantSpan.from_rows(
            [2, 41], [rand((2, 8)), rand((2, 8))], (48, 8), 4)},
            "count": np.array(3, np.int64)}
        store.save_patch(4, base, q4)
        merge_updates(expected, q4)

        got, step = store.load_latest_state()
        assert step == 4
        assert_state_equal(expected, got, f"{kind} mixed replay")
        dgot, _ = recovery.load_state_device(store)
        assert_state_equal(expected, dgot, f"{kind} mixed device")

        # manifest journals the codec per quantized patch
        codecs = {e["step"]: e.get("codec") for e in
                  store.manifest["patches"]}
        assert codecs[2] is None and codecs[3] == ["int8"] \
            and codecs[4] == ["int4"]

        assert store.fold_sync() == 3
        folded = store.load_full(store.latest_full())
        assert_state_equal(expected, folded, f"{kind} mixed fold")
        # the folded base holds raw bytes: reload matches exactly
        got2, _ = store.load_latest_state()
        assert_state_equal(expected, got2, f"{kind} refold")
    finally:
        store.close()


def test_crash_at_mid_span_with_quantized_payload(tmp_path):
    """A kill between two row-span pwrites while folding a quantized
    patch leaves torn raw ranges in the base frame — the chain replays
    over them on restart, and a refold completes."""

    class Killed(RuntimeError):
        pass

    root = str(tmp_path / "s")
    store = make_store(root)
    base, expected, last = drive_quant_chain(store, "int4", persists=3)

    def hook(p):
        if p == "patch:mid_span":
            raise Killed(p)
    cio.set_patch_crash_hook(hook)
    try:
        with pytest.raises(Killed):
            store.fold_sync()
    finally:
        cio.set_patch_crash_hook(None)
    store.journal.close()

    store2 = make_store(root)
    try:
        got, step = store2.load_latest_state()
        assert step == last
        assert_state_equal(expected, got, "after mid_span kill")
        assert store2.fold_sync() == 3
        assert_state_equal(expected, store2.load_full(store2.latest_full()),
                           "refold")
        assert store2.backend.verify(base) is None
    finally:
        store2.close()


# --------------------------------------------------------------------------
# chain_amplification: stored bytes, not logical span bytes
# --------------------------------------------------------------------------

def test_chain_amplification_uses_stored_not_logical_bytes(tmp_path):
    """Satellite: the adaptive fold trigger reads what the backend
    actually wrote. A quantized patch's manifest entry carries stored
    ``bytes`` < journaled logical ``span_bytes``, and the amplification
    ratio sums the stored side."""
    store = make_store(str(tmp_path / "s"))
    try:
        w = rand((256, 64))
        base = store.save_full(1, {"params": {"w": w},
                                   "count": np.array(0, np.int64)},
                               record_names=True)
        base_bytes = next(int(e["bytes"]) for e in store.manifest["fulls"])
        qs = QuantSpan.from_rows([0], [rand((64, 64))], (256, 64), 4)
        store.save_patch(2, base, {"params": {"w": qs},
                                   "count": np.array(1, np.int64)})
        entry = store.manifest["patches"][-1]
        assert entry["codec"] == ["int4"]
        # logical side: the raw bytes those rows would occupy
        assert entry["span_bytes"] == 64 * 64 * 4
        # stored side: roughly 8x smaller (nibbles + scales + framing)
        assert entry["bytes"] < entry["span_bytes"] / 4
        assert store.chain_amplification() == pytest.approx(
            entry["bytes"] / base_bytes)
    finally:
        store.close()


# --------------------------------------------------------------------------
# config plumbing
# --------------------------------------------------------------------------

def test_lowdiff_plus_rejects_bad_diff_quant_combos(tmp_path):
    from repro.configs import get_config
    from repro.models.registry import build_model
    store = make_store(str(tmp_path / "s"))
    try:
        with pytest.raises(ValueError, match="diff_quant"):
            LowDiffPlus(object(), store, diff_quant="int2")
        with pytest.raises(ValueError, match="dirty-granularity row"):
            LowDiffPlus(object(), store, persist_mode="incremental",
                        dirty_granularity="leaf", diff_quant="int8")
        with pytest.raises(ValueError, match="persist-mode incremental"):
            LowDiffPlus(object(), store, persist_mode="full",
                        diff_quant="int4")
        model = build_model(get_config("qwen2-1.5b").reduced())
        eng = LowDiffPlus(model, store, persist_mode="incremental",
                          dirty_granularity="row", diff_quant="int8")
        assert eng.stats()["diff_quant"] == "int8"
        assert "quant" in eng.stats()
    finally:
        store.close()


def test_engine_config_diff_quant_validation():
    from repro.checkpoint.config import StoreConfigError
    cfg = EngineConfig(strategy="lowdiff_plus", persist_mode="incremental",
                       dirty_granularity="row", diff_quant="int4")
    cfg.validate()
    assert cfg.to_dict()["diff_quant"] == "int4"
    assert EngineConfig.from_dict(cfg.to_dict()).diff_quant == "int4"
    with pytest.raises(StoreConfigError, match="diff_quant"):
        EngineConfig(diff_quant="fp8").validate()


def test_quant_meter_counts_encode_and_decode(tmp_path):
    QUANT_METER.reset()
    store = make_store(str(tmp_path / "s"))
    try:
        drive_quant_chain(store, "int4", persists=2)
        store.load_latest_state()
        s = QUANT_METER.stats()
        assert s["bytes_in"] > 0 and s["bytes_out"] > 0
        assert s["bytes_out"] < s["bytes_in"]
        assert s["ratio"] == pytest.approx(s["bytes_in"] / s["bytes_out"])
        assert s["encode_s"] >= 0 and s["decode_s"] > 0
    finally:
        store.close()
        QUANT_METER.reset()
