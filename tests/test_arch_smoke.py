"""Per-architecture smoke tests (reduced configs, CPU).

Every assigned architecture instantiates a reduced variant (2 layers,
d_model<=256, <=4 experts) and runs one forward/train step + one decode
step, asserting output shapes and finiteness.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.data.synthetic import make_batch
from repro.models.registry import build_model
from repro.optim.adam import adam_init, adam_update

SEQ = 32
BATCH = 2


@pytest.fixture(scope="module", params=ASSIGNED_ARCHS)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_forward_loss_finite(arch_setup):
    cfg, model, params = arch_setup
    batch = make_batch(cfg, SEQ, BATCH)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss)
    assert metrics["tokens"] > 0


def test_train_step_updates_and_finite(arch_setup):
    cfg, model, params = arch_setup
    batch = make_batch(cfg, SEQ, BATCH)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch)
        params, opt = adam_update(params, grads, opt, lr=1e-3)
        return params, opt, loss, grads

    opt = adam_init(params)
    params2, opt2, loss, grads = step(params, opt, batch)
    # gradients flow to every leaf
    flat = jax.tree.leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in flat)
    nonzero = sum(bool(jnp.any(g != 0)) for g in flat)
    assert nonzero >= len(flat) - 2  # allow rare dead leaves (e.g. unused bias)
    # params actually moved
    moved = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, params2)
    assert any(jax.tree.leaves(moved))


def test_decode_step_shapes(arch_setup):
    cfg, model, params = arch_setup
    seq_len = 64
    cache = model.init_cache(BATCH, seq_len)
    batch = make_batch(cfg, seq_len, BATCH, kind="decode")
    logits, cache2 = jax.jit(
        lambda p, c, b: model.decode_step(p, c, b, seq_len))(params, cache, batch)
    assert logits.shape == (BATCH, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_decode_matches_prefill_last_token(arch_setup):
    """Decoding token-by-token from zeros matches full forward (causal)."""
    cfg, model, params = arch_setup
    if cfg.arch_type == "audio":
        pytest.skip("audio decode needs cross-cache prefill (covered elsewhere)")
    seq_len = 16
    batch = make_batch(cfg, seq_len, BATCH)
    if cfg.arch_type == "vlm":
        pytest.skip("vlm prefill includes patches; decode parity n/a")
    full_logits = jax.jit(model.logits_fn)(params, batch)
    cache = model.init_cache(BATCH, seq_len)
    step = jax.jit(lambda p, c, b: model.decode_step(p, c, b, seq_len))
    for t in range(seq_len):
        dbatch = {"tokens": batch["tokens"][:, t:t + 1],
                  "pos": jnp.asarray(t, jnp.int32)}
        logits, cache = step(params, cache, dbatch)
    assert jnp.allclose(full_logits, logits, atol=2e-2, rtol=2e-2), (
        float(jnp.max(jnp.abs(full_logits - logits))))
